"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
(arXiv:2411.15242).

The shared transformer block (full attention + SwiGLU MLP, parameters shared
across all applications) is applied after every ``cfg.shared_attn_every``
Mamba2 blocks.  The Mamba stack is scanned segment-wise; the shared block is
applied at the Python level between segments (weights identical, KV caches
distinct per application site).

DR-FL: the layer mask covers the 38 Mamba blocks; the shared block is part of
every submodel (it is shared knowledge — always aggregated), see DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.rules import constrain
from repro.models import transformer as T
from repro.models.ssm import mamba_apply, mamba_decode, mamba_init, mamba_state_init


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _segments(cfg):
    """Split num_layers mamba blocks into segments; a shared-attn application
    follows every full segment of size shared_attn_every."""
    k = cfg.shared_attn_every or cfg.num_layers
    sizes, rest = [], cfg.num_layers
    while rest > 0:
        sizes.append(min(k, rest))
        rest -= k
    return sizes


def num_attn_sites(cfg):
    return sum(1 for s in _segments(cfg) if s == (cfg.shared_attn_every or cfg.num_layers))


def init(key, cfg):
    dtype = _dt(cfg)
    k_emb, k_m, k_a, k_out = jax.random.split(key, 4)
    return {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "mamba": jax.vmap(lambda k: mamba_init(k, cfg, dtype))(
            jax.random.split(k_m, cfg.num_layers)),
        "shared_attn": T.block_init(k_a, cfg, dtype),   # one block, reused
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "unembed": L.dense_init(k_out, cfg.d_model, cfg.vocab_size, dtype),
    }


def unembed_matrix(params, cfg):
    return params["unembed"]["w"]


def _slice(tree, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def apply(params, cfg, tokens, *, layer_mask=None, window=None,
          use_pallas=False, attn_chunk=0, remat="full"):
    B, S = tokens.shape
    x = params["embed"]["emb"][tokens]
    positions = jnp.arange(S)
    mask = (jnp.ones((cfg.num_layers,), jnp.float32)
            if layer_mask is None else layer_mask.astype(jnp.float32))

    def seg_body(x, scanned):
        mp, gate = scanned
        d, _ = mamba_apply(mp, cfg, x)
        return constrain(x + gate.astype(x.dtype) * d), None

    body = jax.checkpoint(seg_body) if remat != "none" else seg_body

    lo = 0
    for size in _segments(cfg):
        x, _ = jax.lax.scan(body, x, (_slice(params["mamba"], lo, lo + size),
                                      mask[lo:lo + size]))
        lo += size
        if size == (cfg.shared_attn_every or cfg.num_layers):
            x, _, _ = T.block_apply(params["shared_attn"], cfg, x, positions,
                                    jnp.ones((), x.dtype), window=window,
                                    use_pallas=use_pallas,
                                    attn_chunk=attn_chunk)
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def logits_fn(params, cfg, hidden):
    return (hidden @ unembed_matrix(params, cfg)).astype(jnp.float32)


def decode_init(params, cfg, batch: int, seq_len: int, *, window=None):
    w = cfg.window if window is None else window
    clen = min(seq_len, w) if w else seq_len
    dtype = _dt(cfg)
    n_sites = num_attn_sites(cfg)
    st = mamba_state_init(cfg, batch)
    return {
        "mamba": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), st),
        "attn": {
            "k": jnp.zeros((n_sites, batch, clen, cfg.num_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((n_sites, batch, clen, cfg.num_kv_heads, cfg.hd), dtype),
            "pos": jnp.zeros((n_sites,), jnp.int32),
        },
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg, cache, tokens, pos, *, layer_mask=None, window=None):
    x = params["embed"]["emb"][tokens]
    mask = (jnp.ones((cfg.num_layers,), jnp.float32)
            if layer_mask is None else layer_mask.astype(jnp.float32))
    positions = pos[None] if jnp.ndim(pos) == 0 else pos

    def seg_body(x, scanned):
        mp, st, gate = scanned
        d, st = mamba_decode(mp, cfg, x, st)
        return x + gate.astype(x.dtype) * d, st

    new_mamba, new_attn_k, new_attn_v, new_attn_pos = [], [], [], []
    lo, site = 0, 0
    for size in _segments(cfg):
        x, st = jax.lax.scan(
            seg_body, x,
            (_slice(params["mamba"], lo, lo + size),
             _slice(cache["mamba"], lo, lo + size), mask[lo:lo + size]))
        new_mamba.append(st)
        lo += size
        if size == (cfg.shared_attn_every or cfg.num_layers):
            c = {"k": cache["attn"]["k"][site], "v": cache["attn"]["v"][site],
                 "pos": cache["attn"]["pos"][site]}
            x, c, _ = T.block_apply(params["shared_attn"], cfg, x, positions,
                                    jnp.ones((), x.dtype), window=window, cache=c)
            new_attn_k.append(c["k"])
            new_attn_v.append(c["v"])
            new_attn_pos.append(c["pos"])
            site += 1

    new_cache = {
        "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_mamba),
        "attn": {"k": jnp.stack(new_attn_k), "v": jnp.stack(new_attn_v),
                 "pos": jnp.stack(new_attn_pos)},
        "pos": cache["pos"] + 1,
    }
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, x), new_cache
