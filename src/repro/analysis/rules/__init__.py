"""jaxlint rule registry.

A rule is a callable ``rule(index: RepoIndex, config: LintConfig) ->
list[Finding]``.  Register new rules here; ``--list-rules`` and the
``rules=`` config filter read this mapping.
"""
from __future__ import annotations

from . import (frozen_refs, host_sync, kernel_parity, pytree_coverage,
               retrace)

ALL_RULES = {
    host_sync.RULE: host_sync.check,
    retrace.RULE: retrace.check,
    pytree_coverage.RULE: pytree_coverage.check,
    kernel_parity.RULE: kernel_parity.check,
    frozen_refs.RULE: frozen_refs.check,
}

__all__ = ["ALL_RULES"]
