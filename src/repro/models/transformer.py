"""Dense / MoE decoder-only transformer with scan-over-layers stacked params.

Used directly by yi-34b, phi3-mini, minitron, command-r (dense) and
qwen3-moe, mixtral (moe).  The VLM/audio models build on the same block.

DR-FL integration: ``apply`` takes ``layer_mask`` — a float ``[L]`` vector
multiplying every block's residual delta, so a depth-prefix submodel
(paper §4.2) is simply ``mask = [1]*k + [0]*(L-k)`` with *no* retracing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.rules import constrain, gather_block_input
from repro.models.moe import moe_apply, moe_init


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def block_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attention_init(ks[0], cfg, dtype),
        "mlp_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.num_experts:
        p["moe"] = moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dtype, bias=cfg.mlp_bias)
    return p


def block_apply(p, cfg, x, positions, gate, *, window=None, use_pallas=False,
                attn_chunk=0, cache=None):
    """One pre-norm residual block.  Returns (x, new_cache, aux_loss)."""
    window = cfg.window if window is None else window
    x = gather_block_input(x)
    h = L.rmsnorm_apply(p["attn_norm"], x, cfg.norm_eps)
    a, new_cache = L.attention_apply(
        p["attn"], cfg, h, positions, causal=True, window=window,
        cache=cache, use_pallas=use_pallas, attn_chunk=attn_chunk,
        norm_eps=cfg.norm_eps)
    x = x + gate * a
    h = L.rmsnorm_apply(p["mlp_norm"], x, cfg.norm_eps)
    if cfg.num_experts:
        m, aux = moe_apply(p["moe"], cfg, h)
    else:
        m, aux = L.swiglu_apply(p["mlp"], h), jnp.zeros((), jnp.float32)
    x = x + gate * m
    return x, new_cache, aux


def init(key, cfg):
    dtype = _dt(cfg)
    k_emb, k_blocks, k_out = jax.random.split(key, 3)
    params = {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": jax.vmap(lambda k: block_init(k, cfg, dtype))(
            jax.random.split(k_blocks, cfg.num_layers)),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(k_out, cfg.d_model, cfg.vocab_size, dtype)
    return params


def unembed_matrix(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"]["emb"].T
    return params["unembed"]["w"]


def _remat_wrap(fn, mode):
    if mode == "none":
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if mode == "dots" else None)
    return jax.checkpoint(fn, policy=policy)


def apply(params, cfg, tokens, *, layer_mask=None, window=None,
          use_pallas=False, attn_chunk=0, remat="full"):
    """tokens: [B, S] int32 -> (hidden [B, S, d], aux_loss scalar).

    ``layer_mask`` is either ``[L]`` (one submodel for the whole batch) or
    ``[L, B]`` (per-example depth-prefix gates — the FL-over-pods step feeds
    each pod's submodel mask through the batch dimension).

    Final logits are intentionally NOT computed here — the train step uses a
    sequence-chunked cross-entropy to avoid materialising [B, S, V].
    """
    B, S = tokens.shape
    x = constrain(params["embed"]["emb"][tokens])
    positions = jnp.arange(S)
    mask = (jnp.ones((cfg.num_layers,), jnp.float32)
            if layer_mask is None else layer_mask.astype(jnp.float32))

    def body(carry, scanned):
        x, aux = carry
        bp, gate = scanned
        g = gate if gate.ndim == 0 else gate[:, None, None]   # [B]->[B,1,1]
        x, _, a = block_apply(bp, cfg, x, positions, g.astype(x.dtype),
                              window=window, use_pallas=use_pallas,
                              attn_chunk=attn_chunk)
        return (constrain(x), aux + gate.mean() * a), None

    body = _remat_wrap(body, remat)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (params["blocks"], mask))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def logits_fn(params, cfg, hidden):
    return (hidden @ unembed_matrix(params, cfg)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------


def decode_cache_len(cfg, seq_len: int) -> int:
    """SWA models keep a ring-sized window cache; full attention keeps all."""
    return min(seq_len, cfg.window) if cfg.window else seq_len


def decode_init(params, cfg, batch: int, seq_len: int, *, window=None):
    w = cfg.window if window is None else window
    clen = min(seq_len, w) if w else seq_len
    dtype = _dt(cfg)
    Lr, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((Lr, batch, clen, Hkv, hd), dtype),
        "v": jnp.zeros((Lr, batch, clen, Hkv, hd), dtype),
        "pos": jnp.zeros((Lr,), jnp.int32),
    }


def decode_step(params, cfg, cache, tokens, pos, *, layer_mask=None, window=None):
    """tokens: [B, 1]; pos: scalar int32 absolute position.

    Returns (logits [B, 1, V], new_cache).
    """
    x = params["embed"]["emb"][tokens]
    mask = (jnp.ones((cfg.num_layers,), jnp.float32)
            if layer_mask is None else layer_mask.astype(jnp.float32))
    positions = pos[None] if jnp.ndim(pos) == 0 else pos

    def body(x, scanned):
        bp, c, gate = scanned
        # cache-relative write position (ring-free: clamp to cache length)
        y, new_c, _ = block_apply(bp, cfg, x, positions, gate.astype(x.dtype),
                                  window=window, cache=c)
        return y, new_c

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache, mask))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, x), new_cache
