"""Charge / availability profiles + the per-run :class:`EnergyScenario`.

The repo's energy model was a static battery: ``remaining`` only ever goes
down (``fleet_charge``) and a device is live whenever ``alive`` says so.
This module adds the scenario axis the DR-FL extensions target (PAPERS.md:
intermittent battery-powered clients, arXiv 2208.04505; global energy
budgets, arXiv 2506.10413) as three orthogonal, composable pieces:

* **charge profiles** — how energy comes BACK: a pure ``[n]``-array
  ``rate(fleet, sim_time)`` in J/s, built only from ``FleetState`` arrays
  (``charge_rate`` amplitude, ``tz_phase`` time-of-day offset) and the sim
  clock, so applying charge stays elementwise over the fleet axis and a
  row-sharded fleet never gathers (the one-all-reduce shape of
  ``dual_selection_energy_step`` is preserved);
* **availability profiles** — when devices are ON: a ``[n]`` bool mask of
  ``(fleet, sim_time)``; unavailable devices auto-abstain exactly like
  dead ones (the async engine also keeps a numpy twin over its host-side
  ``tz_phase`` mirror so per-event idle checks cost no device sync);
* **the global budget** — a fleet-wide joule ceiling enforced by the
  engine + every selector (see ``EnergyScenario.global_budget_j``).

Profiles are small frozen dataclasses (hashable → safe as jit static
arguments) resolved through registries mirroring the
:mod:`repro.models.family` idiom, so adding a scenario is registering a
class, not editing the engine.

Backend-generic: every array expression works on numpy float64 fleets and
jnp fleets alike (``_xp`` dispatch, same as :mod:`repro.core.fleet`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple, Type

import numpy as np

Array = Any  # np.ndarray | jax.Array — profile kernels are backend-generic

#: dedicated RNG stream tag for per-device profile arrays — a distinct
#: spawn key from the fleet/data streams, so enabling a profile never
#: perturbs fleet sampling or Dirichlet shards for the same seed
_PROFILE_RNG_TAG = 0xE67

#: carbon-intensity cutoff for ``carbon_window`` participation pricing:
#: devices abstain while their local intensity exceeds this fraction of
#: the daily peak (the top-intensity ~1/3 of the day)
CARBON_INTENSITY_CUTOFF = 0.75


def _xp(fleet):
    import jax
    import jax.numpy as jnp
    return jnp if isinstance(fleet.remaining, jax.Array) else np


# ---------------------------------------------------------------------------
# charge profiles
# ---------------------------------------------------------------------------


class ChargeProfile:
    """How energy returns to the fleet.

    ``rate`` is the whole contract: a pure ``[n]`` J/s array from fleet
    arrays + the sim clock (no host syncs, no python-per-device work).
    ``participation_ok`` optionally prices *participation* by the same
    clock (``None`` = no gate); ``next_ok_host``/``ok_host`` are the numpy
    twins the async engine's host-side dispatch mask consumes.
    """

    name: str = "abstract"

    def rate(self, fleet, sim_time) -> Array:
        """[n] instantaneous charge rate (J/s) at ``sim_time``."""
        raise NotImplementedError

    def participation_ok(self, fleet, sim_time) -> Optional[Array]:
        """[n] bool participation gate, or None when this profile never
        gates (the common case — only priced windows gate)."""
        return None

    def ok_host(self, tz_phase: np.ndarray, now: float) -> Optional[np.ndarray]:
        """Numpy twin of :meth:`participation_ok` over the host ``tz_phase``
        mirror (async engine dispatch mask)."""
        return None

    def next_ok_host(self, tz_phase: np.ndarray, now: float) -> np.ndarray:
        """[n] earliest sim time >= now at which each device's gate is
        open (``now`` where it already is) — the async engine's wake-event
        schedule.  Profiles without a gate are always open."""
        return np.full(np.shape(tz_phase), float(now))


@dataclasses.dataclass(frozen=True)
class ConstantCharge(ChargeProfile):
    """Flat trickle charge at each device's ``charge_rate`` J/s.

    With the default ``charge_rate = 0`` amplitude this is exactly the
    pre-profile energy model (no recharge ever) — the scenario layer skips
    the charge program entirely in that case, keeping the default path
    bit-for-bit."""

    name: str = "constant"
    period: float = 86400.0             # unused; kept for a uniform ctor

    def rate(self, fleet, sim_time) -> Array:
        return fleet.charge_rate


@dataclasses.dataclass(frozen=True)
class SolarCharge(ChargeProfile):
    """Solar harvesting: a phase-shifted sinusoid clipped at zero.

    ``rate_n(t) = charge_rate_n * max(0, sin(2π (t/period + tz_phase_n)))``
    — per-device amplitude (panel size / weather) and phase (longitude:
    local solar time IS the timezone, so the same ``tz_phase`` array also
    drives diurnal availability).  Day-average yield is ``amplitude / π``.
    """

    name: str = "solar"
    period: float = 86400.0

    def rate(self, fleet, sim_time) -> Array:
        xp = _xp(fleet)
        ang = 2.0 * math.pi * (sim_time / self.period + fleet.tz_phase)
        return fleet.charge_rate * xp.maximum(xp.sin(ang), 0.0)


@dataclasses.dataclass(frozen=True)
class CarbonWindowCharge(ChargeProfile):
    """Carbon/price-aware windows: charging AND participation priced by a
    time-of-day grid-intensity curve.

    Local intensity ``I_n(t) = 0.5 - 0.5 cos(2π (t/period + tz_phase_n))``
    (0 at local midnight, 1 at local peak).  Devices charge at
    ``charge_rate * (1 - I)`` — grid energy flows when it is clean/cheap —
    and abstain from training while ``I > CARBON_INTENSITY_CUTOFF`` (the
    dirty peak), so the selector must schedule around each device's
    window."""

    name: str = "carbon_window"
    period: float = 86400.0

    def _intensity(self, xp, tz_phase, sim_time):
        ang = 2.0 * math.pi * (sim_time / self.period + tz_phase)
        return 0.5 - 0.5 * xp.cos(ang)

    def rate(self, fleet, sim_time) -> Array:
        xp = _xp(fleet)
        return fleet.charge_rate * (
            1.0 - self._intensity(xp, fleet.tz_phase, sim_time))

    def participation_ok(self, fleet, sim_time) -> Array:
        xp = _xp(fleet)
        return (self._intensity(xp, fleet.tz_phase, sim_time)
                <= CARBON_INTENSITY_CUTOFF)

    def ok_host(self, tz_phase: np.ndarray, now: float) -> np.ndarray:
        return (self._intensity(np, np.asarray(tz_phase, np.float64), now)
                <= CARBON_INTENSITY_CUTOFF)

    def next_ok_host(self, tz_phase: np.ndarray, now: float) -> np.ndarray:
        # I <= cutoff  <=>  cos(2π x) >= 1 - 2*cutoff, open on the phase
        # band [1 - x_c, 1 + x_c] around each whole turn (x_c from acos);
        # a blocked device reopens when its phase next reaches 1 - x_c
        tz = np.asarray(tz_phase, np.float64)
        x = (now / self.period + tz) % 1.0
        x_c = math.acos(1.0 - 2.0 * CARBON_INTENSITY_CUTOFF) / (2.0 * math.pi)
        blocked = (x > x_c) & (x < 1.0 - x_c)
        return np.where(blocked, now + ((1.0 - x_c) - x) * self.period, now)


# ---------------------------------------------------------------------------
# availability profiles
# ---------------------------------------------------------------------------


class AvailabilityProfile:
    """When devices are reachable at all (user-traffic waves).

    ``available`` is the device-side mask; ``available_host`` /
    ``next_available_host`` are the numpy twins over the async engine's
    host ``tz_phase`` mirror."""

    name: str = "abstract"

    def available(self, fleet, sim_time) -> Optional[Array]:
        """[n] bool mask, or None when every device is always available."""
        return None

    def available_host(self, tz_phase: np.ndarray,
                       now: float) -> Optional[np.ndarray]:
        return None

    def next_available_host(self, tz_phase: np.ndarray,
                            now: float) -> np.ndarray:
        return np.full(np.shape(tz_phase), float(now))


@dataclasses.dataclass(frozen=True)
class AlwaysAvailable(AvailabilityProfile):
    """Every alive device is always dispatchable — the pre-profile
    semantics, and the trivial default."""

    name: str = "always"
    period: float = 86400.0
    duty: float = 1.0


@dataclasses.dataclass(frozen=True)
class DiurnalAvailability(AvailabilityProfile):
    """Diurnal user-traffic wave: device n is idle-and-chargeable for the
    first ``duty`` fraction of its LOCAL day (phones train overnight on
    the charger), offline for the rest.

    ``frac_n(t) = (t/period + tz_phase_n) mod 1``; available while
    ``frac < duty``.  Shares ``tz_phase`` with solar charging — local
    solar time is the timezone."""

    name: str = "diurnal"
    period: float = 86400.0
    duty: float = 0.5

    def _frac(self, xp, tz_phase, sim_time):
        return (sim_time / self.period + tz_phase) % 1.0

    def available(self, fleet, sim_time) -> Array:
        return self._frac(_xp(fleet), fleet.tz_phase, sim_time) < self.duty

    def available_host(self, tz_phase: np.ndarray, now: float) -> np.ndarray:
        return self._frac(np, np.asarray(tz_phase, np.float64),
                          now) < self.duty

    def next_available_host(self, tz_phase: np.ndarray,
                            now: float) -> np.ndarray:
        tz = np.asarray(tz_phase, np.float64)
        frac = self._frac(np, tz, now)
        return np.where(frac < self.duty, now,
                        now + (1.0 - frac) * self.period)


# ---------------------------------------------------------------------------
# registries (the ModelFamily register/get/known idiom)
# ---------------------------------------------------------------------------

_CHARGE_REGISTRY: Dict[str, Type[ChargeProfile]] = {}
_AVAIL_REGISTRY: Dict[str, Type[AvailabilityProfile]] = {}


def register_charge_profile(cls: Type[ChargeProfile],
                            name: Optional[str] = None) -> Type[ChargeProfile]:
    """Register a charge-profile class under ``cls.name`` (or ``name``)."""
    _CHARGE_REGISTRY[name or cls.name] = cls
    return cls


def register_availability_profile(
        cls: Type[AvailabilityProfile],
        name: Optional[str] = None) -> Type[AvailabilityProfile]:
    _AVAIL_REGISTRY[name or cls.name] = cls
    return cls


def known_charge_profiles() -> Tuple[str, ...]:
    return tuple(sorted(_CHARGE_REGISTRY))


def known_availability_profiles() -> Tuple[str, ...]:
    return tuple(sorted(_AVAIL_REGISTRY))


def get_charge_profile(name: str, period: float = 86400.0) -> ChargeProfile:
    try:
        cls = _CHARGE_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown charge profile {name!r} (registered: "
            f"{', '.join(known_charge_profiles())})") from None
    return cls(period=float(period))


def get_availability_profile(name: str, period: float = 86400.0,
                             duty: float = 1.0) -> AvailabilityProfile:
    try:
        cls = _AVAIL_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown availability profile {name!r} (registered: "
            f"{', '.join(known_availability_profiles())})") from None
    return cls(period=float(period), duty=float(duty))


register_charge_profile(ConstantCharge)
register_charge_profile(SolarCharge)
register_charge_profile(CarbonWindowCharge)
register_availability_profile(AlwaysAvailable)
register_availability_profile(DiurnalAvailability)


# ---------------------------------------------------------------------------
# the per-run scenario
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EnergyScenario:
    """One run's resolved energy scenario.

    The three ``trivial_*`` predicates gate EVERY new engine behavior at
    the python level: a trivial piece traces zero extra programs and pulls
    zero extra host syncs, so the default configuration
    (``charge_profile="constant"``, ``charge_rate=0``,
    ``availability_profile="always"``, ``global_budget_j=0``) runs the
    exact same jit programs — and produces the exact same bits — as the
    profile-free engine (tests/test_energy_profiles.py pins this against
    frozen trajectories)."""

    charge: ChargeProfile
    availability: AvailabilityProfile
    charge_rate: float = 0.0            # fleet-mean amplitude, J/s
    global_budget_j: float = 0.0        # 0 = unlimited
    energy_scale: float = 1.0           # recharge cap: battery * scale

    # -- trivial-path predicates ------------------------------------------
    @property
    def trivial_charge(self) -> bool:
        """True when no joule can ever flow back into the fleet."""
        return self.charge_rate == 0.0

    @property
    def trivial_availability(self) -> bool:
        """True when no device is ever gated out by time of day (neither
        an availability wave nor a priced participation window)."""
        return (isinstance(self.availability, AlwaysAvailable)
                and type(self.charge).participation_ok
                is ChargeProfile.participation_ok)

    @property
    def budget_active(self) -> bool:
        return self.global_budget_j > 0.0

    @property
    def is_trivial(self) -> bool:
        return (self.trivial_charge and self.trivial_availability
                and not self.budget_active)

    # -- per-device profile arrays ----------------------------------------
    def init_fleet(self, fleet, seed: int):
        """Seed the per-device profile arrays on a fresh fleet:
        ``tz_phase`` ~ U[0, 1) (longitude / local solar time) and
        ``charge_rate`` ~ amplitude * U[0.7, 1.3] (panel/charger
        heterogeneity).  Draw order is fixed and the stream is private
        (spawned off ``(seed, _PROFILE_RNG_TAG)``), so the same seed gives
        the same devices the same phases across every scenario."""
        xp = _xp(fleet)
        rng = np.random.default_rng((int(seed), _PROFILE_RNG_TAG))
        n = len(fleet)
        tz = rng.uniform(0.0, 1.0, size=n)
        amp = self.charge_rate * rng.uniform(0.7, 1.3, size=n)
        dt = fleet.remaining.dtype
        return fleet.replace(charge_rate=xp.asarray(amp, dt),
                             tz_phase=xp.asarray(tz, dt))

    # -- applying charge over a sim-time interval -------------------------
    def apply_charge(self, fleet, t0: float, t1: float):
        """Integrate the charge profile over ``[t0, t1]`` (midpoint rule —
        exact for constant rates, second-order for the day-scale curves
        against round-scale steps) and top up every ALIVE device, capped
        at its scaled capacity ``battery * energy_scale``.  Dead devices
        stay dead and hold their (zeroed) charge — harvesting does not
        resurrect a drained device, matching ``fleet_charge``'s
        kill-on-overcommit semantics."""
        if t1 <= t0:
            return fleet
        xp = _xp(fleet)
        rate = self.charge.rate(fleet, 0.5 * (t0 + t1))
        cap = fleet.battery * self.energy_scale
        topped = xp.minimum(fleet.remaining + rate * (t1 - t0),
                            xp.maximum(cap, fleet.remaining))
        return fleet.replace(remaining=xp.where(fleet.alive, topped,
                                                fleet.remaining))

    # -- availability masks -----------------------------------------------
    def available(self, fleet, sim_time) -> Optional[Array]:
        """[n] bool device-side participation mask, or None when trivial
        (callers skip the AND entirely — no extra program)."""
        masks = []
        av = self.availability.available(fleet, sim_time)
        if av is not None:
            masks.append(av)
        gate = self.charge.participation_ok(fleet, sim_time)
        if gate is not None:
            masks.append(gate)
        if not masks:
            return None
        out = masks[0]
        for m in masks[1:]:
            out = out & m
        return out

    def available_host(self, tz_phase: np.ndarray,
                       now: float) -> Optional[np.ndarray]:
        """Numpy twin of :meth:`available` over the async engine's host
        ``tz_phase`` mirror — the per-event dispatch mask costs no device
        sync."""
        masks = []
        av = self.availability.available_host(tz_phase, now)
        if av is not None:
            masks.append(av)
        gate = self.charge.ok_host(tz_phase, now)
        if gate is not None:
            masks.append(gate)
        if not masks:
            return None
        out = masks[0]
        for m in masks[1:]:
            out = out & m
        return out

    def next_available_host(self, tz_phase: np.ndarray, now: float) -> float:
        """Earliest sim time > now at which at least one of the given
        devices passes every gate — the async engine's wake-event time
        when availability blocked a whole dispatch.  Conservative under
        stacked gates (takes each device's max next-open; a wake that
        finds the gate shut again just reschedules)."""
        tz = np.asarray(tz_phase, np.float64)
        if tz.size == 0:
            return float(now)
        nxt = np.maximum(self.availability.next_available_host(tz, now),
                         self.charge.next_ok_host(tz, now))
        t = float(nxt.min())
        return t if t > now else float(now) + 1e-6


def scenario_from_config(cfg) -> EnergyScenario:
    """Resolve the :class:`EnergyScenario` a flat config asks for (any
    object with the ``charge_profile``/``availability_profile`` field
    group works — ``FLConfig`` and duck-typed bench configs alike)."""
    period = float(getattr(cfg, "charge_period", 86400.0))
    return EnergyScenario(
        charge=get_charge_profile(
            getattr(cfg, "charge_profile", "constant"), period=period),
        availability=get_availability_profile(
            getattr(cfg, "availability_profile", "always"), period=period,
            duty=float(getattr(cfg, "availability_duty", 1.0))),
        charge_rate=float(getattr(cfg, "charge_rate", 0.0)),
        global_budget_j=float(getattr(cfg, "global_budget_j", 0.0)),
        energy_scale=float(getattr(cfg, "energy_scale", 1.0)))
