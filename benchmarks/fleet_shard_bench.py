"""Sharded-fleet scaling bench: selection + energy step past n=4096.

Measures ONE data-parallel MARL dual-selection + energy step
(:func:`repro.core.selection.dual_selection_energy_step`: obs -> shared
agent Q -> affordability-masked actions -> Top-K cut -> Eq. 5/7 charge ->
factored summary; a single jit program) at n in {4096, 65536, 1M} devices,
single-placement vs row-sharded over a ``jax.sharding`` "fleet" mesh
(:mod:`repro.sharding.fleet`).  This establishes the first scaling row past
n=4096 — the flat QMIX state could not even be INSTANTIATED there
(``state_dim = n * OBS_DIM``; factored ``state_dim`` stays
``summary_width``, asserted here and in ``tests/test_factored_state.py``).

On CPU the mesh is virtual: ``--devices N`` forces
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` BEFORE jax loads
(so this module must be the process entry point, or the flag must already
be in the environment — the shard-smoke CI job sets it).  On a real
multi-chip host the same code shards over the physical devices.

Peak memory is process peak-RSS (``ru_maxrss``; monotonic, so rows run
small -> large and each row reports the running peak) plus the analytic
per-shard fleet bytes.  Results land in ``BENCH_fleet_shard.json``:

    PYTHONPATH=src python -m benchmarks.fleet_shard_bench            # full
    PYTHONPATH=src python -m benchmarks.fleet_shard_bench --smoke    # CI
    PYTHONPATH=src python -m benchmarks.fleet_shard_bench --fig6     # + one
        REPRO_FIG6_SIZES=4096 factored-selector run folded into the JSON
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import statistics
import sys
import time

SIZES_FULL = (4096, 65536, 1_048_576)
SIZES_SMOKE = (4096,)
K_FRACTION = 0.01          # Top-K participation per step
OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_fleet_shard.json")


def _force_host_devices(n: int) -> None:
    """Must run before jax is imported anywhere in this process."""
    if "jax" in sys.modules:
        return                      # too late — use whatever jax has
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _bench_one(n: int, iters: int, sharded: bool, seed: int = 0) -> dict:
    import jax
    import numpy as np

    from repro.core.fleet import (_ARRAY_FIELDS, sample_fleet_state,
                                  summary_width)
    from repro.core.marl.networks import agent_hidden_init, agent_init
    from repro.core.selection import OBS_DIM, dual_selection_energy_step_jit
    from repro.sharding.fleet import (FLEET_AXIS, fleet_mesh,
                                      shard_agent_array, shard_fleet)

    model_sizes = (2.8e6, 8.4e6, 22.5e6, 44.8e6)
    model_fracs = (0.11, 0.3, 0.72, 1.0)
    k = max(1, int(K_FRACTION * n))
    fleet = sample_fleet_state(n, seed=seed, backend="jax")
    params = agent_init(jax.random.PRNGKey(seed), OBS_DIM,
                        len(model_sizes) + 1)
    hidden = agent_hidden_init(n)
    n_shards = 1
    if sharded:
        mesh = fleet_mesh()
        n_shards = mesh.shape[FLEET_AXIS]
        fleet = shard_fleet(fleet, mesh)
        hidden = shard_agent_array(hidden, mesh)

    def step(f, h):
        f, h, part, actions, summ = dual_selection_energy_step_jit(
            params, h, f, model_sizes, model_fracs, k=k, n_rounds=100)
        return f, h, summ

    # compile + warm
    t0 = time.time()
    fleet, hidden, summ = step(fleet, hidden)
    jax.block_until_ready(summ)
    compile_s = time.time() - t0

    times = []
    for _ in range(iters):
        t0 = time.time()
        fleet, hidden, summ = step(fleet, hidden)
        jax.block_until_ready(summ)
        times.append(time.time() - t0)

    fleet_mb = sum(np.asarray(getattr(fleet, f)).nbytes
                   for f in _ARRAY_FIELDS) / 1e6
    return {
        "n": n, "k": k, "mode": "sharded" if sharded else "single",
        "n_shards": n_shards, "iters": iters,
        "step_time_s": round(statistics.median(times), 4),
        "step_time_min_s": round(min(times), 4),
        "compile_s": round(compile_s, 2),
        "peak_rss_mb": round(_peak_rss_mb(), 1),
        "fleet_mb": round(fleet_mb, 2),
        "fleet_mb_per_shard": round(fleet_mb / n_shards, 2),
        "state_dim_factored": summary_width(len(model_sizes)),
        "state_dim_flat_would_be": n * OBS_DIM,
    }


def _run_fig6_row() -> dict:
    """One REPRO_FIG6_SIZES=4096 factored-selector run (the Fig. 6 fix:
    the flat state OOM-scaled here), folded into the bench JSON."""
    from benchmarks import fig6_scalability
    t0 = time.time()
    results = fig6_scalability.main(sizes=(4096,))
    return {
        "sizes": [4096],
        "wall_s": round(time.time() - t0, 1),
        "best_acc_mean": {f"{m}/n{n}": round(a, 4)
                          for (n, m), a in results.items()},
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=4,
                    help="virtual host devices for the fleet mesh")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: n=4096 only, fewer iters")
    ap.add_argument("--sizes", type=int, nargs="*", default=None)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--fig6", action="store_true",
                    help="also run + record a REPRO_FIG6_SIZES=4096 row")
    ap.add_argument("--no-write", action="store_true")
    args = ap.parse_args(argv)

    _force_host_devices(args.devices)
    import jax

    from benchmarks.common import emit

    sizes = tuple(args.sizes) if args.sizes else (
        SIZES_SMOKE if args.smoke else SIZES_FULL)
    out = {
        "bench": "fleet_shard",
        "backend": jax.default_backend(),
        "host_devices": len(jax.devices()),
        "k_fraction": K_FRACTION,
        "rows": [],
    }
    for n in sorted(sizes):
        iters = args.iters or (3 if (args.smoke or n >= 1_000_000) else 5)
        for sharded in (False, True):
            row = _bench_one(n, iters, sharded)
            out["rows"].append(row)
            emit(f"fleet_shard/{row['mode']}/n{n}",
                 row["step_time_s"] * 1e6,
                 f"shards={row['n_shards']} peak_rss_mb={row['peak_rss_mb']}"
                 f" state_dim={row['state_dim_factored']}")
    if args.fig6:
        out["fig6_n4096"] = _run_fig6_row()
        emit("fleet_shard/fig6/n4096", out["fig6_n4096"]["wall_s"] * 1e6,
             f"best_acc={out['fig6_n4096']['best_acc_mean']}")
    if not args.no_write:
        path = os.path.abspath(OUT_JSON)
        existing = {}
        if os.path.exists(path):
            with open(path) as fh:
                existing = json.load(fh)
        if args.smoke and existing.get("rows"):
            # CI smoke must not clobber the recorded full-scale rows; a
            # fig6 row computed this run still lands
            existing["smoke"] = {k: out[k] for k in ("host_devices", "rows")}
            if "fig6_n4096" in out:
                existing["fig6_n4096"] = out["fig6_n4096"]
            out = existing
        else:
            # full runs refresh what they recomputed but keep previously
            # recorded results: rows merge by (n, mode) — a partial
            # --sizes rerun must not erase the expensive 65536/1M rows —
            # and un-recomputed keys (the ~140s fig6 row) carry over
            fresh = {(r["n"], r["mode"]) for r in out["rows"]}
            out["rows"] += [r for r in existing.get("rows", [])
                            if (r["n"], r["mode"]) not in fresh]
            out["rows"].sort(key=lambda r: (r["n"], r["mode"] != "single"))
            for key in ("fig6_n4096", "smoke"):
                if key in existing and key not in out:
                    out[key] = existing[key]
        with open(path, "w") as fh:
            json.dump(out, fh, indent=1)
        print(f"wrote {path}")
    return out


if __name__ == "__main__":
    main()
