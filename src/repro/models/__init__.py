from repro.models.api import Model, build, extra_inputs  # noqa: F401
from repro.models.family import (ModelFamily, get_family,  # noqa: F401
                                 known_families, register_family,
                                 resolve_family)
