"""``python -m repro.analysis`` — jaxlint CLI.

Exit codes: 0 clean (all findings suppressed with reasons), 1 any
unsuppressed finding, 2 usage error.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    from .lint import LintConfig, run_lint, write_json
    from .rules import ALL_RULES
    from .rules import frozen_refs as frozen_refs_rule

    parser = argparse.ArgumentParser(
        prog="jaxlint",
        description="Repo-aware static analysis for the DR-FL JAX stack.")
    parser.add_argument("--root", default=".",
                        help="repo root (default: cwd)")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="write the machine-readable report here")
    parser.add_argument("--rule", action="append", default=None,
                        metavar="RULE", help="run only this rule "
                        "(repeatable; default: all)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print registered rule ids and exit")
    parser.add_argument("--bless-frozen", action="store_true",
                        help="recompute and write the frozen-reference "
                        "hash ledger, then exit")
    parser.add_argument("-v", "--verbose", action="store_true",
                        help="also print suppressed findings")
    args = parser.parse_args(argv)

    if args.list_rules:
        for name in sorted(ALL_RULES):
            print(name)
        return 0

    config = LintConfig(repo_root=args.root, rules=args.rule)

    if args.bless_frozen:
        hashes = frozen_refs_rule.bless(config)
        for tid, h in sorted(hashes.items()):
            print(f"blessed {tid}: {h[:16]}…")
        print(f"wrote {config.frozen_ledger_rel}")
        return 0

    if args.rule:
        unknown = sorted(set(args.rule) - set(ALL_RULES))
        if unknown:
            print(f"jaxlint: unknown rule(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2

    report = run_lint(config)
    print(report.render(verbose=args.verbose))
    if args.json:
        write_json(report, args.json)
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
