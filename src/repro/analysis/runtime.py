"""Runtime compile/execution guards — the dynamic complement to the
static ``retrace-hazard`` rule.

The static rule catches jit-in-a-loop shapes; this module catches the
hazards only visible at runtime (a static arg that churns, a pytree
whose structure varies per call) by asserting on actual compile counts:

* :func:`compile_guard` — context manager that snapshots the compile
  caches of the given jitted functions (via ``_cache_size()``) and/or a
  ``COUNTERS``-style dict (``{"compiles": int, ...}``, e.g.
  ``repro.fl.batch.COUNTERS``) and asserts at exit that no more than
  ``max_new`` new compilations happened inside the block::

      with compile_guard(dual_selection_energy_step_jit, max_new=1):
          for _ in range(20):
              step(...)          # same shapes -> one executable

* :func:`cache_size` — best-effort compile-cache size of one jitted
  function (0 when the wrapper does not expose it).
"""
from __future__ import annotations

import contextlib
from typing import Dict, Iterator, Optional


def cache_size(jitted_fn) -> int:
    """Number of compiled executables cached on a ``jax.jit`` wrapper.

    Best-effort: returns 0 for wrappers that do not expose
    ``_cache_size`` (older jax, non-jit callables) so guards degrade to
    counter-only checks rather than erroring.
    """
    probe = getattr(jitted_fn, "_cache_size", None)
    if callable(probe):
        try:
            return int(probe())
        except Exception:
            return 0
    return 0


@contextlib.contextmanager
def compile_guard(*jitted_fns, counters: Optional[Dict[str, int]] = None,
                  counter_key: str = "compiles",
                  max_new: int = 1) -> Iterator[None]:
    """Assert that at most ``max_new`` NEW compilations happen inside
    the ``with`` block, summed over ``jitted_fns`` cache growth and the
    optional ``counters[counter_key]`` delta.

    Raises ``AssertionError`` naming the offending sources, so a test
    failure reads as "this step retraced", not a bare count mismatch.
    """
    before_caches = [cache_size(f) for f in jitted_fns]
    before_counter = counters.get(counter_key, 0) if counters is not None \
        else 0
    yield
    new = 0
    offenders = []
    for fn, before in zip(jitted_fns, before_caches):
        grown = cache_size(fn) - before
        if grown > 0:
            new += grown
            name = getattr(fn, "__name__", None) or repr(fn)
            offenders.append(f"{name} (+{grown} executable(s))")
    if counters is not None:
        grown = counters.get(counter_key, 0) - before_counter
        if grown > 0:
            new += grown
            offenders.append(f"counters['{counter_key}'] (+{grown})")
    assert new <= max_new, (
        f"compile_guard: {new} new compilation(s) inside the guarded block "
        f"(allowed {max_new}): {', '.join(offenders)} — a static arg or "
        "pytree structure is churning; see docs/ANALYSIS.md#retrace-hazard")
