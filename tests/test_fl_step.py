"""FL-over-pods train step: the jitted masked-gradient path must equal the
explicit per-client layer-aligned aggregation (paper Step 2)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_smoke_config
from repro.core.layerwise import layer_mask
from repro.launch.steps import (build_fl_train_step, build_train_step,
                                chunked_cross_entropy, _unembed)
from repro.models import build
from repro.optim import adamw_init


def test_fl_step_grads_equal_explicit_layerwise_mean():
    cfg = get_smoke_config("phi3-mini-3.8b")
    tcfg = TrainConfig(loss_chunk=8, remat="none", grad_clip=0.0,
                       weight_decay=0.0)
    model, fl_step = build_fl_train_step(cfg, tcfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    n_clients, per = 2, 2
    B, S = n_clients * per, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                cfg.vocab_size)
    m0 = layer_mask(cfg, 0)            # client 0: shallow prefix
    m1 = layer_mask(cfg, 1)            # client 1: full depth
    gates = jnp.stack([m0] * per + [m1] * per, axis=1)   # [L, B]
    counts = m0 + m1                                     # [L]

    # --- FL step gradient (via the jitted masked path) ---------------------
    def fl_loss(p):
        hidden, _ = model.apply(p, tokens, {}, layer_mask=gates, remat="none")
        return chunked_cross_entropy(hidden, _unembed(model, p), labels, 8)

    g_fl = jax.grad(fl_loss)(params)
    scale = n_clients / jnp.maximum(counts, 1.0)
    g_fl = jax.tree.map(
        lambda g: g * scale.reshape((-1,) + (1,) * (g.ndim - 1))
        if g.ndim >= 1 and g.shape[0] == cfg.num_layers else g, g_fl)

    # --- explicit per-client grads + masked mean ----------------------------
    def client_loss(p, sl, m):
        hidden, _ = model.apply(p, tokens[sl], {}, layer_mask=m, remat="none")
        return chunked_cross_entropy(hidden, _unembed(model, p), labels[sl], 8)

    g0 = jax.grad(client_loss)(params, slice(0, per), m0)
    g1 = jax.grad(client_loss)(params, slice(per, None), m1)

    def masked_mean(a, b):
        if a.ndim >= 1 and a.shape[0] == cfg.num_layers:
            num = a + b
            den = counts.reshape((-1,) + (1,) * (a.ndim - 1))
            return num / jnp.maximum(den, 1.0) * jnp.minimum(den, 1.0)
        return (a + b) / 2.0

    g_ref = jax.tree.map(masked_mean, g0, g1)
    for ka, (l_fl, l_ref) in enumerate(zip(jax.tree.leaves(g_fl),
                                           jax.tree.leaves(g_ref))):
        np.testing.assert_allclose(np.asarray(l_fl, np.float32),
                                   np.asarray(l_ref, np.float32),
                                   atol=2e-4, rtol=2e-3,
                                   err_msg=f"leaf {ka}")


def test_fl_step_runs_end_to_end():
    cfg = get_smoke_config("minitron-8b")
    tcfg = TrainConfig(loss_chunk=8, remat="none")
    model, fl_step = build_fl_train_step(cfg, tcfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    state = {"params": params, "opt": adamw_init(params)}
    B, S, L = 4, 16, cfg.num_layers
    m = jnp.stack([layer_mask(cfg, i % 2) for i in range(B)], axis=1)
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "layer_gates": m,
        "layer_counts": m.sum(axis=1) / (B / 2),
        "n_clients": jnp.float32(2.0),
    }
    state, metrics = jax.jit(fl_step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
