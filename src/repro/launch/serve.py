"""Production serving launcher: batched greedy decoding with a persistent
KV cache / recurrent state and simple slot-based continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b --smoke \
        --slots 4 --max-new 16 --requests 10

Requests (random prompts here; a real deployment feeds a queue) are packed
into fixed batch slots; finished slots are refilled without re-compiling —
the serve step is shape-stable in (batch, 1).  On the production mesh this
pairs with the decode-shape dry-run sharding config.

Demo simplification: all slots share one monotone position cursor, so a
refilled slot can still attend to the previous occupant's KV entries.  A
production deployment adds per-slot start offsets to the attention mask
(per-sequence ``kv_len`` is already supported by ``gqa_attend``).
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.steps import build_serve_step
from repro.models import extra_inputs


class SlotServer:
    """Fixed-slot continuous batching over a single jitted decode step."""

    def __init__(self, cfg, slots: int, max_len: int, seed: int = 0):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.model, serve_step = build_serve_step(cfg)
        # jaxlint: allow(retrace-hazard) -- jitted once per server process
        self._step = jax.jit(serve_step, donate_argnums=(1,))
        key = jax.random.PRNGKey(seed)
        self.params = self.model.init(key)
        extras = {k: jax.random.normal(key, shp).astype(dt) for k, (shp, dt)
                  in extra_inputs(cfg, slots, max_len).items()}
        self.cache = self.model.decode_init(self.params, slots, max_len,
                                            extras=extras)
        self.tok = jnp.zeros((slots, 1), jnp.int32)
        self.pos = 0
        self.active: List[Optional[dict]] = [None] * slots

    def submit(self, prompt: np.ndarray, max_new: int) -> Optional[int]:
        """Assign a request to a free slot; returns slot id or None."""
        for s, a in enumerate(self.active):
            if a is None:
                self.active[s] = {"prompt": list(prompt), "fed": 0,
                                  "out": [], "max_new": max_new}
                return s
        return None

    def step(self):
        """One global decode step: teacher-forces pending prompt tokens,
        collects generated tokens for slots past their prompt."""
        tok = np.asarray(self.tok).copy()
        for s, a in enumerate(self.active):
            if a and a["fed"] < len(a["prompt"]):
                tok[s, 0] = a["prompt"][a["fed"]]
                a["fed"] += 1
        next_tok, self.cache = self._step(self.params, self.cache,
                                          jnp.asarray(tok),
                                          jnp.int32(self.pos))
        self.pos += 1
        nt = np.asarray(next_tok)
        done = []
        for s, a in enumerate(self.active):
            if not a:
                continue
            if a["fed"] >= len(a["prompt"]):
                a["out"].append(int(nt[s, 0]))
                if len(a["out"]) >= a["max_new"]:
                    done.append((s, a))
                    self.active[s] = None
        return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    max_len = args.prompt_len + args.max_new + 8
    srv = SlotServer(cfg, args.slots, max_len * 2)
    rng = np.random.default_rng(0)
    pending = [rng.integers(0, cfg.vocab_size, size=args.prompt_len)
               for _ in range(args.requests)]
    completed, t0, steps = 0, time.time(), 0
    while completed < args.requests:
        while pending and srv.submit(pending[0], args.max_new) is not None:
            pending.pop(0)
        for s, a in srv.step():
            completed += 1
            print(f"request done (slot {s}): {a['out']}")
        steps += 1
        if srv.pos >= srv.max_len - 1:
            print("cache exhausted; stopping")
            break
    dt = time.time() - t0
    print(f"served {completed}/{args.requests} requests in {steps} steps, "
          f"{dt:.1f}s ({dt / max(steps, 1) * 1000:.0f} ms/step, "
          f"slots={args.slots})")


if __name__ == "__main__":
    sys.exit(main())
