from repro.checkpoint.io import (  # noqa: F401
    load_pytree, read_payload, save_pytree, latest_step,
)
from repro.checkpoint.engine import (  # noqa: F401
    CheckpointHalt, EngineCheckpointer, config_fingerprint,
    decode_state, encode_state, rng_state, set_rng_state,
)
