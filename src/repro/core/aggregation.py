"""Aggregation operators: FedAvg (Eq. 2) and DR-FL layer-aligned averaging.

Paper Step 2: "layer-align averaging — the same parts of the network will be
aggregated".  A layer of the global model is updated with the data-size-
weighted mean of exactly those client gradients whose submodel contains the
layer; layers no client trained keep the previous global value.

Three deployment forms:
* :func:`layerwise_aggregate` — host/driver-side over a list of client
  updates (the original simulation path, kept as the parity reference).
* the STACKED form — client updates flattened into equal-width segment rows
  ``[N, R, seg]`` with a per-row mask matrix ``[N, R]``
  (:class:`StackTemplate` + :func:`stacked_masked_mean`), dispatched to the
  Pallas ``layer_agg`` kernel as ONE fused pass (interpret mode on CPU).
* :func:`fl_allreduce` — the same op expressed as a masked ``psum`` over the
  ``pod`` mesh axis (multi-pod production mapping; each pod is a client).
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def tree_path_items(tree, _path=()):
    """Yield ``(path, leaf)`` for every leaf of a dict/list/tuple pytree.

    Paths are tuples of dict keys / sequence indices: positional identity,
    not object identity, so aliased leaves (the same array object reachable
    at two paths) keep distinct entries — the property the scatter
    aggregation table relies on."""
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from tree_path_items(v, _path + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from tree_path_items(v, _path + (i,))
    else:
        yield _path, tree


def tree_path_align(ref, other, _path=()):
    """Yield ``(path, other_leaf_or_None)`` for every leaf position of
    ``ref`` — ``None`` where ``other`` (a possibly depth-truncated /
    structure-poorer tree, e.g. a ScaleFL client delta) has no entry."""
    if isinstance(ref, dict):
        for k, v in ref.items():
            o = other[k] if (other is not None and k in other) else None
            yield from tree_path_align(v, o, _path + (k,))
    elif isinstance(ref, (list, tuple)):
        for i, v in enumerate(ref):
            o = (other[i] if (other is not None and i < len(other))
                 else None)
            yield from tree_path_align(v, o, _path + (i,))
    else:
        yield _path, other


#: default per-element magnitude ceiling for client deltas — far above any
#: legitimate local-SGD delta, so only corrupted/diverged payloads trip it
DELTA_MAG_CAP = 1e8


def delta_valid(delta, mag_cap: float = DELTA_MAG_CAP):
    """Device-side scalar bool: every leaf of ``delta`` is finite and within
    ``mag_cap`` in magnitude.  The per-client gate of the quarantine layer
    (graceful degradation: a poisoned update must never reach the global
    params)."""
    ok = jnp.asarray(True)
    for leaf in jax.tree.leaves(delta):
        fin = jnp.isfinite(leaf)
        ok = ok & fin.all()
        safe = jnp.where(fin, leaf, 0)
        ok = ok & (jnp.max(jnp.abs(safe), initial=0.0) <= mag_cap)
    return ok


def sanitize_delta(delta):
    """Zero every non-finite element.  Quarantine zeroes a bad client's
    MASK, but 0 * nan = nan, so the numerator needs finite operands; for
    all-finite deltas ``where`` is an exact element copy (bit-for-bit)."""
    return jax.tree.map(
        lambda u: jnp.where(jnp.isfinite(u), u, jnp.zeros_like(u)), delta)


def stacked_rows_valid(U, mag_cap: float = DELTA_MAG_CAP):
    """[N] bool from stacked client rows [N, R, seg]: finite everywhere and
    within ``mag_cap`` — vectorized :func:`delta_valid` for the stacked
    aggregation path."""
    fin = jnp.isfinite(U)
    safe = jnp.where(fin, U, 0.0)
    return (fin.all(axis=(1, 2))
            & (jnp.max(jnp.abs(safe), axis=(1, 2)) <= mag_cap))


def fedavg(updates: Sequence, weights: Optional[Sequence[float]] = None):
    """Plain FedAvg over pytrees (Eq. 2). ``weights`` ~ client data sizes."""
    n = len(updates)
    if weights is None:
        w = [1.0 / n] * n
    else:
        tot = float(sum(weights))
        w = [float(x) / tot for x in weights]
    return jax.tree.map(
        lambda *xs: sum(wi * x.astype(jnp.float32) for wi, x in zip(w, xs)
                        ).astype(xs[0].dtype),
        *updates)


def layerwise_aggregate(global_params, client_updates: List, client_masks: List,
                        weights: Optional[Sequence[float]] = None,
                        server_lr: float = 1.0):
    """DR-FL layer-aligned aggregation.

    global_params : pytree W_t
    client_updates: list of pytrees (client gradient/delta, SAME structure —
                    clients zero-fill layers they did not train)
    client_masks  : list of pytrees of 0/1 masks (from
                    :func:`repro.core.layerwise.stacked_update_mask`),
                    broadcastable leaf-wise against the updates
    weights       : client data sizes L_n (paper Eq. 2)

    Returns W_{t+1} = W_t + server_lr * masked weighted mean of updates.
    """
    n = len(client_updates)
    if weights is None:
        weights = [1.0] * n
    w = [float(x) for x in weights]

    def agg(gp, *leaves):
        ups = leaves[:n]
        msks = leaves[n:]
        num = sum(wi * m.astype(jnp.float32) * u.astype(jnp.float32)
                  for wi, u, m in zip(w, ups, msks))
        den = sum(wi * m.astype(jnp.float32) for wi, m in zip(w, msks))
        den = jnp.broadcast_to(den, num.shape) if hasattr(den, "shape") else den
        avg = jnp.where(den > 0, num / jnp.maximum(den, 1e-12), 0.0)
        return (gp.astype(jnp.float32) + server_lr * avg).astype(gp.dtype)

    return jax.tree.map(agg, global_params, *client_updates, *client_masks)


# ---------------------------------------------------------------------------
# stacked [N, R, seg] representation (feeds the Pallas layer_agg kernel)
# ---------------------------------------------------------------------------
#
# The kernel wants a uniform [N, L, D]; the CNN's layer groups span ~3 orders
# of magnitude in size, so a naive stack to [N, n_groups, max_group] wastes
# ~7x memory on padding.  Instead each group is padded to a multiple of a
# fixed segment width ``seg`` and laid out as consecutive ROWS of one
# [N, R, seg] array: the mask value is constant within a group, so every row
# of a group carries its group's mask entry and the kernel's per-layer
# masked mean is exact.  Padding waste is < n_groups * seg elements total.


class StackTemplate(NamedTuple):
    """Row layout of one model's parameters, grouped by aggregation unit."""
    seg: int                               # segment (row) width
    n_rows: int                            # R: total rows
    group_sizes: Tuple[int, ...]           # flat element count per group
    group_rows: Tuple[Tuple[int, int], ...]  # (row_start, row_stop) per group


def build_stack_template(group_trees: Sequence, seg: int = 1024
                         ) -> StackTemplate:
    sizes, rows, r = [], [], 0
    for tree in group_trees:
        n = int(sum(l.size for l in jax.tree.leaves(tree)))
        nr = max(1, -(-n // seg))
        sizes.append(n)
        rows.append((r, r + nr))
        r += nr
    return StackTemplate(seg=int(seg), n_rows=r, group_sizes=tuple(sizes),
                         group_rows=tuple(rows))


def _flat_group(tree, lead_axes: int = 0):
    """Concat a group's leaves into one flat vector (or [P, flat])."""
    leaves = jax.tree.leaves(tree)
    if lead_axes:
        return jnp.concatenate(
            [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves],
            axis=1)
    return jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32) for l in leaves])


def stack_group_rows(group_trees: Sequence, template: StackTemplate,
                     held, stacked: bool = False):
    """Flatten held groups into segment rows.

    group_trees: one entry per HELD group, in global group order (entries
                 for unheld groups are skipped via ``held``);
    held:        boolean per global group;
    stacked:     leaves carry a leading participant axis [P, ...].

    Returns [R, seg] (or [P, R, seg]) float32 with zeros outside held groups.
    """
    it = iter(group_trees)
    parts = []
    lead = None
    for g, is_held in enumerate(held):
        r0, r1 = template.group_rows[g]
        nr, size = r1 - r0, template.group_sizes[g]
        if not is_held:
            parts.append(("zeros", nr))
            continue
        flat = _flat_group(next(it), lead_axes=1 if stacked else 0)
        pad = nr * template.seg - size
        if stacked:
            lead = flat.shape[0]
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
            parts.append(("rows", flat.reshape(lead, nr, template.seg)))
        else:
            flat = jnp.pad(flat, (0, pad))
            parts.append(("rows", flat.reshape(nr, template.seg)))
    out = []
    for kind, v in parts:
        if kind == "rows":
            out.append(v)
        elif stacked:
            out.append(jnp.zeros((lead, v, template.seg), jnp.float32))
        else:
            out.append(jnp.zeros((v, template.seg), jnp.float32))
    return jnp.concatenate(out, axis=1 if stacked else 0)


def group_row_mask(held, template: StackTemplate) -> jnp.ndarray:
    """Expand a per-group 0/1 vector to the per-row mask [R]."""
    m = jnp.zeros((template.n_rows,), jnp.float32)
    for g, is_held in enumerate(held):
        if is_held:
            r0, r1 = template.group_rows[g]
            m = m.at[r0:r1].set(1.0)
    return m


def stacked_masked_mean(U, mask01, weights, alphas=None, *, interpret=None,
                        use_kernel: Optional[bool] = None):
    """Masked weighted mean over clients on the stacked representation.

    U: [N, R, seg]; mask01: [N, R] 0/1 hold masks; weights: [N];
    alphas: optional [N] per-client staleness scales applied to the
    NUMERATOR only (FedAsync absolute damping) — the denominator keeps the
    0/1 hold mask, recovered from the kernel's single-mask contract by
    rescaling each row with (sum w*alpha*m) / (sum w*m).  ``alphas=None``
    skips the rescale entirely, so the fresh path is bit-for-bit the plain
    kernel output.  Returns [R, seg] float32.

    Dispatch: the Pallas ``layer_agg`` kernel on TPU (one fused VMEM pass
    per block), and the identical-math fused XLA einsum elsewhere —
    interpret-mode Pallas walks the R-row grid in a simulated loop, which
    is a testing tool, not a CPU execution path.  ``use_kernel=True``
    forces the kernel (tests pair it with ``interpret=True``).
    """
    from repro.kernels.layer_agg import layer_agg_op

    w = jnp.asarray(weights, jnp.float32)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    op = (lambda u, m, ww: layer_agg_op(u, m, ww, interpret=interpret)) \
        if use_kernel else _stacked_mean_ref
    if alphas is None:
        return op(U, mask01, w)
    a = jnp.asarray(alphas, jnp.float32)
    m_alpha = mask01 * a[:, None]
    out = op(U, m_alpha, w)
    den01 = (w[:, None] * mask01).sum(axis=0)
    den_a = (w[:, None] * m_alpha).sum(axis=0)
    ratio = jnp.where(den01 > 0, den_a / jnp.maximum(den01, 1e-12), 0.0)
    return out * ratio[:, None]


@jax.jit
def _stacked_mean_ref(U, mask, w):
    from repro.kernels.layer_agg import layer_agg_ref
    return layer_agg_ref(U, mask, w)


def unstack_apply(global_group_trees: Sequence, rows, template: StackTemplate,
                  server_lr: float = 1.0):
    """Apply averaged delta rows [R, seg] back onto the global group trees.

    Returns the list of updated group trees (same structures/dtypes);
    mirrors :func:`layerwise_aggregate`'s ``gp + server_lr * avg`` leaf op.
    """
    out = []
    for g, tree in enumerate(global_group_trees):
        r0, r1 = template.group_rows[g]
        flat = rows[r0:r1].reshape(-1)[:template.group_sizes[g]]
        leaves, treedef = jax.tree.flatten(tree)
        new_leaves, off = [], 0
        for l in leaves:
            d = flat[off:off + l.size].reshape(l.shape)
            new_leaves.append(
                (l.astype(jnp.float32) + server_lr * d).astype(l.dtype))
            off += l.size
        out.append(jax.tree.unflatten(treedef, new_leaves))
    return out


def fl_allreduce(update, mask, weight, axis_name: str = "pod"):
    """Masked layer-aligned aggregation as a collective (inside shard_map).

    Each pod contributes ``update`` (zero outside its submodel), ``mask``
    (its update mask) and scalar ``weight`` (data size).  Returns the
    aggregated delta every pod applies to its replica of the global model —
    DR-FL Step 2 as a single psum pair over the pod axis.
    """
    def one(u, m):
        num = jax.lax.psum(weight * m * u.astype(jnp.float32), axis_name)
        den = jax.lax.psum(weight * m, axis_name)
        return jnp.where(den > 0, num / jnp.maximum(den, 1e-12), 0.0).astype(u.dtype)

    return jax.tree.map(one, update, mask)
