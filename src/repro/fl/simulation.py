"""DR-FL federated simulation (paper §4.2 workflow, Steps 1–5).

One ``run_simulation`` call reproduces one cell of the paper's experiments:
a fleet of heterogeneous battery-powered devices trains a shared layer-wise
global model under an energy budget, with the configured dual-selection
strategy.  Returns a full history for the benchmark harnesses (accuracy per
exit per round, remaining energy, running time, fleet survival).

Rounds are scheduled by the event-driven :class:`repro.fl.engine.RoundEngine`:

* ``engine_mode="sync"`` (default) — classic barrier rounds, bit-for-bit
  identical to the frozen reference loop kept below
  (:func:`_run_once_reference`, the parity contract enforced by
  ``tests/test_engine.py``);
* ``engine_mode="async"`` — dispatch and completion are separate timeline
  events over per-device virtual clocks; late updates are aggregated with
  FedAsync-style staleness decay.  The default for Fig. 6 scalability runs.

Method arms:
    method="drfl"      selector in {marl, greedy, random, static}
    method="heterofl"  (greedy energy-aware model choice per the paper's
                        fair-comparison adaptation)
    method="scalefl"   (same greedy adaptation)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.fleet import (fleet_charge_jit, fleet_connect,
                              fleet_cost_matrix_jit, fleet_total_remaining)
from repro.core.selection import (GreedySelector, MarlSelector, RandomSelector,
                                  SelectorBase, StaticTierSelector)
from repro.fl import client as fl_client
from repro.fl import server as fl_server
from repro.fl.engine import RoundEngine, build_world, sync_task_budget
from repro.models.family import get_family


@dataclasses.dataclass
class FLConfig:
    n_devices: int = 40
    n_rounds: int = 30
    participation: float = 0.10         # paper: 10% per round
    local_epochs: int = 5               # paper §5
    batch_size: int = 32                # paper §5
    lr: float = 0.05                    # paper §5
    alpha: float = 0.5                  # Dirichlet non-IID
    num_classes: int = 10
    n_train: int = 4000
    n_val_fraction: float = 0.04        # paper Table 2 optimum
    noise: float = 1.0
    hw: int = 16                        # image size (CPU budget: 16x16)
    width_mult: float = 0.25            # CNN slimming for CPU-budget runs
    seed: int = 0
    model_family: str = "cnn"           # registered ModelFamily (see
                                        # repro.models.family / fl/spec.py)
    method: str = "drfl"                # drfl | heterofl | scalefl
    selector: str = "marl"              # marl | greedy | random | static
    reward_weights: tuple = (1000.0, 0.01, 1.0)
    marl_train_every: int = 2
    marl_updates_per_round: int = 2
    marl_episodes: int = 1              # selector pre-training episodes (the
                                        # reported run is the LAST episode)
    hotplug_round: int = 0              # paper §4.2: hot-plug devices join at
    hotplug_n: int = 0                  # this round with fresh batteries
    energy_scale: float = 1.0           # scales battery to stress budgets
    # --- energy scenarios (repro.energy; docs/ENERGY.md) -------------------
    # pluggable harvesting/availability profiles + a fleet-wide joule
    # budget; the defaults below are the trivial scenario, bit-for-bit
    # identical to profile-free runs
    charge_profile: str = "constant"    # constant | solar | carbon_window
    charge_rate: float = 0.0            # fleet-mean harvest amplitude, J/s
    charge_period: float = 86400.0      # profile day length, sim-seconds
    availability_profile: str = "always"  # always | diurnal
    availability_duty: float = 1.0      # fraction of the local day online
    global_budget_j: float = 0.0        # fleet-wide joule budget (0 = off)
    server_lr: float = 0.7              # damps layer-aligned update drift
    # --- event-driven round engine (repro.fl.engine) -----------------------
    engine_mode: str = "sync"           # sync | async
    staleness_decay: float = 0.5        # FedAsync (1+s)^-decay down-weighting
    async_eval_every: int = 1           # evaluate every N async aggregations
    async_time_horizon: float = 0.0     # sim-seconds budget (0 = task budget)
    async_task_budget: int = 0          # client tasks (0 = sync-equivalent)
    # --- client-update executor (repro.fl.batch) ---------------------------
    # "auto" buckets participants by submodel index and runs each bucket as
    # ONE vmap(scan) jit program at 64+ device fleets (<= 4 dispatches per
    # sync round); "perclient" keeps the bit-for-bit legacy per-client loop
    client_executor: str = "auto"       # auto | perclient | batched
    # --- scaled MARL state + fleet sharding --------------------------------
    # QMIX mixer global state: "flat" = the n_devices*OBS_DIM concatenation
    # (bit-for-bit legacy), "factored" = the fixed-width fleet summary whose
    # state_dim is independent of fleet size; "auto" keeps flat up to
    # repro.core.selection.FACTORED_AUTO_N (256) devices, factors above
    state_mode: str = "auto"            # auto | flat | factored
    # QMIX mixer: "flat" = per-agent hypernet (bit-for-bit legacy, O(n)
    # params + replay), "set" = permutation-invariant set/attention mixer
    # over sampled-agent replay (n-free training cost); "auto" keeps flat
    # up to FACTORED_AUTO_N (256) devices like state_mode
    mixer_mode: str = "auto"            # auto | flat | set
    # sampled-agent budget under the set mixer: episode traces and replay
    # minibatches keep at most this many agents (uniform per episode)
    marl_agent_budget: int = 4096
    # shard FleetState's [n] arrays over a jax.sharding "fleet" mesh of this
    # many local devices (0/1 = off, -1 = all local devices); selection +
    # energy kernels then run data-parallel (repro.sharding.fleet)
    fleet_mesh: int = 0
    # --- crash safety: checkpoint/resume + fault injection -----------------
    # (repro.checkpoint.engine + repro.fl.faults; docs/RESILIENCE.md)
    checkpoint_dir: str = ""            # empty = checkpointing off
    checkpoint_every: int = 0           # save every N (virtual) rounds
    checkpoint_keep: int = 3            # manifests kept (older ones rotate)
    resume: bool = False                # resume from latest manifest in dir
    fault_crashes: int = 0              # seeded churn counts (async only)
    fault_timeouts: int = 0
    fault_disconnects: int = 0
    fault_corrupts: int = 0
    fault_horizon: float = 0.0          # event window (0 = async horizon)
    fault_seed: int = -1                # -1 = reuse cfg.seed
    # in-flight tasks are declared lost (and their slot reclaimed) at
    # dispatch + factor * t_cost; only active when faults are injected
    task_deadline_factor: float = 4.0


def _make_selector(cfg: FLConfig, n_models: int) -> SelectorBase:
    if cfg.method in ("heterofl", "scalefl"):
        return GreedySelector()          # the paper's fair-comparison arm
    return {
        "marl": lambda: MarlSelector(
            cfg.n_devices + cfg.hotplug_n, n_models, cfg.n_rounds, cfg.seed,
            state_mode=getattr(cfg, "state_mode", "auto"),
            mixer_mode=getattr(cfg, "mixer_mode", "auto"),
            agent_budget=getattr(cfg, "marl_agent_budget", 4096)),
        "greedy": lambda: GreedySelector(),
        "random": lambda: RandomSelector(cfg.seed),
        "static": lambda: StaticTierSelector(cfg.seed),
    }[cfg.selector]()


# replay-buffer obs storage budget (float32 elements).  Episode obs are
# inherently [T+1, n, OBS_DIM], so at 4096+ devices a fixed 64-episode
# capacity is multi-GB before the first round runs — the "flat QMIX state
# OOM-scales" half of the Fig. 6 failure.  Capacity degrades gracefully
# instead (64 episodes at paper scale, >= 4 always).
_BUFFER_OBS_ELEMS = 2 ** 24


def _make_buffer(cfg: FLConfig):
    import logging

    from repro.core.marl.buffer import ReplayBuffer
    from repro.core.selection import OBS_DIM, marl_state_dim, resolve_mixer_mode
    from repro.models.family import get_family
    n_agents = cfg.n_devices + cfg.hotplug_n
    if cfg.engine_mode == "async":
        # one episode step per selector.select call: at most one per task
        # plus one failed-dispatch probe per completion/boundary event —
        # sized from the budget the engine will ACTUALLY dispatch
        budget = int(cfg.async_task_budget or sync_task_budget(cfg))
        episode_len = 2 * budget + cfg.n_rounds + 8
    else:
        episode_len = cfg.n_rounds
    state_dim = marl_state_dim(
        getattr(cfg, "state_mode", "auto"), n_agents,
        get_family(cfg.model_family).num_submodels())
    mixer_mode = resolve_mixer_mode(getattr(cfg, "mixer_mode", "auto"),
                                    n_agents)
    agent_budget = (int(getattr(cfg, "marl_agent_budget", 4096))
                    if mixer_mode == "set" else None)
    stored_agents = (min(n_agents, agent_budget) if agent_budget
                     else n_agents)
    capacity = max(4, min(64, _BUFFER_OBS_ELEMS
                          // ((episode_len + 1) * stored_agents * OBS_DIM)))
    if capacity < 64:
        # loud, once per buffer: fig5/table1 runs at scale must be able to
        # report their EFFECTIVE replay size (also recorded per-update in
        # hist["qmix"] by the engine)
        logging.getLogger(__name__).warning(
            "QMIX replay capacity degraded to %d episodes (episode_len=%d, "
            "stored agents=%d of %d, obs budget=%d elems); consider "
            "mixer_mode='set' / a smaller marl_agent_budget",
            capacity, episode_len, stored_agents, n_agents,
            _BUFFER_OBS_ELEMS)
    return ReplayBuffer(capacity, episode_len, n_agents, OBS_DIM,
                        state_dim, cfg.seed, agent_budget=agent_budget)


def run_simulation(cfg, verbose: bool = False,
                   halt_after_saves: int = 0) -> Dict:
    """Runs the FL simulation.  ``cfg`` is an :class:`FLConfig` (the stable
    flat compatibility surface) or a typed :class:`repro.fl.spec.
    SimulationSpec`; both are validated up front, so a typo like
    ``selector="mral"`` or ``engine_mode="asynch"`` raises here instead of
    deep inside a run.  With ``marl_episodes > 1`` and the MARL selector,
    earlier episodes pre-train the QMIX policy (fresh fleet + global model
    each episode, persistent learner + replay buffer) and the LAST episode
    is reported — the CPU-scale analogue of the paper's long online
    runs.

    Crash safety: with ``cfg.checkpoint_dir`` + ``cfg.checkpoint_every``
    set, the engine snapshots its FULL run state on that cadence; with
    ``cfg.resume=True`` the latest manifest in the directory is loaded
    (after a config-fingerprint check) and the run continues — histories
    and final params are byte-identical to an uninterrupted run.
    ``halt_after_saves=N`` (> 0, test/bench hook) simulates a crash by
    raising :class:`repro.checkpoint.engine.CheckpointHalt` right after
    the N-th checkpoint save of this call."""
    from repro.fl.spec import ensure_flat_config
    cfg = ensure_flat_config(cfg)
    resume_state = resume_meta = None
    if cfg.resume:
        from repro.checkpoint.engine import (EngineCheckpointer,
                                             config_fingerprint)
        if not cfg.checkpoint_dir:
            raise ValueError("resume=True needs checkpoint_dir")
        ck = EngineCheckpointer(cfg.checkpoint_dir,
                                keep=cfg.checkpoint_keep)
        latest = ck.latest()
        if latest is not None:
            resume_state, resume_meta = ck.load(latest)
            fp = config_fingerprint(cfg)
            got = resume_meta.get("fingerprint")
            if got != fp:
                raise ValueError(
                    f"checkpoint fingerprint {got!r} does not match this "
                    f"config ({fp!r}); refusing to resume a different run")
    halt = ({"remaining": int(halt_after_saves)} if halt_after_saves > 0
            else None)
    start_ep = int(resume_meta["episode"]) if resume_meta else 0
    selector = None
    buffer = None
    episodes = cfg.marl_episodes if (cfg.method == "drfl"
                                     and cfg.selector == "marl") else 1
    for ep in range(episodes):
        if ep < start_ep:
            # fully covered by the checkpoint: the restored selector +
            # buffer state already contain these episodes' training
            continue
        if selector is None:
            selector = _make_selector(
                cfg, get_family(cfg.model_family).num_submodels())
        marl = selector if isinstance(selector, MarlSelector) else None
        resuming = resume_state is not None and ep == start_ep
        if marl:
            if buffer is None:
                buffer = _make_buffer(cfg)
            if not resuming:
                # the resumed episode's trace/hidden/RNG state comes from
                # the checkpoint — resetting would fork the episode
                marl.reset_episode()
        engine = RoundEngine(cfg, selector, buffer,
                             verbose=verbose and ep == episodes - 1,
                             episode=ep,
                             resume_state=resume_state if resuming else None,
                             halt_counter=halt)
        hist = engine.run()
        resume_state = None              # consumed by its episode
    return hist


# ---------------------------------------------------------------------------
# frozen synchronous reference loop
# ---------------------------------------------------------------------------
#
# This is the pre-engine round loop, kept VERBATIM (modulo the shared
# build_world setup, the collision-free client seeds, and the family=
# routing that keeps it runnable on any registered model family) as the
# parity contract for RoundEngine's sync mode — the same role the scalar
# DeviceState path in repro.core.energy plays for the vectorized FleetState
# kernels.  tests/test_engine.py asserts engine sync histories match this
# bit-for-bit; do not "improve" it.


def _run_once_reference(cfg: FLConfig, verbose=False, selector=None,
                        buffer=None):
    w = build_world(cfg)
    fleet = w.fleet
    global_params = w.global_params
    M = w.n_models
    x_tr, y_tr, x_val, y_val, parts = w.x_tr, w.y_tr, w.x_val, w.y_val, w.parts
    sizes, fractions = w.sizes, w.fractions
    n_total = w.n_total
    if selector is None:
        selector = _make_selector(cfg, M)
    hist_hotplug_done = False

    marl = selector if isinstance(selector, MarlSelector) else None
    if marl:
        if buffer is None:
            from repro.core.marl.buffer import ReplayBuffer
            from repro.core.selection import OBS_DIM
            # state rows must match what THIS selector's episode_arrays
            # emits — its learner already resolved the state mode (flat
            # keeps the legacy n*OBS_DIM width bit-for-bit)
            buffer = ReplayBuffer(64, cfg.n_rounds, cfg.n_devices, OBS_DIM,
                                  marl.learner.cfg.state_dim, cfg.seed)
        marl.reset_episode()

    hist = {"acc": [], "acc_mean": [], "energy": [], "round_time": [],
            "alive": [], "participants": [], "model_choices": [],
            "reward": [], "wall_clock": [], "dropouts": 0}
    prev_acc = float(np.mean(fl_server.evaluate(global_params, x_val, y_val,
                                                family=w.family)))
    e_prev = fleet_total_remaining(fleet)
    w1, w2, w3 = cfg.reward_weights
    rows = np.arange(n_total)

    for t in range(cfg.n_rounds):
        t0 = time.time()
        if (cfg.hotplug_n and not hist_hotplug_done
                and t >= cfg.hotplug_round):
            fleet = fleet_connect(fleet, cfg.n_devices, cfg.energy_scale)
            hist_hotplug_done = True
        n_connected = cfg.n_devices + (cfg.hotplug_n if hist_hotplug_done
                                       else 0)
        k = max(1, int(round(cfg.participation * n_connected)))
        sel = selector.select(fleet, t, k, sizes, fractions,
                              cfg.local_epochs, cfg.batch_size)

        choice = np.asarray(sel.model_choice, np.int64)
        active = choice >= 0
        m_idx = np.clip(choice, 0, M - 1)
        t_tra_m, t_com_m, e_tra_m, e_com_m = fleet_cost_matrix_jit(
            fleet, sizes, fractions, cfg.local_epochs, cfg.batch_size)
        need = np.asarray(e_tra_m + e_com_m)[rows, m_idx]
        t_cost = np.asarray(t_tra_m + t_com_m)[rows, m_idx]
        fleet, ok = fleet_charge_jit(fleet, jnp.asarray(need),
                                     jnp.asarray(active))
        ok = np.asarray(ok)
        hist["dropouts"] += int((active & ~ok).sum())
        survivors = active & ok
        t_round = float(t_cost[survivors].max()) if survivors.any() else 0.0

        deltas, idxs, weights = [], [], []
        for i in sel.participants:
            if not survivors[i]:
                continue                     # wasted energy, no contribution
            m = int(choice[i])
            xi = x_tr[parts[i]]
            yi = y_tr[parts[i]]
            if len(xi) == 0:
                continue
            upd_seed = fl_client.client_update_seed(cfg.seed, t, i)
            if cfg.method == "drfl":
                d_, _ = fl_client.drfl_client_update(
                    global_params, m, xi, yi, epochs=cfg.local_epochs,
                    batch=cfg.batch_size, lr=cfg.lr, seed=upd_seed,
                    family=w.family)
            elif cfg.method == "heterofl":
                d_, _ = fl_client.heterofl_client_update(
                    global_params, m, xi, yi, epochs=cfg.local_epochs,
                    batch=cfg.batch_size, lr=cfg.lr, seed=upd_seed,
                    family=w.family)
            else:
                d_, _ = fl_client.scalefl_client_update(
                    global_params, m, xi, yi, epochs=cfg.local_epochs,
                    batch=cfg.batch_size, lr=cfg.lr, seed=upd_seed,
                    family=w.family)
            deltas.append(d_)
            idxs.append(m)
            weights.append(float(len(xi)))

        if deltas:
            if cfg.method == "drfl":
                global_params = fl_server.aggregate_drfl(
                    global_params, deltas, idxs, weights,
                    server_lr=cfg.server_lr, family=w.family)
            else:
                global_params = fl_server.aggregate_sliced(
                    global_params, deltas, weights)

        accs = fl_server.evaluate(global_params, x_val, y_val,
                                  family=w.family)
        acc = float(np.mean(accs))
        e_now = fleet_total_remaining(fleet)
        reward = (w1 * (acc - prev_acc) - w2 * (e_prev - e_now)
                  - w3 * (t_round / 60.0))
        selector.observe_reward(reward)
        prev_acc, e_prev = acc, e_now

        if marl:
            if (t + 1) % cfg.marl_train_every == 0 and marl.ep_rewards:
                obs, state, actions, rewards = marl.episode_arrays(fleet, t + 1)
                buffer.add_episode(obs, state, actions, rewards)
                for _ in range(cfg.marl_updates_per_round):
                    batch = buffer.sample(marl.learner.cfg.batch_size)
                    if batch:
                        marl.learner.update(batch)

        alive_now = int(np.asarray(fleet.alive).sum())
        hist["acc"].append(np.asarray(accs))
        hist["acc_mean"].append(acc)
        hist["energy"].append(e_now)
        hist["round_time"].append(t_round)
        hist["alive"].append(alive_now)
        hist["participants"].append(list(sel.participants))
        hist["model_choices"].append([sel.model_choice[i] for i in sel.participants])
        hist["reward"].append(reward)
        hist["wall_clock"].append(time.time() - t0)
        if verbose:
            print(f"  round {t:3d}: acc={acc:.3f} exits="
                  f"{np.round(np.asarray(accs), 3)} alive={alive_now}"
                  f" energy={e_now:,.0f}J time={t_round:.1f}s r={reward:+.2f}")
        if alive_now == 0:
            break

    hist["final_acc"] = hist["acc"][-1] if hist["acc"] else np.zeros(4)
    hist["best_acc"] = (np.max(np.stack(hist["acc"]), axis=0)
                        if hist["acc"] else np.zeros(4))
    hist["params"] = global_params
    return hist, selector, buffer
