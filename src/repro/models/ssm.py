"""Mamba2 (SSD) block — chunkwise-parallel training scan, O(1)-state decode.

State-space recurrence per head h (head dim P, state dim N, ngroups=1):
    S_t = a_t * S_{t-1} + dt_t * B_t x_t^T          (S ∈ R^{N×P})
    y_t = C_t^T S_t + D * x_t
with a_t = exp(-softplus(dt_raw)*exp(A_log)) ∈ (0,1).

The chunkwise algorithm evaluates within-chunk interactions as a masked
quadratic form (chunk length ``cfg.ssm_chunk``) and carries the inter-chunk
state through a `lax.scan` — linear in sequence length.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L

CONV_K = 4  # causal depthwise conv kernel width


def mamba_dims(cfg):
    inner = cfg.ssm_expand * cfg.d_model
    P = 64 if inner % 64 == 0 else inner // max(1, cfg.num_heads)
    H = inner // P
    N = cfg.ssm_state
    return inner, H, P, N


def mamba_init(key, cfg, dtype):
    d = cfg.d_model
    inner, H, P, N = mamba_dims(cfg)
    conv_dim = inner + 2 * N
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "norm": L.rmsnorm_init(d, dtype),
        "w_in": L._normal(ks[0], (d, 2 * inner + 2 * N + H), s, dtype),
        "conv_w": L._normal(ks[1], (conv_dim, CONV_K), 0.5, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),   # softplus(-2) ≈ 0.13
        "D": jnp.ones((H,), jnp.float32),
        "out_norm": L.rmsnorm_init(inner, dtype),
        "w_out": L._normal(ks[2], (inner, d), 1.0 / math.sqrt(inner), dtype),
    }


def _causal_conv(x, w, b):
    """x: [B,S,C]; depthwise causal conv, kernel CONV_K."""
    pad = jnp.pad(x, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[:, i] for i in range(CONV_K))
    return jax.nn.silu(out + b)


def _split_in(p, cfg, x):
    inner, H, P, N = mamba_dims(cfg)
    h = L.rmsnorm_apply(p["norm"], x, cfg.norm_eps)
    zxbcdt = h @ p["w_in"]
    z = zxbcdt[..., :inner]
    xbc = zxbcdt[..., inner:inner + inner + 2 * N]
    dt_raw = zxbcdt[..., -H:].astype(jnp.float32)
    return z, xbc, dt_raw, (inner, H, P, N)


def _gates(p, dt_raw):
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])              # [B,S,H]
    log_a = -dt * jnp.exp(p["A_log"])                        # [B,S,H] <= 0
    return dt, log_a


def _ssd_chunk_scan(xh, Bm, Cm, dt, log_a, D, chunk, state=None):
    """xh: [B,S,H,P]; Bm/Cm: [B,S,N]; dt/log_a: [B,S,H].

    Returns y [B,S,H,P], final state [B,H,N,P].
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nC = S // Q

    def ck(t):  # [B,S,...] -> [nC,B,Q,...]
        return jnp.moveaxis(t.reshape(B, nC, Q) if t.ndim == 2
                            else t.reshape((B, nC, Q) + t.shape[2:]), 1, 0)

    xs = (ck(xh.astype(jnp.float32)), ck(Bm.astype(jnp.float32)),
          ck(Cm.astype(jnp.float32)), ck(dt), ck(log_a))
    S0 = jnp.zeros((B, H, N, P), jnp.float32) if state is None else state

    def body(Sst, xs_c):
        xc, Bc, Cc, dtc, lac = xs_c                          # [B,Q,...]
        b = jnp.cumsum(lac, axis=1)                          # [B,Q,H]
        total = b[:, -1]                                     # [B,H]
        # intra-chunk: scores[b,h,i,j] = (C_i . B_j) exp(b_i - b_j) dt_j, j<=i
        cb = jnp.einsum("bin,bjn->bij", Cc, Bc)              # [B,Q,Q]
        dec = b[:, :, None, :] - b[:, None, :, :]            # [B,Q,Q,H] (i,j)
        tri = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
        w = jnp.where(tri, jnp.exp(dec), 0.0) * dtc[:, None, :, :]
        scores = cb[..., None] * w                           # [B,Q,Q,H]
        y = jnp.einsum("bijh,bjhp->bihp", scores, xc)        # [B,Q,H,P]
        # inter-chunk: y_i += exp(b_i) C_i . S_prev
        y += jnp.exp(b)[..., None] * jnp.einsum("bin,bhnp->bihp", Cc, Sst)
        # state update
        wj = jnp.exp(total[:, None] - b) * dtc               # [B,Q,H]
        S_new = jnp.exp(total)[..., None, None] * Sst + \
            jnp.einsum("bjh,bjn,bjhp->bhnp", wj, Bc, xc)
        return S_new, y

    Sf, ys = jax.lax.scan(body, S0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    y = y + D[None, None, :, None] * xh.astype(jnp.float32)
    return y, Sf


def mamba_apply(p, cfg, x, state=None):
    """x: [B,S,d] -> (delta [B,S,d], new_state)."""
    B, S, d = x.shape
    z, xbc, dt_raw, (inner, H, P, N) = _split_in(p, cfg, x)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xh = xbc[..., :inner].reshape(B, S, H, P)
    Bm = xbc[..., inner:inner + N]
    Cm = xbc[..., inner + N:]
    dt, log_a = _gates(p, dt_raw)
    y, Sf = _ssd_chunk_scan(xh, Bm, Cm, dt, log_a, p["D"], cfg.ssm_chunk, state)
    y = y.reshape(B, S, inner).astype(x.dtype)
    y = L.rmsnorm_apply(p["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["w_out"], Sf


def mamba_state_init(cfg, batch):
    inner, H, P, N = mamba_dims(cfg)
    conv_dim = inner + 2 * N
    return {
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, conv_dim), jnp.float32),
    }


def mamba_decode(p, cfg, x, state):
    """x: [B,1,d]; recurrent single step."""
    B, _, d = x.shape
    z, xbc, dt_raw, (inner, H, P, N) = _split_in(p, cfg, x)
    # conv with carried state
    hist = jnp.concatenate([state["conv"], xbc.astype(jnp.float32)], axis=1)  # [B,K,C]
    conv = sum(hist[:, i, :] * p["conv_w"][:, i].astype(jnp.float32)
               for i in range(CONV_K))
    conv = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32))                # [B,C]
    new_conv = hist[:, 1:]
    xh = conv[:, :inner].reshape(B, H, P)
    Bm = conv[:, inner:inner + N]
    Cm = conv[:, inner + N:]
    dt, log_a = _gates(p, dt_raw[:, 0])                       # [B,H]
    a = jnp.exp(log_a)
    Sst = a[..., None, None] * state["ssm"] + \
        jnp.einsum("bh,bn,bhp->bhnp", dt, Bm, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cm, Sst) + p["D"][None, :, None] * xh
    y = y.reshape(B, 1, inner).astype(x.dtype)
    y = L.rmsnorm_apply(p["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["w_out"], {"ssm": Sst, "conv": new_conv}
