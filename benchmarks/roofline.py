"""Roofline report over the dry-run results (EXPERIMENTS.md §Roofline).

Reads dryrun_results.json (produced by ``python -m repro.launch.dryrun --all
--mesh both --json dryrun_results.json``) and prints the per-(arch x shape)
three-term roofline table with the dominant bottleneck and the
MODEL_FLOPS / HLO_FLOPs usefulness ratio.  Single-pod rows only, per the
harness contract (the multi-pod rows prove the pod axis shards)."""
from __future__ import annotations

import json
import os
import sys

from benchmarks.common import emit


def main(path=None):
    path = path or os.path.join(os.path.dirname(__file__), "..",
                                "dryrun_results.json")
    if not os.path.exists(path):
        emit("roofline/missing", 0.0,
             f"no {os.path.basename(path)} — run python -m repro.launch.dryrun --all --mesh both")
        return []
    rows = json.load(open(path))
    table = []
    for r in rows:
        if not r.get("ok") or r.get("mesh") != "single":
            continue
        rf = r["roofline"]
        table.append(r)
        emit(f"roofline/{r['arch']}/{r['shape']}", rf["t_bound_s"] * 1e6,
             f"dominant={rf['dominant']};t_comp={rf['t_compute_s']:.4g}"
             f";t_mem={rf['t_memory_s']:.4g};t_coll={rf['t_collective_s']:.4g}"
             f";useful_ratio={rf.get('useful_flops_ratio', 0):.3f}"
             f";hbm_GiB={r['memory']['total_hbm_bytes'] / 2**30:.2f}")
    ok_multi = sum(1 for r in rows if r.get("ok") and r.get("mesh") == "multi")
    emit("roofline/summary", 0.0,
         f"single_pod_ok={len(table)};multi_pod_ok={ok_multi};total={len(rows)}")
    return table


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else None)
