"""Paper Fig. 5: total remaining energy + running time vs round, DR-FL vs
HeteroFL-style greedy, heterogeneous fleet (paper: 20 Nano + 20 Xavier).

Directional claims checked: (a) DR-FL sustains more rounds before devices
exhaust their batteries; (b) DR-FL's cumulative running time grows slower
(less waiting/useless training)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_params, emit, family_supports
from repro.fl import FLConfig, run_simulation


def main(seed=0, verbose=False):
    p = bench_params()
    p["n_rounds"] = max(p["n_rounds"], 10)
    out = {}
    for method, sel in (("drfl", "marl"), ("heterofl", "greedy")):
        if not family_supports(p, method):
            emit(f"fig5/{method}", 0.0,
                 f"skipped=unsupported_by_{p['model_family']}")
            continue
        t0 = time.time()
        cfg = FLConfig(method=method, selector=sel, seed=seed,
                       marl_episodes=3, **p)   # binding battery budget
        h = run_simulation(cfg, verbose=verbose)
        e = np.asarray(h["energy"])
        t = np.cumsum(h["round_time"])
        alive = np.asarray(h["alive"])
        surv = int(np.argmax(alive < alive[0])) if (alive < alive[0]).any() \
            else len(alive)
        out[method] = dict(energy=e, cum_time=t, alive=alive, surv=surv)
        emit(f"fig5/{method}", (time.time() - t0) * 1e6,
             f"rounds_before_first_death={surv};final_energy_J={e[-1]:.0f};"
             f"final_cum_time_s={t[-1]:.1f};alive_end={alive[-1]}")
    if "drfl" in out and "heterofl" in out:
        emit("fig5/claim", 0.0,
             f"drfl_survives_rounds={out['drfl']['surv']}"
             f";heterofl_survives_rounds={out['heterofl']['surv']}"
             f";claim_holds={out['drfl']['surv'] >= out['heterofl']['surv']}")
    return out


if __name__ == "__main__":
    main(verbose=True)
