"""Fused RMSNorm Pallas kernel (one HBM round-trip instead of XLA's
mean+rsqrt+mul chain). Rows tile over the grid; the feature dim stays whole
in VMEM (d <= 8192 across all assigned archs => <= 32 KiB f32 per row)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                 # [br, d]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x2d, scale, *, eps=1e-5, block_rows=256, interpret=False):
    """x2d: [R, d]; scale: [d] -> [R, d]."""
    R, d = x2d.shape
    block_rows = min(block_rows, R)
    assert R % block_rows == 0
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x2d.dtype),
        interpret=interpret,
    )(x2d, scale)
