"""Non-IID client partitions (paper §5.1.2): Dirichlet(alpha) heterogeneous
splits following HeteroFL's methodology — smaller alpha = more non-IID."""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_per_client: int = 8) -> List[np.ndarray]:
    """Returns per-client index arrays."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    for _ in range(100):
        parts = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx = np.flatnonzero(labels == c)
            rng.shuffle(idx)
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
            for i, chunk in enumerate(np.split(idx, cuts)):
                parts[i].extend(chunk.tolist())
        sizes = [len(p) for p in parts]
        if min(sizes) >= min_per_client:
            break
    return [np.asarray(sorted(p), dtype=np.int64) for p in parts]
