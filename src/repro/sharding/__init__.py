from repro.sharding.rules import (activation_spec, batch_axes, cache_specs,
                                  constrain, param_specs, set_activation_mesh,
                                  spec_for)  # noqa: F401
from repro.sharding.fleet import (FLEET_AXIS, fleet_mesh,  # noqa: F401
                                  fleet_shardings, fleet_spec_for,
                                  is_sharded, maybe_shard_fleet, shard_fleet,
                                  unshard_fleet)
