"""Pluggable ``ModelFamily`` protocol + registry — the FL stack's model API.

DR-FL's dual selection runs over *layer-wise* models: a global model that
factors into depth-prefix submodels Model_1..Model_M, each with its own
early exit.  Everything the FL layers (client updates, aggregation masks,
stack templates, the bucketed executor, cost calibration) need from a model
is captured here as one protocol, so `repro.fl` and `repro.core.aggregation`
never import a concrete architecture:

* :class:`ModelFamily` — the abstract surface (init / apply_all_exits /
  masks / stacked-aggregation layout / per-method client updates / cost
  model).
* :class:`LayerwiseFamily` — the shared implementation for any family whose
  parameters follow the canonical layer-wise tree layout
  ``{"stem": ..., "stages": [stage_0, ...], "exits": [exit_0, ...]}``
  (submodel m = stem + stages[:m+1] + exits[:m+1]).  Masks, stack groups,
  templates, SGD client updates and the paper-scale cost model are all
  generic over that layout; concrete families supply ``init``,
  ``apply_all_exits`` and an analytic ``flops_per_sample``.
* the registry — ``register_family`` / ``get_family`` / ``resolve_family``.
  ``"cnn"`` (:class:`repro.models.cnn.CnnFamily`) is the registered default;
  ``"mlp"`` (:class:`repro.models.mlp.MlpFamily`) is the early-exit MLP
  built from :mod:`repro.models.layers`; ``"transformer"``
  (:class:`repro.models.transformer_family.TransformerFamily`) is the
  early-exit decoder trained on the synthetic next-token corpus.

Families are stateful singletons: they own the jitted per-method step
programs and the mask / stack-template caches, so two call sites asking for
the same family share compiled programs (the engine and the frozen
reference loop trace the SAME jitted functions — that is what keeps the
sync-parity contract bit-for-bit).

Public surface (one-line contracts):

* :class:`ModelFamily` — the abstract protocol: ``init`` /
  ``apply_all_exits`` / ``num_submodels`` (model surface), ``submodel_*``
  (depth-prefix views + size accounting), ``update_mask`` /
  ``stack_groups`` / ``stack_template`` / ``held_groups`` /
  ``unstack_groups`` (aggregation layout), ``loss_fn`` /
  ``client_update`` / ``bucket_trace_context`` (client training),
  ``cost_model`` (paper-scale Eq. 5/7 calibration),
  ``state_summary_width`` / ``fleet_summary`` (the factored QMIX global
  state, sized by the family not the fleet), ``supports`` /
  ``supported_methods`` (method gating).
* :class:`LayerwiseFamily` — everything above implemented generically for
  the canonical ``{"stem", "stages": [...], "exits": [...]}`` layout;
  subclasses supply ``init`` / ``apply_all_exits`` / ``num_submodels`` /
  ``flops_per_sample``.
* :func:`register_family` — add a singleton to the registry (key =
  ``family.name`` unless overridden).
* :func:`known_families` — sorted registry keys (builtins auto-load).
* :func:`get_family` — registry lookup; ``None`` -> the default family.
* :func:`resolve_family` — accept name / instance / None uniformly.
* :func:`cross_entropy` — mean CE over a batch (shared loss primitive).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core.baselines import kd_loss


# ---------------------------------------------------------------------------
# shared loss primitives
# ---------------------------------------------------------------------------


def cross_entropy(logits, y):
    """Mean CE over a batch (log-sum-exp form, integer labels)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32),
                              axis=-1)[:, 0]
    return jnp.mean(lse - tgt)


def _mean_loss(losses) -> float:
    """ONE host sync for a whole local run: per-step device scalars stay
    un-synced and are reduced on device; only the final mean crosses."""
    if not losses:
        return 0.0
    # jaxlint: allow(host-sync-in-hot-path) -- the documented one pull per local run: device-reduced mean loss
    return float(jnp.mean(jnp.stack(losses)))


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------


class ModelFamily:
    """Abstract model-family surface consumed by ``repro.fl``.

    Concrete families are registered singletons (hash by identity — they are
    safe as jit static arguments)."""

    #: registry key / display name
    name: str = "abstract"
    #: FL methods (client-update kinds) this family can train
    supported_methods: Tuple[str, ...] = ()
    #: image size the paper-scale energy model is calibrated at
    ref_hw: int = 32

    # -- model surface ---------------------------------------------------
    def init(self, key, num_classes: int = 10, width_mult: float = 1.0,
             hw: int = 32):
        raise NotImplementedError

    def num_submodels(self) -> int:
        raise NotImplementedError

    def apply_all_exits(self, params, x):
        """Logits from every exit held by ``params`` (truncated trees ok)."""
        raise NotImplementedError

    def flops_per_sample(self, model_idx: int, image_hw: int = 32,
                         width_mult: float = 1.0) -> float:
        """Analytic forward FLOPs for Model_{idx+1} (energy-model input)."""
        raise NotImplementedError

    # -- data surface ------------------------------------------------------
    def make_dataset(self, n: int, num_classes: int = 10, hw: int = 32,
                     noise: float = 1.0, seed: int = 0):
        """The training corpus this family learns from: ``(x, y)`` numpy
        arrays whose ROWS the FL stack treats opaquely (Dirichlet shards by
        label ``y``, row-gathers mini-batches, feeds ``x`` straight to
        ``apply_all_exits``).  Default: the synthetic class-conditional
        image set (``x [n, hw, hw, 3]`` float32); token families override
        with ``[n, seq]`` int32 context windows whose next-token label is
        the class — ``hw`` doubles as the sequence length there."""
        from repro.data.synthetic import synthetic_image_dataset
        return synthetic_image_dataset(n, num_classes, hw=hw, noise=noise,
                                       seed=seed)

    # -- submodel structure ----------------------------------------------
    def submodel_tree(self, tree, model_idx: int):
        """Depth-prefix view of ``tree`` a Model_{idx+1} client trains."""
        raise NotImplementedError

    def submodel_params(self, method: str, global_params, model_idx: int):
        """The initial tree a ``method`` client at ``model_idx`` trains."""
        raise NotImplementedError

    def submodel_size_bytes(self, params, model_idx: int) -> int:
        raise NotImplementedError

    # -- aggregation layout ----------------------------------------------
    def update_mask(self, global_params, model_idx: int, scale: float = 1.0):
        raise NotImplementedError

    def stack_groups(self, params) -> List:
        """Aggregation-unit group trees, in global group order."""
        raise NotImplementedError

    def held_groups(self, global_params, model_idx: int) -> List[bool]:
        """Which global groups a Model_{idx+1} submodel holds."""
        raise NotImplementedError

    def unstack_groups(self, global_params, groups: List):
        """Rebuild a full tree from updated group trees."""
        raise NotImplementedError

    def stack_template(self, global_params, seg: int = 1024):
        raise NotImplementedError

    # -- client training -------------------------------------------------
    def loss_fn(self, method: str) -> Callable:
        raise NotImplementedError

    def client_update(self, method: str, global_params, model_idx: int,
                      x, y, *, epochs: int = 5, batch: int = 32,
                      lr: float = 0.05, seed: int = 0):
        raise NotImplementedError

    def bucket_trace_context(self):
        """Context manager active while the bucketed-vmap executor traces
        this family's forward pass (families may swap in vmap-friendly
        formulations, e.g. the CNN's patches-conv on CPU)."""
        return contextlib.nullcontext()

    # -- cost model -------------------------------------------------------
    def cost_model(self, num_classes: int = 10
                   ) -> Tuple[Tuple[float, ...], Tuple[float, ...]]:
        """(submodel bytes, FLOP fractions) at PAPER scale (width 1.0,
        ``ref_hw`` images) — what the Eq. 5/7 energy accounting charges."""
        raise NotImplementedError

    # -- factored MARL state ----------------------------------------------
    def state_summary_width(self, n_bins: Optional[int] = None) -> int:
        """Width of this family's factored QMIX global state
        (:func:`repro.core.fleet.summary_width` over its submodel count) —
        a function of the FAMILY, independent of ``n_devices``.  This is
        the registry hook the scaled MARL selector sizes its mixer with."""
        from repro.core import fleet as core_fleet
        bins = core_fleet.SUMMARY_BINS if n_bins is None else n_bins
        return core_fleet.summary_width(self.num_submodels(), bins)

    def fleet_summary(self, fleet, round_idx=0, n_rounds: int = 1, *,
                      num_classes: int = 10, local_epochs: int = 5,
                      batch_size: int = 32):
        """Fixed-width fleet summary priced with THIS family's Eq. 5/7
        cost model (per-submodel affordability fractions use the family's
        paper-scale sizes/FLOP fractions) — see
        :func:`repro.core.fleet.fleet_summary`."""
        from repro.core import fleet as core_fleet
        sizes, fractions = self.cost_model(num_classes)
        return core_fleet.fleet_summary(
            fleet, sizes, fractions, round_idx, n_rounds,
            local_epochs, batch_size)

    def supports(self, method: str) -> bool:
        return method in self.supported_methods

    def __repr__(self):
        return f"<ModelFamily {self.name!r}>"


# ---------------------------------------------------------------------------
# generic layer-wise implementation (canonical stem/stages/exits layout)
# ---------------------------------------------------------------------------


class LayerwiseFamily(ModelFamily):
    """Shared machinery for families with the canonical layer-wise layout.

    Parameters are ``{"stem": tree, "stages": [tree...], "exits": [tree...]}``
    with one exit per stage; submodel m trains stem + stages[:m+1] +
    exits[:m+1] (deep supervision over every held exit).  Aggregation
    groups are stem + each stage + each exit — the units
    :meth:`update_mask` masks as wholes and the stacked Pallas path
    flattens into segment rows.
    """

    supported_methods = ("drfl",)

    def __init__(self):
        # mask pytrees depend only on tree STRUCTURE and (model_idx, scale);
        # leaves are immutable jnp scalars, safe to alias between calls
        self._mask_cache: dict = {}
        self._template_cache: dict = {}
        self._cost_cache: dict = {}
        self._jit_cache: dict = {}

    # -- submodel structure ----------------------------------------------
    def submodel_tree(self, tree, model_idx: int):
        return {"stem": tree["stem"],
                "stages": tree["stages"][:model_idx + 1],
                "exits": tree["exits"][:model_idx + 1]}

    def submodel_params(self, method: str, global_params, model_idx: int):
        if method == "drfl":
            return self.submodel_tree(global_params, model_idx)
        raise ValueError(f"family {self.name!r} does not support "
                         f"method {method!r} (supported: "
                         f"{self.supported_methods})")

    def _size_tree(self, params, model_idx: int):
        """The pytree a Model_{idx+1} client actually holds on device for
        size accounting: depth prefix + ITS exit head only."""
        return {"stem": params["stem"],
                "stages": params["stages"][:model_idx + 1],
                "exits": [params["exits"][model_idx]]}

    def submodel_size_bytes(self, params, model_idx: int) -> int:
        tree = self._size_tree(params, model_idx)
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))

    # -- aggregation layout ----------------------------------------------
    def update_mask(self, global_params, model_idx: int, scale: float = 1.0):
        """Scalar masks matching the layer-wise tree: stem + stages<=m +
        exits<=m (clients deep-supervise every exit their submodel holds).
        ``scale`` replaces the 1.0 of held layers — the staleness path
        builds decay masks (value alpha_s per exit-layer) with the same
        structure."""
        key = (jax.tree.structure(global_params), int(model_idx),
               float(scale))
        hit = self._mask_cache.get(key)
        if hit is not None:
            return hit

        def const(tree, v):
            return jax.tree.map(lambda _: jnp.asarray(v, jnp.float32), tree)

        mask = {
            "stem": const(global_params["stem"], scale),
            "stages": [const(s, scale if i <= model_idx else 0.0)
                       for i, s in enumerate(global_params["stages"])],
            "exits": [const(e, scale if i <= model_idx else 0.0)
                      for i, e in enumerate(global_params["exits"])],
        }
        if len(self._mask_cache) > 512:     # staleness scales are open-ended
            self._mask_cache.clear()
        self._mask_cache[key] = mask
        return mask

    def stack_groups(self, params) -> List:
        return ([params["stem"]] + list(params["stages"])
                + list(params["exits"]))

    def held_groups(self, global_params, model_idx: int) -> List[bool]:
        n_stages = len(global_params["stages"])
        held = [i <= model_idx for i in range(n_stages)]
        return [True] + held + held

    def unstack_groups(self, global_params, groups: List):
        n_stages = len(global_params["stages"])
        return {"stem": groups[0],
                "stages": groups[1:1 + n_stages],
                "exits": groups[1 + n_stages:]}

    def stack_template(self, global_params, seg: int = 1024):
        shapes = tuple((tuple(l.shape), str(l.dtype))
                       for l in jax.tree.leaves(global_params))
        key = (shapes, int(seg))
        if key not in self._template_cache:
            self._template_cache[key] = aggregation.build_stack_template(
                self.stack_groups(global_params), seg=seg)
        return self._template_cache[key]

    # -- losses -----------------------------------------------------------
    def _drfl_loss(self, sub, x, y):
        """Joint CE over every exit the submodel holds (BranchyNet-style
        deep supervision); the deepest held exit carries full weight,
        shallower exits get 0.3."""
        outs = self.apply_all_exits(sub, x)
        loss = cross_entropy(outs[-1], y)
        for o in outs[:-1]:
            loss = loss + 0.3 * cross_entropy(o, y)
        return loss / (1.0 + 0.3 * (len(outs) - 1))

    def _slice_loss(self, sub, x, y):
        """Width-sliced trees (HeteroFL): loss at the deepest exit."""
        outs = self.apply_all_exits(sub, x)
        return cross_entropy(outs[-1], y)

    def _scalefl_loss(self, sub, x, y):
        """Depth+width tree; CE at every held exit + KD deepest->shallower."""
        outs = self.apply_all_exits(sub, x)
        teacher = outs[-1]
        loss = cross_entropy(teacher, y)
        for s in outs[:-1]:
            loss = loss + 0.5 * (cross_entropy(s, y)
                                 + kd_loss(s, jax.lax.stop_gradient(teacher)))
        return loss / max(len(outs), 1)

    def loss_fn(self, method: str) -> Callable:
        try:
            return {"drfl": self._drfl_loss,
                    "heterofl": self._slice_loss,
                    "scalefl": self._scalefl_loss}[method]
        except KeyError:
            raise ValueError(f"unknown method {method!r}") from None

    # -- jitted per-method SGD steps --------------------------------------
    def _step_fn(self, method: str):
        key = ("step", method)
        fn = self._jit_cache.get(key)
        if fn is not None:
            return fn
        loss_fn = self.loss_fn(method)
        if method == "drfl":
            # jaxlint: allow(retrace-hazard) -- memoised in self._jit_cache keyed by (step, method); built once per family
            @functools.partial(jax.jit, static_argnums=(3,))
            def fn(params, x, y, model_idx: int, lr: float = 0.05):
                def wrapped(p):
                    return loss_fn(self.submodel_tree(p, model_idx), x, y)

                loss, grads = jax.value_and_grad(wrapped)(params)
                new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
                return new, loss
        else:
            # jaxlint: allow(retrace-hazard) -- memoised in self._jit_cache keyed by (step, method); built once per family
            @jax.jit
            def fn(params, x, y, lr: float = 0.05):
                loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
                new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
                return new, loss
        self._jit_cache[key] = fn
        return fn

    def eval_fn(self):
        """Jitted per-exit accuracy over one batch (server evaluation)."""
        fn = self._jit_cache.get("eval")
        if fn is None:
            # jaxlint: allow(retrace-hazard) -- memoised in self._jit_cache under "eval"; built once per family
            @jax.jit
            def fn(params, x, y):
                outs = self.apply_all_exits(params, x)
                return jnp.stack([jnp.mean((jnp.argmax(o, -1) == y))
                                  for o in outs])
            self._jit_cache["eval"] = fn
        return fn

    # -- client training --------------------------------------------------
    def client_update(self, method: str, global_params, model_idx: int,
                      x, y, *, epochs: int = 5, batch: int = 32,
                      lr: float = 0.05, seed: int = 0):
        """One client's local run: returns ``(delta, mean local loss)``.

        ``method="drfl"`` trains the depth-prefix submodel *in place* on
        the full-structure tree (grads are exactly zero outside the
        submodel, so the returned delta is already zero-filled for
        layer-aligned aggregation); other methods train the family's
        sliced submodel tree and return the sliced delta."""
        from repro.data.loader import epoch_batches
        if not self.supports(method):
            raise ValueError(f"family {self.name!r} does not support "
                             f"method {method!r} (supported: "
                             f"{self.supported_methods})")
        rng = np.random.default_rng(seed)
        step = self._step_fn(method)
        if method == "drfl":
            params = global_params
            losses = []
            for _ in range(epochs):
                for xb, yb in epoch_batches(x, y, batch, rng):
                    params, l = step(params, jnp.asarray(xb),
                                     jnp.asarray(yb), model_idx, lr)
                    losses.append(l)
            delta = jax.tree.map(lambda a, b: a - b, params, global_params)
            return delta, _mean_loss(losses)
        sub = self.submodel_params(method, global_params, model_idx)
        params, losses = sub, []
        for _ in range(epochs):
            for xb, yb in epoch_batches(x, y, batch, rng):
                params, l = step(params, jnp.asarray(xb), jnp.asarray(yb),
                                 lr)
                losses.append(l)
        delta = jax.tree.map(lambda a, b: a - b, params, sub)
        return delta, _mean_loss(losses)

    # -- cost model --------------------------------------------------------
    def cost_model(self, num_classes: int = 10):
        """Paper-scale calibration: submodel sizes from an eval_shape init
        at width 1.0 / ``ref_hw`` (no arrays materialized), FLOP fractions
        from the analytic per-sample forward cost."""
        key = int(num_classes)
        hit = self._cost_cache.get(key)
        if hit is not None:
            return hit
        M = self.num_submodels()
        ref = jax.eval_shape(
            lambda k: self.init(k, num_classes, width_mult=1.0,
                                hw=self.ref_hw),
            jax.random.PRNGKey(0))
        sizes = tuple(
            sum(l.size * l.dtype.itemsize
                for l in jax.tree.leaves(self._size_tree(ref, m)))
            for m in range(M))
        full = self.flops_per_sample(M - 1, self.ref_hw, 1.0)
        fractions = tuple(self.flops_per_sample(m, self.ref_hw, 1.0) / full
                          for m in range(M))
        self._cost_cache[key] = (sizes, fractions)
        return sizes, fractions


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelFamily] = {}
_DEFAULT = "cnn"
_BUILTINS_LOADED = False


def register_family(family: ModelFamily,
                    name: Optional[str] = None) -> ModelFamily:
    """Register a family singleton under ``name`` (default: family.name)."""
    key = name or family.name
    _REGISTRY[key] = family
    return family


def _ensure_builtins():
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    # concrete families self-register at import; imported lazily so the
    # registry module itself stays import-cycle-free
    from repro.models import cnn, mlp, transformer_family  # noqa: F401


def known_families() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def get_family(name: Optional[str] = None) -> ModelFamily:
    _ensure_builtins()
    key = name or _DEFAULT
    try:
        return _REGISTRY[key]
    except KeyError:
        raise ValueError(
            f"unknown model family {key!r} "
            f"(registered: {', '.join(sorted(_REGISTRY))})") from None


def resolve_family(family=None) -> ModelFamily:
    """None -> the default family; str -> registry lookup; a ModelFamily
    instance passes through."""
    if family is None:
        return get_family()
    if isinstance(family, str):
        return get_family(family)
    if isinstance(family, ModelFamily):
        return family
    raise TypeError(f"expected ModelFamily, name or None, got {family!r}")
