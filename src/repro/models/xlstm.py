"""xLSTM backbone (arXiv:2405.04517): alternating mLSTM / sLSTM blocks.

* Even blocks: **mLSTM** — per-head matrix memory ``C ∈ R^{P×P}`` with
  exponential input gate and sigmoid forget gate; trained with the
  **chunkwise-parallel** stabilised algorithm (linear in sequence length),
  decoded with the O(1)-state recurrent step.
* Odd blocks: **sLSTM** — scalar memory with block-diagonal (per-head)
  recurrent weights and exponential-gating max-stabiliser; `lax.scan` over
  time (non-associative recurrence, cannot be parallelised).

Assignment note: ``d_ff=0`` — blocks carry internal up/down projections
(mLSTM projection factor 2; sLSTM gated FFN factor 4/3), per the paper's
block design.

Stacking: ``lax.scan`` over L/2 (mLSTM, sLSTM) pairs of stacked params.
DR-FL ``layer_mask`` has length ``num_layers`` and is consumed pairwise.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.rules import constrain


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    H = cfg.num_heads
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d)
    return {
        "norm": L.rmsnorm_init(d, dtype),
        "w_up": L._normal(ks[0], (d, 2 * inner), s, dtype),       # u ++ z(gate)
        "wq": L._normal(ks[1], (inner, inner), 1.0 / math.sqrt(inner), dtype),
        "wk": L._normal(ks[2], (inner, inner), 1.0 / math.sqrt(inner), dtype),
        "wv": L._normal(ks[3], (inner, inner), 1.0 / math.sqrt(inner), dtype),
        "w_if": L._normal(ks[4], (d, 2 * H), s, jnp.float32),      # i, f gate logits
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(jnp.float32),
        "out_norm": L.rmsnorm_init(inner, dtype),
        "w_down": L._normal(ks[5], (inner, d), 1.0 / math.sqrt(inner), dtype),
    }


def _mlstm_chunk_scan(q, k, v, log_i, log_f, chunk, state=None):
    """Stabilised chunkwise mLSTM.

    q,k,v: [B, H, S, P]; log_i/log_f: [B, H, S].
    Returns y [B, H, S, P] and final (C [B,H,P,P], n [B,H,P], m [B,H]).
    """
    B, H, S, P = q.shape
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nC = S // Q

    qc = jnp.moveaxis(q.reshape(B, H, nC, Q, P), 2, 0)       # [nC, B, H, Q, P]
    kc = jnp.moveaxis(k.reshape(B, H, nC, Q, P), 2, 0)
    vc = jnp.moveaxis(v.reshape(B, H, nC, Q, P), 2, 0)
    lic = jnp.moveaxis(log_i.reshape(B, H, nC, Q), 2, 0)     # [nC, B, H, Q]
    lfc = jnp.moveaxis(log_f.reshape(B, H, nC, Q), 2, 0)

    if state is None:
        C0 = jnp.zeros((B, H, P, P), jnp.float32)
        n0 = jnp.zeros((B, H, P), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def body(carry, xs):
        C, n, m = carry
        qb, kb, vb, li, lf = xs
        qb, kb, vb = (t.astype(jnp.float32) for t in (qb, kb, vb))
        b = jnp.cumsum(lf, axis=-1)                          # [B,H,Q] inclusive
        total = b[..., -1]                                   # [B,H]
        # per-position intra log weights: a_ij = b_i - b_j + li_j  (j<=i)
        aij = b[..., :, None] - b[..., None, :] + li[..., None, :]
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        aij = jnp.where(tri, aij, -jnp.inf)
        inter_log = m[..., None] + b                         # [B,H,Q]
        m_i = jnp.maximum(inter_log, jnp.max(aij, axis=-1))  # [B,H,Q]
        m_i = jnp.maximum(m_i, -1e30)
        w_intra = jnp.exp(aij - m_i[..., None])              # [B,H,Q,Q]
        w_inter = jnp.exp(inter_log - m_i)                   # [B,H,Q]
        scale = 1.0 / math.sqrt(P)
        s_ij = jnp.einsum("bhip,bhjp->bhij", qb * scale, kb) * w_intra
        num = jnp.einsum("bhij,bhjp->bhip", s_ij, vb)
        num += w_inter[..., None] * jnp.einsum("bhip,bhpq->bhiq", qb * scale, C)
        den = jnp.sum(s_ij, axis=-1)
        den += w_inter * jnp.einsum("bhip,bhp->bhi", qb * scale, n)
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # state update to end of chunk
        m_new = jnp.maximum(m + total, jnp.max(total[..., None] - b + li, axis=-1))
        w_old = jnp.exp(m + total - m_new)                   # [B,H]
        w_j = jnp.exp(total[..., None] - b + li - m_new[..., None])  # [B,H,Q]
        C_new = w_old[..., None, None] * C + jnp.einsum("bhj,bhjp,bhjq->bhpq", w_j, kb, vb)
        n_new = w_old[..., None] * n + jnp.einsum("bhj,bhjp->bhp", w_j, kb)
        return (C_new, n_new, m_new), y

    (C, n, m), ys = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    y = jnp.moveaxis(ys, 0, 2).reshape(B, H, S, P)           # [B,H,S,P]
    return y, (C, n, m)


def mlstm_step(q, k, v, log_i, log_f, state):
    """Single recurrent step.  q,k,v: [B,H,P]; gates [B,H]."""
    C, n, m = state
    P = q.shape[-1]
    q, k, v = (t.astype(jnp.float32) for t in (q, k, v))
    m_new = jnp.maximum(log_f + m, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    C = f_p[..., None, None] * C + i_p[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = f_p[..., None] * n + i_p[..., None] * k
    scale = 1.0 / math.sqrt(P)
    num = jnp.einsum("bhp,bhpq->bhq", q * scale, C)
    den = jnp.einsum("bhp,bhp->bh", q * scale, n)
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return y, (C, n, m_new)


def _mlstm_pre(p, cfg, x):
    """Shared projections.  x: [B,S,d] -> q,k,v [B,H,S,P], gates, z-gate."""
    B, S, d = x.shape
    inner = cfg.ssm_expand * d
    H = cfg.num_heads
    P = inner // H
    h = L.rmsnorm_apply(p["norm"], x, cfg.norm_eps)
    up = h @ p["w_up"]
    u, z = jnp.split(up, 2, axis=-1)                          # [B,S,inner] each
    q = (u @ p["wq"]).reshape(B, S, H, P).transpose(0, 2, 1, 3)
    k = (u @ p["wk"]).reshape(B, S, H, P).transpose(0, 2, 1, 3)
    v = (u @ p["wv"]).reshape(B, S, H, P).transpose(0, 2, 1, 3)
    gl = (h.astype(jnp.float32) @ p["w_if"]) + p["b_if"]      # [B,S,2H]
    i_raw, f_raw = jnp.split(gl, 2, axis=-1)
    log_i = jnp.transpose(i_raw, (0, 2, 1))                   # [B,H,S]
    log_f = jnp.transpose(jax.nn.log_sigmoid(f_raw), (0, 2, 1))
    return q, k, v, log_i, log_f, z, (B, S, inner, H, P)


def mlstm_apply(p, cfg, x, state=None):
    q, k, v, log_i, log_f, z, (B, S, inner, H, P) = _mlstm_pre(p, cfg, x)
    y, new_state = _mlstm_chunk_scan(q, k, v, log_i, log_f, cfg.ssm_chunk, state)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, inner).astype(x.dtype)
    y = L.rmsnorm_apply(p["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["w_down"], new_state


def mlstm_decode(p, cfg, x, state):
    """x: [B,1,d]."""
    q, k, v, log_i, log_f, z, (B, S, inner, H, P) = _mlstm_pre(p, cfg, x)
    y, new_state = mlstm_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                              log_i[:, :, 0], log_f[:, :, 0], state)
    y = y.reshape(B, 1, inner).astype(x.dtype)
    y = L.rmsnorm_apply(p["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["w_down"], new_state


def mlstm_state_init(cfg, batch):
    inner = cfg.ssm_expand * cfg.d_model
    H = cfg.num_heads
    P = inner // H
    return (jnp.zeros((batch, H, P, P), jnp.float32),
            jnp.zeros((batch, H, P), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    P = d // H
    f = max(1, int(d * 4 / 3) // 8 * 8)
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "norm": L.rmsnorm_init(d, dtype),
        "w_in": L._normal(ks[0], (d, 4 * d), s, dtype),           # z,i,f,o pre-acts
        "r": L._normal(ks[1], (H, P, 4 * P), 1.0 / math.sqrt(P), jnp.float32),
        "b": jnp.tile(jnp.concatenate(
            [jnp.zeros((d,)), jnp.zeros((d,)), 3.0 * jnp.ones((d,)), jnp.zeros((d,))]), (1,)).astype(jnp.float32),
        "out_norm": L.rmsnorm_init(d, dtype),
        "ffn": L.swiglu_init(ks[2], d, f, dtype),
    }


def _slstm_cell(gates_x, r, h, c, n, m, H, P):
    """One sLSTM step.  gates_x: [B, 4d] input pre-activations."""
    B = gates_x.shape[0]
    hr = h.reshape(B, H, P)
    rec = jnp.einsum("bhp,hpq->bhq", hr, r).reshape(B, 4 * H * P)
    z_r, i_r, f_r, o_r = jnp.split(gates_x + rec, 4, axis=-1)
    log_i = i_r
    log_f = jax.nn.log_sigmoid(f_r)
    m_new = jnp.maximum(log_f + m, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(z_r)
    o = jax.nn.sigmoid(o_r)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return h_new, c_new, n_new, m_new


def slstm_apply(p, cfg, x, state=None):
    B, S, d = x.shape
    H = cfg.num_heads
    P = d // H
    hin = L.rmsnorm_apply(p["norm"], x, cfg.norm_eps)
    gx = (hin.astype(jnp.float32) @ p["w_in"].astype(jnp.float32)) + p["b"]  # [B,S,4d]
    if state is None:
        state = slstm_state_init(cfg, B)
    h0, c0, n0, m0 = state

    def body(carry, gxt):
        h, c, n, m = carry
        h, c, n, m = _slstm_cell(gxt, p["r"], h, c, n, m, H, P)
        return (h, c, n, m), h

    (h, c, n, m), hs = jax.lax.scan(body, (h0, c0, n0, m0), jnp.moveaxis(gx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)                # [B,S,d]
    y = L.rmsnorm_apply(p["out_norm"], y, cfg.norm_eps)
    return L.swiglu_apply(p["ffn"], y), (h, c, n, m)


def slstm_decode(p, cfg, x, state):
    B, S, d = x.shape
    H, P = cfg.num_heads, d // cfg.num_heads
    hin = L.rmsnorm_apply(p["norm"], x, cfg.norm_eps)
    gx = (hin.astype(jnp.float32) @ p["w_in"].astype(jnp.float32)) + p["b"]
    h, c, n, m = _slstm_cell(gx[:, 0], p["r"], *state, H, P)
    y = h[:, None, :].astype(x.dtype)
    y = L.rmsnorm_apply(p["out_norm"], y, cfg.norm_eps)
    return L.swiglu_apply(p["ffn"], y), (h, c, n, m)


def slstm_state_init(cfg, batch):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, z, jnp.full((batch, d), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init(key, cfg):
    dtype = _dt(cfg)
    assert cfg.num_layers % 2 == 0
    npairs = cfg.num_layers // 2
    k_emb, k_m, k_s, k_out = jax.random.split(key, 4)
    return {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "mlstm": jax.vmap(lambda k: mlstm_init(k, cfg, dtype))(jax.random.split(k_m, npairs)),
        "slstm": jax.vmap(lambda k: slstm_init(k, cfg, dtype))(jax.random.split(k_s, npairs)),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "unembed": L.dense_init(k_out, cfg.d_model, cfg.vocab_size, dtype),
    }


def unembed_matrix(params, cfg):
    return params["unembed"]["w"]


def apply(params, cfg, tokens, *, layer_mask=None, window=None,
          use_pallas=False, attn_chunk=0, remat="full"):
    B, S = tokens.shape
    x = constrain(params["embed"]["emb"][tokens])
    npairs = cfg.num_layers // 2
    mask = (jnp.ones((cfg.num_layers,), jnp.float32)
            if layer_mask is None else layer_mask.astype(jnp.float32))
    mask = mask.reshape(npairs, 2)

    def body(x, scanned):
        mp, sp, gate = scanned
        dm, _ = mlstm_apply(mp, cfg, x)
        x = x + gate[0].astype(x.dtype) * dm
        ds, _ = slstm_apply(sp, cfg, x)
        x = x + gate[1].astype(x.dtype) * ds
        return constrain(x), None

    if remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (params["mlstm"], params["slstm"], mask))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def logits_fn(params, cfg, hidden):
    return (hidden @ unembed_matrix(params, cfg)).astype(jnp.float32)


def decode_init(params, cfg, batch: int, seq_len: int, *, window=None):
    npairs = cfg.num_layers // 2

    def stack(make):
        st = make(cfg, batch)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (npairs,) + a.shape), st)

    return {"mlstm": stack(mlstm_state_init), "slstm": stack(slstm_state_init),
            "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, cfg, cache, tokens, pos, *, layer_mask=None, window=None):
    x = params["embed"]["emb"][tokens]
    npairs = cfg.num_layers // 2
    mask = (jnp.ones((cfg.num_layers,), jnp.float32)
            if layer_mask is None else layer_mask.astype(jnp.float32)).reshape(npairs, 2)

    def body(x, scanned):
        mp, sp, ms, ss, gate = scanned
        dm, ms = mlstm_decode(mp, cfg, x, ms)
        x = x + gate[0].astype(x.dtype) * dm
        ds, ss = slstm_decode(sp, cfg, x, ss)
        x = x + gate[1].astype(x.dtype) * ds
        return x, (ms, ss)

    x, (ms, ss) = jax.lax.scan(
        body, x, (params["mlstm"], params["slstm"], cache["mlstm"], cache["slstm"], mask))
    new_cache = {"mlstm": ms, "slstm": ss, "pos": cache["pos"] + 1}
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, x), new_cache
