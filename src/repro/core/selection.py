"""Dual-selection strategies (paper §4.3): choose, per round, (a) which
layer-wise model each device trains and (b) which devices participate.

``MarlSelector`` is the paper's method: per-agent argmax-Q picks the model
action (action M = do not participate), then Top-K over the chosen Q values
picks the participants.  Baseline selectors implement the comparison arms
used in §5 (greedy energy-aware, random, static-by-tier).

All selectors run on the vectorized :class:`repro.core.fleet.FleetState`
engine (affordability masks and cost matrices are single batched kernel
evaluations, not per-device Python loops).  They still accept a plain
``Sequence[DeviceState]`` — :func:`as_fleet_state` converts through the
numpy float64 backend, which matches the scalar reference semantics
bit-for-bit, so legacy callers see identical decisions.

``local_epochs``/``batch_size`` are threaded through ``select`` so the
affordability mask prices exactly the round the simulation will charge
(defaults match the paper's §5 values).

The QMIX mixer's global state has two modes (``MarlSelector(state_mode=)``):

* ``"flat"`` — the per-agent observations concatenated, ``n_devices *
  OBS_DIM`` wide: the original formulation, kept bit-for-bit (the parity
  contract enforced by ``tests/test_factored_state.py``) but linear in
  fleet size in both mixer parameters and replay-buffer memory;
* ``"factored"`` — :func:`repro.core.fleet.fleet_summary`: a fixed-width,
  permutation-invariant fleet summary (battery/capability histograms,
  per-submodel affordability fractions from the model family's cost
  model, energy totals, round phase) whose width is INDEPENDENT of
  ``n_devices`` — the 4096+/1M-device scaling path (compact global
  summaries rather than per-client concatenation, after Zhang et al.,
  arXiv:2201.02932).

``resolve_state_mode`` maps the config-level ``"auto"`` to flat at or
below :data:`FACTORED_AUTO_N` devices (small fleets keep the legacy
trajectory bit-for-bit) and factored at scale.

The QMIX *mixer* has the same two-regime split (``MarlSelector(
mixer_mode=)``): ``"flat"`` keeps the original per-agent hypernet mixer
(one weight row per agent — bit-for-bit legacy, O(n) parameters and
replay), ``"set"`` swaps in the permutation-invariant set/attention
mixer (:func:`repro.core.marl.networks.set_mixer_apply`) plus
sampled-agent episode traces capped at ``agent_budget`` agents, making
QMIX *training* cost independent of fleet size.  ``resolve_mixer_mode``
maps ``"auto"`` across the same :data:`FACTORED_AUTO_N` boundary.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import DeviceState
from repro.core.fleet import (FleetState, as_fleet_state, fleet_affordability,
                              fleet_affordability_jit, fleet_charge,
                              fleet_cost_matrix, fleet_cost_matrix_jit,
                              fleet_is_jax, fleet_summary, fleet_topk_mask,
                              summary_width)
from repro.core.marl.qmix import QmixConfig, QmixLearner, epsilon


@dataclasses.dataclass
class Selection:
    participants: List[int]          # device indices
    model_choice: List[int]          # per-device submodel index (-1 = none)
    q_values: Optional[np.ndarray] = None

    def __post_init__(self):
        # ``model_choice`` must cover the whole fleet: the engine indexes
        # it by raw device id, so a short list silently mis-indexes (or
        # IndexErrors rounds later).  Participants out of its range are a
        # selector bug — fail at construction, where the stack still
        # points at the offender.
        n = len(self.model_choice)
        bad = [int(i) for i in self.participants
               if not 0 <= int(i) < n]
        if bad:
            raise ValueError(
                f"Selection.participants {bad} out of range for "
                f"model_choice of length {n} (model_choice must have one "
                f"entry per fleet device)")


class SelectorBase:
    name = "base"

    def select(self, devices, round_idx: int, k: int,
               model_sizes: Sequence[float],
               model_fractions: Sequence[float],
               local_epochs: int = 5, batch_size: int = 32,
               budget_left: Optional[float] = None) -> Selection:
        """``budget_left`` (scalar J) is the remaining fleet-wide energy
        budget under a repro.energy global-budget scenario — EVERY
        selector must refuse per-device actions whose cost alone exceeds
        it (the engine additionally trims cohorts whose cumulative cost
        would overrun).  ``None`` = no budget, the default decision path
        bit-for-bit."""
        raise NotImplementedError

    def observe_reward(self, reward: float,
                       sim_time: Optional[float] = None):
        """Credit the reward for the most recent ``select``.

        Under the event-driven engine this fires at EVENT time — when the
        dispatch's cohort of updates has arrived and been aggregated — with
        ``sim_time`` the fleet's virtual clock at that moment, rather than
        at a synchronous round barrier."""
        pass

    def state_dict(self) -> dict:
        """Checkpointable snapshot of selector-internal mutable state.

        Baselines with a numpy Generator persist its bit_generator state;
        stateless selectors persist nothing.  Restoring into a freshly
        constructed selector of the same config must reproduce the
        uninterrupted decision sequence bit-for-bit."""
        rng = getattr(self, "rng", None)
        if rng is not None:
            return {"kind": "rng", "rng": rng.bit_generator.state}
        return {"kind": "stateless"}

    def load_state_dict(self, state: dict) -> None:
        kind = state.get("kind")
        if kind == "rng":
            self.rng.bit_generator.state = state["rng"]
        elif kind != "stateless":
            raise ValueError(f"selector snapshot kind {kind!r} does not "
                             f"match selector {self.name!r}")


def obs_vector(dev: DeviceState, round_idx: int, n_rounds: int) -> np.ndarray:
    """Paper Eq. 9: s_t^n = [L_n, C_n, E_n, t] (+ last-round latencies,
    §4.3.2), normalised to O(1) ranges.  Scalar reference for
    :func:`fleet_obs`."""
    return np.array([
        dev.data_size / 1000.0,
        dev.effective_compute(1.0) / 500.0,
        dev.remaining / dev.profile.battery,
        round_idx / max(n_rounds, 1),
        1.0 if dev.alive else 0.0,
    ], np.float32)


OBS_DIM = 5

#: largest fleet for which ``state_mode="auto"`` keeps the flat QMIX global
#: state; strictly above this the factored summary takes over (the boundary
#: is inclusive so documented <= 256-device workflows — e.g. the Fig. 6
#: 64/256 rows — keep their legacy bit-for-bit trajectories)
FACTORED_AUTO_N = 256

STATE_MODES = ("flat", "factored")


def resolve_state_mode(state_mode: str, n_agents: int) -> str:
    """Map a config-level state mode to a concrete one: ``"auto"`` keeps
    the bit-for-bit flat state at or below :data:`FACTORED_AUTO_N` agents
    and switches to the fixed-width factored summary above."""
    if state_mode == "auto":
        return "factored" if n_agents > FACTORED_AUTO_N else "flat"
    if state_mode in STATE_MODES:
        return state_mode
    raise ValueError(f"unknown state_mode {state_mode!r} "
                     f"(expected 'auto', 'flat' or 'factored')")


MIXER_MODES = ("flat", "set")

#: default sampled-agent budget for set-mixer replay: episode traces and
#: replay minibatches store at most this many agents per episode (uniform
#: without replacement, importance-reweighted through the mixer's logit
#: slot), so QMIX training memory/compute stop scaling with fleet size
SAMPLE_AGENT_BUDGET = 4096


def resolve_mixer_mode(mixer_mode: str, n_agents: int) -> str:
    """Map a config-level mixer mode to a concrete one: ``"auto"`` keeps
    the bit-for-bit flat hypernet mixer at or below
    :data:`FACTORED_AUTO_N` agents (the same inclusive boundary as
    :func:`resolve_state_mode`) and switches to the scale-free
    set/attention mixer above."""
    if mixer_mode == "auto":
        return "set" if n_agents > FACTORED_AUTO_N else "flat"
    if mixer_mode in MIXER_MODES:
        return mixer_mode
    raise ValueError(f"unknown mixer_mode {mixer_mode!r} "
                     f"(expected 'auto', 'flat' or 'set')")


def marl_state_dim(state_mode: str, n_agents: int, n_models: int) -> int:
    """QMIX mixer ``state_dim`` for a concrete state mode — ``n_agents *
    OBS_DIM`` flat, :func:`repro.core.fleet.summary_width` (independent of
    ``n_agents``) factored."""
    mode = resolve_state_mode(state_mode, n_agents)
    if mode == "factored":
        return summary_width(n_models)
    return n_agents * OBS_DIM


# jaxlint: allow(host-sync-in-hot-path) -- numpy float64 parity reference by design; fleet_obs_batch is the device-side twin
def fleet_obs(fleet: FleetState, round_idx: int, n_rounds: int) -> np.ndarray:
    """[n, OBS_DIM] float32 — vectorized :func:`obs_vector` over the fleet."""
    t = round_idx / max(n_rounds, 1)
    cols = np.stack([
        np.asarray(fleet.data_size, np.float64) / 1000.0,
        np.asarray(fleet.compute * fleet.mode_compute) / 500.0,
        np.asarray(fleet.remaining / fleet.battery),
        np.full(len(fleet), t),
        np.asarray(fleet.alive, np.float64),
    ], axis=1)
    return cols.astype(np.float32)


class MarlSelector(SelectorBase):
    """The paper's MARL-based dual-selection (QMIX, Fig. 3).

    ``state_mode="flat"`` (default) keeps the original ``n_devices *
    OBS_DIM`` mixer state bit-for-bit; ``"factored"`` swaps in the
    fixed-width :func:`repro.core.fleet.fleet_summary`, making
    ``learner.cfg.state_dim`` independent of fleet size (``"auto"``
    resolves by :func:`resolve_state_mode`).

    ``mixer_mode="flat"`` (default) keeps the per-agent hypernet mixer
    bit-for-bit; ``"set"`` swaps in the permutation-invariant
    set/attention mixer and caps the episode trace at ``agent_budget``
    uniformly-sampled agents (redrawn per episode, fixed within one so
    the training-time GRU unroll is consistent), making replay memory
    and the QMIX update independent of fleet size (``"auto"`` resolves
    by :func:`resolve_mixer_mode`).  ``select`` still acts on the FULL
    fleet either way — only the learning trace is sampled.
    """

    name = "marl"

    def __init__(self, n_devices: int, n_models: int, n_rounds: int,
                 seed: int = 0, state_mode: str = "flat",
                 mixer_mode: str = "flat",
                 agent_budget: int = SAMPLE_AGENT_BUDGET):
        self.n_models = n_models
        self.n_rounds = n_rounds
        self.state_mode = resolve_state_mode(state_mode, n_devices)
        self.mixer_mode = resolve_mixer_mode(mixer_mode, n_devices)
        self.agent_budget = int(agent_budget)
        self.n_sampled = (min(n_devices, self.agent_budget)
                          if self.mixer_mode == "set" else n_devices)
        cfg = QmixConfig(
            n_agents=n_devices, obs_dim=OBS_DIM, num_actions=n_models + 1,
            state_dim=marl_state_dim(self.state_mode, n_devices, n_models),
            eps_decay_rounds=max(10, n_rounds // 2),
            mixer_mode=self.mixer_mode)
        self.learner = QmixLearner(cfg, jax.random.PRNGKey(seed))
        self.key = jax.random.PRNGKey(seed + 1)
        self.hidden = self.learner.init_hidden()
        self.total_rounds = 0   # epsilon decays on TOTAL experience (across
                                # pre-training episodes), not per-episode
        # last round-pricing seen by select(); episode_arrays uses it to
        # price the terminal factored summary consistently
        self._last_pricing = None
        self._sample_rng = np.random.default_rng((seed, 0xA6E))
        self._ep_idx: Optional[np.ndarray] = None
        self._draw_agent_sample()
        # episode trace for the replay buffer
        self.ep_obs: List[np.ndarray] = []
        self.ep_state: List[np.ndarray] = []
        self.ep_actions: List[np.ndarray] = []
        self.ep_rewards: List[float] = []

    def _draw_agent_sample(self):
        """Redraw the episode's sampled-agent set (set-mixer mode only;
        uniform without replacement, so the self-normalised importance
        weights the mixer consumes are equal — log-weights zero)."""
        n = self.learner.cfg.n_agents
        if self.mixer_mode == "set" and self.n_sampled < n:
            self._ep_idx = np.sort(self._sample_rng.choice(
                n, self.n_sampled, replace=False))
        else:
            self._ep_idx = None

    def _trace_agents(self, arr: np.ndarray) -> np.ndarray:
        """Cut a per-agent [n, ...] row down to the episode's sampled set."""
        return arr if self._ep_idx is None else arr[self._ep_idx]

    def reset_episode(self):
        self.hidden = self.learner.init_hidden()
        self._draw_agent_sample()
        self.ep_obs, self.ep_state = [], []
        self.ep_actions, self.ep_rewards = [], []

    def _state(self, fleet, obs, round_idx, model_sizes, model_fractions,
               local_epochs, batch_size, avail=None) -> np.ndarray:
        if self.state_mode == "factored":
            from repro.core.fleet import fleet_summary_jit
            fn = fleet_summary_jit if fleet_is_jax(fleet) else fleet_summary
            # jaxlint: allow(host-sync-in-hot-path) -- summary pulled once per select; it feeds the host-side replay buffer
            return np.asarray(fn(
                fleet, tuple(model_sizes), tuple(model_fractions), round_idx,
                self.n_rounds, local_epochs, batch_size,
                afford=avail), np.float32)
        return obs.reshape(-1)

    def select(self, devices, round_idx, k, model_sizes, model_fractions,
               local_epochs=5, batch_size=32, budget_left=None):
        fleet = as_fleet_state(devices)
        obs = fleet_obs(fleet, round_idx, self.n_rounds)
        self._last_pricing = (tuple(model_sizes), tuple(model_fractions),
                              local_epochs, batch_size)
        self.key, sub = jax.random.split(self.key)
        eps = epsilon(self.learner.cfg, self.total_rounds)
        self.total_rounds += 1
        # affordability action mask ("prevent selected devices from dropping
        # out of the FL process due to energy limitations", paper §4.2 Step
        # 3), priced at the round the simulation will actually charge; a
        # live global budget additionally masks actions it cannot cover
        aff = (fleet_affordability_jit if fleet_is_jax(fleet)
               else fleet_affordability)
        if budget_left is None:
            avail = aff(fleet, model_sizes, model_fractions, local_epochs,
                        batch_size)
        else:
            avail = aff(fleet, model_sizes, model_fractions, local_epochs,
                        batch_size, budget_left=float(budget_left))
        # factored mode reuses the mask — the dominant O(n*M) cost kernel
        # runs once per select, not once for the mask and once in the summary
        state = self._state(fleet, obs, round_idx, model_sizes,
                            model_fractions, local_epochs, batch_size,
                            avail=avail)
        actions_d, qv_d, self.hidden = self.learner.act(
            jnp.asarray(obs), self.hidden, sub, eps, jnp.asarray(avail))
        # jaxlint: allow(host-sync-in-hot-path) -- the one batched pull per select: actions + Q values + liveness
        actions, qv, alive = jax.device_get((actions_d, qv_d, fleet.alive))
        # dead devices never participate
        actions = np.where(alive, actions, self.n_models)
        willing = np.flatnonzero(actions < self.n_models)
        # Top-K over Q values among willing agents (paper §4.3.3)
        order = willing[np.argsort(-qv[willing], kind="stable")]
        chosen = [int(i) for i in order[:k]]
        model_choice = [-1] * len(fleet)
        for i in chosen:
            model_choice[i] = int(actions[i])
        # learning trace: full fleet in flat mode, the episode's sampled
        # agent set under the set mixer (replay memory stays bounded)
        self.ep_obs.append(self._trace_agents(obs))
        self.ep_state.append(state)
        self.ep_actions.append(self._trace_agents(actions).copy())
        return Selection(participants=chosen, model_choice=model_choice,
                         q_values=qv)

    def observe_reward(self, reward: float,
                       sim_time: Optional[float] = None):
        # QMIX is time-index-agnostic: only the reward ORDER (aligned with
        # select calls by the engine's in-dispatch-order commits) matters
        self.ep_rewards.append(float(reward))

    def episode_arrays(self, final_devices, round_idx):
        fleet = as_fleet_state(final_devices)
        final_obs_full = fleet_obs(fleet, round_idx, self.n_rounds)
        obs = np.stack(self.ep_obs + [self._trace_agents(final_obs_full)])
        if self.state_mode == "factored":
            if self._last_pricing is None:
                # both modes reject zero-step episodes (flat fails in the
                # np.stack below); fail with the clearer message here
                raise ValueError("episode_arrays() before any select(): "
                                 "no round pricing to build the terminal "
                                 "factored summary from")
            sizes, fracs, epochs, batch = self._last_pricing
            final_state = self._state(fleet, final_obs_full, round_idx,
                                      sizes, fracs, epochs, batch)
            state = np.stack(self.ep_state + [final_state])
        elif self._ep_idx is not None:
            # sampled trace + flat state: the mixer state stays the FULL
            # fleet's concatenated observations (recorded per select);
            # only the per-agent obs/action columns were subsampled
            state = np.stack(self.ep_state
                             + [final_obs_full.reshape(-1)])
        else:
            state = obs.reshape(obs.shape[0], -1)
        # jaxlint: allow(host-sync-in-hot-path) -- end-of-episode flush: the reward buffer is a Python-float list
        rewards = np.asarray(self.ep_rewards, np.float32)
        return obs, state, np.stack(self.ep_actions), rewards

    def state_dict(self) -> dict:
        """Full mid-episode snapshot: QMIX learner (online/target/opt/
        update counter), act key, GRU hidden, epsilon schedule position,
        the episode trace, and both host RNGs — everything needed so a
        resumed run's decision stream is bit-for-bit the uninterrupted
        one."""
        return {
            "kind": "marl",
            "learner": self.learner.state_dict(),
            "key": self.key,
            "hidden": self.hidden,
            "total_rounds": self.total_rounds,
            "last_pricing": self._last_pricing,
            "sample_rng": self._sample_rng.bit_generator.state,
            "ep_idx": self._ep_idx,
            "ep_obs": list(self.ep_obs),
            "ep_state": list(self.ep_state),
            "ep_actions": list(self.ep_actions),
            "ep_rewards": list(self.ep_rewards),
        }

    # jaxlint: allow(host-sync-in-hot-path) -- one-time resume from a
    # checkpoint; restored leaves are host numpy already
    def load_state_dict(self, state: dict) -> None:
        if state.get("kind") != "marl":
            raise ValueError("checkpoint selector snapshot is "
                             f"{state.get('kind')!r}, not 'marl' — selector "
                             "config drifted since save")
        self.learner.load_state_dict(state["learner"])
        self.key = jnp.asarray(state["key"])
        self.hidden = jnp.asarray(state["hidden"])
        self.total_rounds = int(state["total_rounds"])
        lp = state["last_pricing"]
        self._last_pricing = tuple(lp) if lp is not None else None
        self._sample_rng.bit_generator.state = state["sample_rng"]
        ep_idx = state["ep_idx"]
        self._ep_idx = None if ep_idx is None else np.asarray(ep_idx)
        self.ep_obs = list(state["ep_obs"])
        self.ep_state = list(state["ep_state"])
        self.ep_actions = list(state["ep_actions"])
        self.ep_rewards = [float(r) for r in state["ep_rewards"]]


class GreedySelector(SelectorBase):
    """Energy-aware greedy (the paper's baseline adaptation): each device
    picks the LARGEST submodel it can afford this round; Top-K by remaining
    energy."""

    name = "greedy"

    def select(self, devices, round_idx, k, model_sizes, model_fractions,
               local_epochs=5, batch_size=32, budget_left=None):
        fleet = as_fleet_state(devices)
        M = len(model_sizes)
        costs = (fleet_cost_matrix_jit if fleet_is_jax(fleet)
                 else fleet_cost_matrix)
        _, _, e_tra, e_com = costs(
            fleet, model_sizes, model_fractions, local_epochs, batch_size)
        # jaxlint: allow(host-sync-in-hot-path) -- one batched pull per select: costs + energy + liveness for the host argsort
        e_need, remaining, alive = jax.device_get(
            (e_tra + e_com, fleet.remaining, fleet.alive))
        afford = (e_need < remaining[:, None]) & alive[:, None]   # [n, M]
        if budget_left is not None:
            # global-budget hard constraint: never pick a submodel the
            # remaining fleet-wide budget cannot pay for
            afford &= e_need <= float(budget_left)
        # largest affordable submodel per device (-1 if none)
        best = np.where(afford.any(axis=1),
                        M - 1 - np.argmax(afford[:, ::-1], axis=1), -1)
        cand = np.flatnonzero(best >= 0)
        order = cand[np.argsort(-remaining[cand], kind="stable")]
        chosen = [int(i) for i in order[:k]]
        model_choice = [-1] * len(fleet)
        for i in chosen:
            model_choice[i] = int(best[i])
        return Selection(participants=chosen, model_choice=model_choice)


def _budget_filter(fleet, chosen, model_choice, model_sizes, model_fractions,
                   local_epochs, batch_size, budget_left):
    """Drop already-chosen (device, model) picks whose cost alone exceeds
    the remaining fleet-wide budget (repro.energy global-budget hard
    constraint) — the post-hoc arm for selectors that pick models without
    pricing them (random/static).  RNG draw order is untouched, so runs
    without a budget are bit-for-bit unaffected."""
    costs = (fleet_cost_matrix_jit if fleet_is_jax(fleet)
             else fleet_cost_matrix)
    _, _, e_tra, e_com = costs(
        fleet, model_sizes, model_fractions, local_epochs, batch_size)
    # jaxlint: allow(host-sync-in-hot-path) -- budget-scenario-only pull: per-pick costs for the hard-constraint filter
    e_need = np.asarray(jax.device_get(e_tra + e_com))
    kept = [i for i in chosen
            if e_need[i, model_choice[i]] <= float(budget_left)]
    out_choice = [-1] * len(model_choice)
    for i in kept:
        out_choice[i] = model_choice[i]
    return kept, out_choice


class RandomSelector(SelectorBase):
    """Vanilla-FL-style: uniform random K clients, random affordable model."""

    name = "random"

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def select(self, devices, round_idx, k, model_sizes, model_fractions,
               local_epochs=5, batch_size=32, budget_left=None):
        fleet = as_fleet_state(devices)
        # jaxlint: allow(host-sync-in-hot-path) -- numpy baseline selector: one liveness pull per round
        alive = [int(i) for i in np.flatnonzero(np.asarray(fleet.alive))]
        self.rng.shuffle(alive)
        chosen = alive[:k]
        model_choice = [-1] * len(fleet)
        for i in chosen:
            model_choice[i] = int(self.rng.integers(0, len(model_sizes)))
        if budget_left is not None:
            chosen, model_choice = _budget_filter(
                fleet, chosen, model_choice, model_sizes, model_fractions,
                local_epochs, batch_size, budget_left)
        return Selection(participants=chosen, model_choice=model_choice)


def fleet_obs_batch(fleet: FleetState, round_idx, n_rounds: int):
    """Backend-generic (jit/shard-friendly) twin of :func:`fleet_obs` —
    jnp on the jax backend, so the observation matrix is computed where the
    fleet lives instead of gathering to the host.  :func:`fleet_obs` stays
    the numpy float64 parity reference."""
    xp = jnp if fleet_is_jax(fleet) else np
    dt = fleet.remaining.dtype
    t = xp.asarray(round_idx, dt) / max(int(n_rounds), 1)
    cols = xp.stack([
        fleet.data_size.astype(dt) / 1000.0,
        fleet.compute * fleet.mode_compute / 500.0,
        fleet.remaining / fleet.battery,
        xp.full((len(fleet),), t, dt),
        fleet.alive.astype(dt),
    ], axis=1)
    return cols.astype(jnp.float32 if xp is jnp else np.float32)


def dual_selection_energy_step(agent_params, hidden, fleet: FleetState,
                               model_sizes, model_fractions, k: int,
                               round_idx=0, n_rounds: int = 1,
                               local_epochs: int = 5, batch_size: int = 32,
                               budget_left=None, charge_profile=None,
                               sim_time=0.0, charge_dt: float = 0.0,
                               energy_scale: float = 1.0,
                               avail_mask=None):
    """One greedy (evaluation-mode) MARL dual-selection + energy step as a
    SINGLE jittable program — the data-parallel hot path for sharded
    fleets (``benchmarks/fleet_shard_bench.py``).

    obs → shared-weight agent Q (vmapped over the fleet axis) →
    affordability-masked argmax actions → Top-K participant cut over
    chosen Qs → Eq. 5/7 energy charge → factored summary.  Every stage is
    elementwise or a small reduction over the ``[n]`` axis, so under a
    :func:`repro.sharding.fleet.shard_fleet` placement the whole step runs
    data-parallel with one ``summary_width``-sized all-reduce at the end —
    no full-fleet gather, no host sync.

    The repro.energy scenario hooks keep that shape: ``budget_left``
    (scalar J) tightens the affordability mask, ``avail_mask`` ([n] bool —
    a precomputed availability/participation wave) gates willingness
    exactly like liveness, and ``charge_profile`` (a registered
    ``ChargeProfile``, static) applies ``charge_dt`` sim-seconds of
    harvesting after the charge step, capped at ``battery * energy_scale``
    — all pure elementwise ``[n]`` ops, so the all-reduce count is
    unchanged.  Defaults (None/0) trace the exact pre-scenario program.

    Returns ``(new_fleet, new_hidden, participants[n] bool, actions[n],
    summary)``.
    """
    from repro.core.marl.networks import agent_step
    xp = jnp if fleet_is_jax(fleet) else np
    M = len(model_sizes)
    obs = fleet_obs_batch(fleet, round_idx, n_rounds)
    q, h = agent_step(agent_params, obs, hidden)              # [n, M+1]
    avail = fleet_affordability(fleet, model_sizes, model_fractions,
                                local_epochs, batch_size,
                                budget_left=budget_left)
    actions = xp.argmax(xp.where(avail, q, -1e9), axis=-1)
    q_chosen = xp.take_along_axis(q, actions[:, None], axis=-1)[:, 0]
    willing = (actions < M) & fleet.alive
    if avail_mask is not None:
        willing = willing & avail_mask
    scores = xp.where(willing, q_chosen.astype(fleet.remaining.dtype),
                      -xp.inf)
    participants = fleet_topk_mask(scores, k)
    m_idx = xp.clip(actions, 0, M - 1)
    _, _, e_tra, e_com = fleet_cost_matrix(
        fleet, model_sizes, model_fractions, local_epochs, batch_size)
    need = xp.take_along_axis(e_tra + e_com, m_idx[:, None], axis=-1)[:, 0]
    fleet, ok = fleet_charge(fleet, need, participants)
    if charge_profile is not None and charge_dt > 0:
        rate = charge_profile.rate(fleet, sim_time + 0.5 * charge_dt)
        cap = fleet.battery * energy_scale
        topped = xp.minimum(fleet.remaining + rate * charge_dt,
                            xp.maximum(cap, fleet.remaining))
        fleet = fleet.replace(remaining=xp.where(fleet.alive, topped,
                                                 fleet.remaining))
    # NOTE: the summary's affordability block re-prices the POST-charge
    # fleet (it describes the state the next decision sees), so the mask
    # above cannot be reused here; XLA CSEs the shared cost subexpressions
    # within this single program
    summary = fleet_summary(fleet, model_sizes, model_fractions, round_idx,
                            n_rounds, local_epochs, batch_size)
    return fleet, h, participants & ok, actions, summary


dual_selection_energy_step_jit = jax.jit(
    dual_selection_energy_step,
    static_argnames=("k", "n_rounds", "charge_profile", "charge_dt",
                     "energy_scale"))


class StaticTierSelector(SelectorBase):
    """HeteroFL-style static assignment: submodel fixed by device tier."""

    name = "static"
    TIER_MODEL = {"small": 0, "medium": 1, "large": 3}

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def select(self, devices, round_idx, k, model_sizes, model_fractions,
               local_epochs=5, batch_size=32, budget_left=None):
        fleet = as_fleet_state(devices)
        # jaxlint: allow(host-sync-in-hot-path) -- numpy baseline selector: one liveness pull per round
        alive = [int(i) for i in np.flatnonzero(np.asarray(fleet.alive))]
        self.rng.shuffle(alive)
        chosen = alive[:k]
        model_choice = [-1] * len(fleet)
        for i in chosen:
            m = self.TIER_MODEL[fleet.tiers[i]]
            model_choice[i] = min(m, len(model_sizes) - 1)
        if budget_left is not None:
            chosen, model_choice = _budget_filter(
                fleet, chosen, model_choice, model_sizes, model_fractions,
                local_epochs, batch_size, budget_left)
        return Selection(participants=chosen, model_choice=model_choice)
