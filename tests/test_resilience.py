"""Crash-safe fleet service (tentpole): kill-and-resume bit-for-bit
parity across engine modes, seeded fault injection accounting,
poisoned-delta quarantine, and graceful-degradation terminal markers.

The contract under test: a run that is killed after a checkpoint save
and resumed from disk must produce byte-identical history and global
params to an uninterrupted run of the same config — RNG streams, the
async event heap, MARL learner state and replay included.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointHalt
from repro.core.selection import GreedySelector
from repro.fl import (FaultEvent, FaultPlan, FLConfig, RoundEngine,
                      run_simulation)
from repro.fl import server as fl_server
from repro.fl.spec import ResilienceSpec, SimulationSpec
from repro.models import cnn

SMALL = dict(n_devices=8, n_rounds=6, participation=0.5, local_epochs=1,
             batch_size=8, n_train=256, hw=8, seed=3)
# faults must land on live, in-flight devices to exercise anything: give
# the fleet healthy batteries and full participation
CHURN = dict(SMALL, participation=1.0, energy_scale=50.0, n_rounds=8,
             engine_mode="async", async_time_horizon=400.0,
             fault_crashes=1, fault_timeouts=2, fault_disconnects=1,
             fault_corrupts=3)


def _canon(x):
    if isinstance(x, (np.ndarray, jax.Array)):
        a = np.asarray(x)
        return ("arr", str(a.dtype), a.tobytes())
    if isinstance(x, dict):
        return {k: _canon(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_canon(v) for v in x]
    return x


def _assert_bit_identical(ref, res):
    assert set(ref) == set(res)
    for k in ref:
        if k in ("wall_clock", "params"):
            continue                     # wall time is the one allowed diff
        assert _canon(ref[k]) == _canon(res[k]), f"hist[{k!r}] diverged"
    ra = jax.tree.leaves(ref["params"])
    rb = jax.tree.leaves(res["params"])
    assert len(ra) == len(rb)
    for a, b in zip(ra, rb):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def _kill_and_resume(cfg, tmp_path, halt_after=1, every=2):
    """Reference run, then a checkpointed run killed after ``halt_after``
    saves, then a resumed run; assert resumed == reference bit-for-bit."""
    ref = run_simulation(cfg)
    ck = dataclasses.replace(cfg, checkpoint_dir=str(tmp_path / "ck"),
                             checkpoint_every=every)
    with pytest.raises(CheckpointHalt):
        run_simulation(ck, halt_after_saves=halt_after)
    res = run_simulation(dataclasses.replace(ck, resume=True))
    _assert_bit_identical(ref, res)
    return ref


# ----------------------------------------------------------------------
# kill-and-resume parity
# ----------------------------------------------------------------------

def test_sync_marl_kill_resume_parity(tmp_path):
    # halt_after=4 lands the kill inside episode 1, so the resume has to
    # restore mid-episode MARL state (learner, replay, RNG streams) too
    cfg = FLConfig(**SMALL, marl_episodes=2)
    _kill_and_resume(cfg, tmp_path, halt_after=4)


def test_async_greedy_kill_resume_parity(tmp_path):
    cfg = FLConfig(**SMALL, engine_mode="async", selector="greedy",
                   client_executor="perclient")
    _kill_and_resume(cfg, tmp_path)


def test_async_faulted_marl_kill_resume_parity(tmp_path):
    # the acceptance case: checkpoint + kill + resume with the fault
    # timeline (reaps, rejoins, armed corruptions) mid-flight
    cfg = FLConfig(**CHURN)
    ref = _kill_and_resume(cfg, tmp_path, halt_after=2)
    assert ref["faults"]["events"], "churn config must actually fault"


@pytest.mark.slow
def test_async_set_mixer_batched_kill_resume_parity(tmp_path):
    cfg = FLConfig(**SMALL, engine_mode="async", client_executor="batched",
                   mixer_mode="set", marl_agent_budget=4, marl_episodes=2)
    _kill_and_resume(cfg, tmp_path, halt_after=3)


def test_resume_rejects_config_drift(tmp_path):
    cfg = FLConfig(**SMALL, selector="greedy",
                   checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=2)
    with pytest.raises(CheckpointHalt):
        run_simulation(cfg, halt_after_saves=1)
    drifted = dataclasses.replace(cfg, resume=True, seed=cfg.seed + 1)
    with pytest.raises(ValueError, match="refusing to resume"):
        run_simulation(drifted)


# ----------------------------------------------------------------------
# fault injection: plan + accounting
# ----------------------------------------------------------------------

def test_fault_plan_is_seed_deterministic():
    a = FaultPlan.sample(16, 100.0, crashes=2, timeouts=2, corrupts=2, seed=7)
    b = FaultPlan.sample(16, 100.0, crashes=2, timeouts=2, corrupts=2, seed=7)
    c = FaultPlan.sample(16, 100.0, crashes=2, timeouts=2, corrupts=2, seed=8)
    assert a.events == b.events and a.events != c.events
    assert all(0.0 < e.time < 100.0 for e in a.events)


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan(events=(FaultEvent(time=1.0, kind="gremlin", device=0),))
    with pytest.raises(ValueError, match="corrupt payload"):
        FaultPlan(events=(FaultEvent(time=1.0, kind="corrupt", device=0,
                                     payload="zero"),))
    with pytest.raises(ValueError, match="horizon"):
        FaultPlan.sample(4, 0.0, crashes=1)
    assert FaultPlan.from_config(FLConfig()) is None


def test_faults_require_async_engine():
    cfg = FLConfig(**SMALL, selector="greedy", fault_crashes=1,
                   fault_horizon=100.0)
    with pytest.raises(ValueError, match="async"):
        RoundEngine(cfg, GreedySelector())


def test_fault_accounting_is_complete():
    """Every planned event must surface in hist["faults"] with an
    outcome, and every poisoned delta must be quarantined — the global
    params stay finite no matter what the churn injects."""
    cfg = FLConfig(**CHURN, selector="greedy")
    plan = FaultPlan.from_config(cfg)
    hist = run_simulation(cfg)
    faults = hist["faults"]
    injected = [e for e in faults["events"] if e["injected"]]
    assert len(injected) == len(plan)
    assert all("outcome" in e for e in faults["events"])
    want = sorted((e.time, e.kind, e.device) for e in plan.events)
    got = sorted((e["time"], e["kind"], e["device"]) for e in injected)
    assert got == want
    n_poisoned = sum(1 for e in faults["events"]
                     if e.get("outcome") == "poisoned")
    assert faults["n_quarantined"] == n_poisoned == len(faults["quarantined"])
    assert n_poisoned > 0, "churn config must exercise the quarantine path"
    assert faults["n_reaped"] == sum(hist["lost"])
    assert faults["n_reaped"] > 0, "churn config must exercise reaping"
    for leaf in jax.tree.leaves(hist["params"]):
        assert np.isfinite(np.asarray(leaf)).all()
    assert hist["terminated"]["lost"] == faults["n_reaped"]


def test_all_in_flight_dead_terminates_with_marker():
    """Regression: crashing the whole fleet mid-first-wave used to leave
    completions that never arrive; now reaps reclaim the window and the
    run ends with an explicit ``fleet_dead`` terminal marker."""
    cfg = FLConfig(**dict(SMALL, participation=1.0), energy_scale=50.0,
                   engine_mode="async", selector="greedy",
                   async_time_horizon=400.0)
    plan = FaultPlan(events=tuple(
        FaultEvent(time=1.0 + 0.01 * i, kind="crash", device=i)
        for i in range(cfg.n_devices)))
    hist = RoundEngine(cfg, GreedySelector(), fault_plan=plan).run()
    assert hist["terminated"]["reason"] == "fleet_dead"
    mid = sum(1 for e in hist["faults"]["events"]
              if e["outcome"] == "crash_mid_task")
    assert mid > 0 and hist["faults"]["n_reaped"] == mid
    assert not hist["alive"] or hist["alive"][-1] == 0


# ----------------------------------------------------------------------
# quarantine at the aggregation layer
# ----------------------------------------------------------------------

def _params():
    return cnn.init(jax.random.PRNGKey(0), num_classes=10, width_mult=0.25)


@pytest.mark.parametrize("poison", [float("nan"), float("inf"), 1e30])
def test_sliced_aggregation_quarantines_bad_delta(poison):
    p = _params()
    good = jax.tree.map(lambda a: jnp.full_like(a, 1e-3), p)
    bad = jax.tree.map(lambda a: jnp.full_like(a, poison), p)
    out, valid = fl_server.aggregate_sliced(p, [good, bad], [1.0, 1.0],
                                            with_stats=True)
    valid = np.asarray(valid)
    assert valid.tolist() == [True, False]
    ref = fl_server.aggregate_sliced(p, [good], [1.0])
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_drfl_aggregation_quarantines_bad_delta():
    p = _params()
    good = jax.tree.map(lambda a: jnp.full_like(a, 1e-3), p)
    bad = jax.tree.map(lambda a: jnp.full_like(a, jnp.nan), p)
    out, valid = fl_server.aggregate_drfl(p, [good, bad], [0, 0], [1.0, 1.0],
                                          with_stats=True)
    assert np.asarray(valid).tolist() == [True, False]
    ref = fl_server.aggregate_drfl(p, [good], [0], [1.0])
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    for leaf in jax.tree.leaves(out):
        assert np.isfinite(np.asarray(leaf)).all()


def test_zero_survivor_round_leaves_params_unchanged():
    p = _params()
    bad = jax.tree.map(lambda a: jnp.full_like(a, jnp.nan), p)
    out, valid = fl_server.aggregate_sliced(p, [bad, bad], [1.0, 1.0],
                                            with_stats=True)
    assert not np.asarray(valid).any()
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(p)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------
# spec surface
# ----------------------------------------------------------------------

def test_resilience_spec_round_trips_through_flat():
    cfg = FLConfig(**SMALL, selector="greedy", engine_mode="async",
                   async_time_horizon=200.0, fault_crashes=2,
                   fault_horizon=100.0, fault_seed=9,
                   checkpoint_dir="/tmp/ck", checkpoint_every=4,
                   checkpoint_keep=5, task_deadline_factor=3.0)
    spec = SimulationSpec.from_flat(cfg)
    assert spec.resilience.fault_crashes == 2
    assert spec.resilience.n_faults() == 2
    flat = spec.to_flat()
    for f in ("fault_crashes", "fault_horizon", "fault_seed",
              "checkpoint_dir", "checkpoint_every", "checkpoint_keep",
              "task_deadline_factor"):
        assert getattr(flat, f) == getattr(cfg, f)


def test_resilience_spec_validation():
    with pytest.raises(ValueError, match="task_deadline_factor"):
        ResilienceSpec(task_deadline_factor=1.0)
    with pytest.raises(ValueError, match="resume"):
        ResilienceSpec(resume=True)
    with pytest.raises(ValueError, match="async"):
        SimulationSpec.from_flat(FLConfig(fault_crashes=1,
                                          fault_horizon=50.0))
