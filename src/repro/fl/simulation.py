"""DR-FL federated simulation (paper §4.2 workflow, Steps 1–5).

One ``run_simulation`` call reproduces one cell of the paper's experiments:
a fleet of heterogeneous battery-powered devices trains a shared layer-wise
global model under an energy budget, with the configured dual-selection
strategy.  Returns a full history for the benchmark harnesses (accuracy per
exit per round, remaining energy, running time, fleet survival).

The fleet lives in the vectorized :class:`repro.core.fleet.FleetState`
engine (jax backend): per-round selection masks, Eq. 5/7 cost evaluation,
and battery charging are a few jitted batched kernels, so fleets of 256+
devices (RQ3 / Fig. 6) cost the same per-round Python overhead as 10.

Method arms:
    method="drfl"      selector in {marl, greedy, random, static}
    method="heterofl"  (greedy energy-aware model choice per the paper's
                        fair-comparison adaptation)
    method="scalefl"   (same greedy adaptation)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fleet import (FleetState, fleet_charge_jit, fleet_connect,
                              fleet_cost_matrix_jit, fleet_disconnect,
                              fleet_total_remaining, make_fleet_state)
from repro.core.selection import (GreedySelector, MarlSelector, RandomSelector,
                                  SelectorBase, StaticTierSelector)
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import synthetic_image_dataset
from repro.fl import client as fl_client
from repro.fl import server as fl_server
from repro.models import cnn


@dataclasses.dataclass
class FLConfig:
    n_devices: int = 40
    n_rounds: int = 30
    participation: float = 0.10         # paper: 10% per round
    local_epochs: int = 5               # paper §5
    batch_size: int = 32                # paper §5
    lr: float = 0.05                    # paper §5
    alpha: float = 0.5                  # Dirichlet non-IID
    num_classes: int = 10
    n_train: int = 4000
    n_val_fraction: float = 0.04        # paper Table 2 optimum
    noise: float = 1.0
    hw: int = 16                        # image size (CPU budget: 16x16)
    width_mult: float = 0.25            # CNN slimming for CPU-budget runs
    seed: int = 0
    method: str = "drfl"                # drfl | heterofl | scalefl
    selector: str = "marl"              # marl | greedy | random | static
    reward_weights: tuple = (1000.0, 0.01, 1.0)
    marl_train_every: int = 2
    marl_updates_per_round: int = 2
    marl_episodes: int = 1              # selector pre-training episodes (the
                                        # reported run is the LAST episode)
    hotplug_round: int = 0              # paper §4.2: hot-plug devices join at
    hotplug_n: int = 0                  # this round with fresh batteries
    energy_scale: float = 1.0           # scales battery to stress budgets
    server_lr: float = 0.7              # damps layer-aligned update drift


def _make_selector(cfg: FLConfig, n_models: int) -> SelectorBase:
    if cfg.method in ("heterofl", "scalefl"):
        return GreedySelector()          # the paper's fair-comparison arm
    return {
        "marl": lambda: MarlSelector(cfg.n_devices + cfg.hotplug_n, n_models,
                                     cfg.n_rounds, cfg.seed),
        "greedy": lambda: GreedySelector(),
        "random": lambda: RandomSelector(cfg.seed),
        "static": lambda: StaticTierSelector(cfg.seed),
    }[cfg.selector]()


def run_simulation(cfg: FLConfig, verbose: bool = False) -> Dict:
    """Runs the FL simulation.  With ``marl_episodes > 1`` and the MARL
    selector, earlier episodes pre-train the QMIX policy (fresh fleet +
    global model each episode, persistent learner + replay buffer) and the
    LAST episode is reported — the CPU-scale analogue of the paper's long
    online runs."""
    selector = None
    buffer = None
    episodes = cfg.marl_episodes if (cfg.method == "drfl"
                                     and cfg.selector == "marl") else 1
    for ep in range(episodes):
        hist, selector, buffer = _run_once(
            cfg, verbose and ep == episodes - 1, selector, buffer,
            seed_offset=ep)
    return hist


def _run_once(cfg: FLConfig, verbose, selector=None, buffer=None,
              seed_offset: int = 0):
    key = jax.random.PRNGKey(cfg.seed)

    # --- data: synthetic CIFAR-like, Dirichlet non-IID split ---------------
    x, y = synthetic_image_dataset(cfg.n_train, cfg.num_classes, hw=cfg.hw,
                                   noise=cfg.noise, seed=cfg.seed)
    n_val = max(64, int(cfg.n_val_fraction * cfg.n_train))
    x_val, y_val = x[:n_val], y[:n_val]          # server-side validation set
    x_tr, y_tr = x[n_val:], y[n_val:]
    parts = dirichlet_partition(y_tr, cfg.n_devices + cfg.hotplug_n,
                                cfg.alpha, cfg.seed)

    # --- fleet (vectorized SoA engine) + global model ----------------------
    n_total = cfg.n_devices + cfg.hotplug_n
    fleet = make_fleet_state(n_total, cfg.seed,
                             data_sizes=[len(p) for p in parts],
                             backend="jax")
    fleet = fleet.replace(remaining=fleet.battery * cfg.energy_scale)
    if cfg.hotplug_n:                   # hot-plug devices: not yet connected
        fleet = fleet_disconnect(fleet, cfg.n_devices)
    global_params = cnn.init(key, cfg.num_classes, width_mult=cfg.width_mult)
    M = cnn.num_submodels()
    # Energy/time accounting (Eq. 5 & 7) is calibrated to the PAPER-scale
    # backbone (full-width ResNet-18 on 32x32): the slim CNN is only the
    # CPU-budget compute proxy; batteries must see paper-scale costs for the
    # wooden-barrel dynamics to reproduce.
    ref_params = jax.eval_shape(
        lambda k: cnn.init(k, cfg.num_classes, width_mult=1.0),
        jax.random.PRNGKey(0))
    sizes = tuple(
        sum(x.size * x.dtype.itemsize
            for x in jax.tree.leaves(cnn.submodel_param_tree(ref_params, m)))
        for m in range(M))
    full_flops = cnn.flops_per_sample(M - 1, 32, 1.0)
    fractions = tuple(cnn.flops_per_sample(m, 32, 1.0) / full_flops
                      for m in range(M))
    if selector is None:
        selector = _make_selector(cfg, M)
    hist_hotplug_done = False

    marl = selector if isinstance(selector, MarlSelector) else None
    if marl:
        if buffer is None:
            from repro.core.marl.buffer import ReplayBuffer
            from repro.core.selection import OBS_DIM
            buffer = ReplayBuffer(64, cfg.n_rounds, cfg.n_devices, OBS_DIM,
                                  cfg.n_devices * OBS_DIM, cfg.seed)
        marl.reset_episode()

    hist = {"acc": [], "acc_mean": [], "energy": [], "round_time": [],
            "alive": [], "participants": [], "model_choices": [],
            "reward": [], "wall_clock": [], "dropouts": 0}
    prev_acc = float(np.mean(fl_server.evaluate(global_params, x_val, y_val)))
    e_prev = fleet_total_remaining(fleet)
    w1, w2, w3 = cfg.reward_weights
    rows = np.arange(n_total)

    for t in range(cfg.n_rounds):
        t0 = time.time()
        if (cfg.hotplug_n and not hist_hotplug_done
                and t >= cfg.hotplug_round):
            # paper Step 1 hot-plug: new devices connect, receive the global
            # model (implicit — clients always pull W_t), start with full
            # batteries
            fleet = fleet_connect(fleet, cfg.n_devices, cfg.energy_scale)
            hist_hotplug_done = True
        # Top-K budget tracks the CONNECTED fleet: once hot-plug devices
        # join, the participation fraction applies to all of them (computing
        # k from cfg.n_devices alone would silently shrink the effective
        # fraction after the join round).
        n_connected = cfg.n_devices + (cfg.hotplug_n if hist_hotplug_done
                                       else 0)
        k = max(1, int(round(cfg.participation * n_connected)))
        sel = selector.select(fleet, t, k, sizes, fractions,
                              cfg.local_epochs, cfg.batch_size)

        # --- vectorized energy accounting: price every (device, model) pair
        # in one jitted kernel, charge the whole fleet in one shot ----------
        choice = np.asarray(sel.model_choice, np.int64)
        active = choice >= 0
        m_idx = np.clip(choice, 0, M - 1)
        t_tra_m, t_com_m, e_tra_m, e_com_m = fleet_cost_matrix_jit(
            fleet, sizes, fractions, cfg.local_epochs, cfg.batch_size)
        need = np.asarray(e_tra_m + e_com_m)[rows, m_idx]
        t_cost = np.asarray(t_tra_m + t_com_m)[rows, m_idx]
        fleet, ok = fleet_charge_jit(fleet, jnp.asarray(need),
                                     jnp.asarray(active))
        ok = np.asarray(ok)
        hist["dropouts"] += int((active & ~ok).sum())
        survivors = active & ok
        t_round = float(t_cost[survivors].max()) if survivors.any() else 0.0

        # --- local training on the surviving participants ------------------
        deltas, idxs, weights = [], [], []
        for i in sel.participants:
            if not survivors[i]:
                continue                     # wasted energy, no contribution
            m = int(choice[i])
            xi = x_tr[parts[i]]
            yi = y_tr[parts[i]]
            if len(xi) == 0:
                # large-fleet Dirichlet splits can leave a device with no
                # local data: it still paid the round's (mostly comm)
                # energy but has nothing to contribute
                continue
            upd_seed = cfg.seed * 1000 + t * 100 + i
            if cfg.method == "drfl":
                d_, _ = fl_client.drfl_client_update(
                    global_params, m, xi, yi, epochs=cfg.local_epochs,
                    batch=cfg.batch_size, lr=cfg.lr, seed=upd_seed)
            elif cfg.method == "heterofl":
                d_, _ = fl_client.heterofl_client_update(
                    global_params, m, xi, yi, epochs=cfg.local_epochs,
                    batch=cfg.batch_size, lr=cfg.lr, seed=upd_seed)
            else:
                d_, _ = fl_client.scalefl_client_update(
                    global_params, m, xi, yi, epochs=cfg.local_epochs,
                    batch=cfg.batch_size, lr=cfg.lr, seed=upd_seed)
            deltas.append(d_)
            idxs.append(m)
            weights.append(float(len(xi)))

        if deltas:
            if cfg.method == "drfl":
                global_params = fl_server.aggregate_drfl(
                    global_params, deltas, idxs, weights,
                    server_lr=cfg.server_lr)
            else:
                global_params = fl_server.aggregate_sliced(
                    global_params, deltas, weights)

        accs = fl_server.evaluate(global_params, x_val, y_val)
        acc = float(np.mean(accs))
        e_now = fleet_total_remaining(fleet)
        reward = (w1 * (acc - prev_acc) - w2 * (e_prev - e_now)
                  - w3 * (t_round / 60.0))
        selector.observe_reward(reward)
        prev_acc, e_prev = acc, e_now

        if marl:
            if (t + 1) % cfg.marl_train_every == 0 and marl.ep_rewards:
                obs, state, actions, rewards = marl.episode_arrays(fleet, t + 1)
                buffer.add_episode(obs, state, actions, rewards)
                for _ in range(cfg.marl_updates_per_round):
                    batch = buffer.sample(marl.learner.cfg.batch_size)
                    if batch:
                        marl.learner.update(batch)

        alive_now = int(np.asarray(fleet.alive).sum())
        hist["acc"].append(np.asarray(accs))
        hist["acc_mean"].append(acc)
        hist["energy"].append(e_now)
        hist["round_time"].append(t_round)
        hist["alive"].append(alive_now)
        hist["participants"].append(list(sel.participants))
        hist["model_choices"].append([sel.model_choice[i] for i in sel.participants])
        hist["reward"].append(reward)
        hist["wall_clock"].append(time.time() - t0)
        if verbose:
            print(f"  round {t:3d}: acc={acc:.3f} exits="
                  f"{np.round(np.asarray(accs), 3)} alive={alive_now}"
                  f" energy={e_now:,.0f}J time={t_round:.1f}s r={reward:+.2f}")
        if alive_now == 0:
            break

    hist["final_acc"] = hist["acc"][-1] if hist["acc"] else np.zeros(4)
    hist["best_acc"] = (np.max(np.stack(hist["acc"]), axis=0)
                        if hist["acc"] else np.zeros(4))
    hist["params"] = global_params
    return hist, selector, buffer
