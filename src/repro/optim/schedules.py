"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(kind: str, base_lr: float, warmup_steps: int, total_steps: int):
    warmup_steps = max(1, warmup_steps)

    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = (s + 1.0) / warmup_steps   # nonzero LR at step 0
        if kind == "constant":
            decay = jnp.ones_like(s)
        elif kind == "linear":
            frac = (s - warmup_steps) / max(1, total_steps - warmup_steps)
            decay = jnp.clip(1.0 - frac, 0.0, 1.0)
        elif kind == "cosine":
            frac = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            raise ValueError(f"unknown schedule {kind!r}")
        return base_lr * jnp.where(s < warmup_steps, warm, decay)

    return fn
