"""Per-client loop vs bucketed-vmap executor: dispatch count + wall time.

Times the full per-round CLIENT-UPDATE + AGGREGATION hot path of a sync
DR-FL round (selection, energy accounting and evaluation excluded — they
are identical under both executors) for the two paths:

* ``perclient`` — one jit dispatch per participant per mini-batch, a
  per-client delta reduction and host loss sync, then the list-based
  ``aggregate_drfl`` (eager tree.map over ~90 leaves x N clients);
* ``batched``   — repro.fl.batch: participants bucketed by submodel index,
  each bucket ONE vmap(scan) jit program (<= 4 program executions per
  round, mini-batches gathered device-side), deltas fed STACKED into the
  one-program ``aggregate_drfl_stacked`` (Pallas ``layer_agg`` on TPU,
  fused einsum on CPU).

The configuration is the CPU-budget large-fleet regime (8x8 images,
0.06-width backbone, batch 8) where per-op overhead dominates per-step
FLOPs —
the regime ``client_executor="auto"`` picks the batched path for (on CPU,
execution of paper-width models is BLAS-bound and auto keeps them
per-client; see ``repro.fl.engine.resolve_client_executor``).

Repeat rounds keep the cohort membership fixed and rotate the per-round
client seeds (fresh schedules each round, same padded shapes), so the
timed rounds measure the steady state a long run amortizes to; program
compile counts are reported separately (``batched_compiles_warm``) —
cohort churn re-compiles only when a bucket's pow2-padded (P, T) signature
is new.

Every registered model family runs the same harness (``--family cnn``
limits the sweep) over its OWN corpus (``family.make_dataset`` — image
rows for cnn/mlp, token windows for the transformer); BENCH_client.json
records per-family medians with a ``family`` field per row — the CNN rows
keep the PR 3 emit names and configuration, so its numbers stay
regression-comparable.

    python -m benchmarks.client_bench                 # n=64/256/1024 sweep
    python -m benchmarks.client_bench --smoke         # n=64, 2 rounds (CI)
    python -m benchmarks.client_bench --family mlp    # one family only
    python -m benchmarks.client_bench --json OUT.json # record results

The ISSUE 3 acceptance targets >= 5x at n=256 on CPU with <= 4
client-update program executions per round.  The dispatch bound holds
everywhere; measured wall-time speedup on the 2-core container is ~2.5-4x
median (bursts to ~5.8x unloaded) — per-client execution there is already
op-work-bound inside XLA, so the remaining gap is arithmetic, not
dispatch.  BENCH_client.json records the medians for future PRs to
regress against.
"""
from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.data.partition import dirichlet_partition
from repro.fl import batch as fl_batch
from repro.fl import client as fl_client
from repro.fl import server as fl_server
from repro.models.family import get_family

FAMILIES = ("cnn", "mlp", "transformer")
PARTICIPATION = 0.1
EPOCHS = 2
BATCH = 8
LR = 0.05
HW = 8
WIDTH = 0.06
SERVER_LR = 0.7


def _setup(n: int, family: str = "cnn", seed: int = 0):
    fam = get_family(family)
    # family-routed corpus; for image families this is the exact legacy
    # synthetic_image_dataset call (bit-for-bit comparable rows)
    x, y = fam.make_dataset(max(1500, 6 * n), 10, hw=HW, noise=1.0,
                            seed=seed)
    parts = dirichlet_partition(y, n, 0.5, seed)
    params = fam.init(jax.random.PRNGKey(seed), 10, width_mult=WIDTH, hw=HW)
    return x, y, parts, params


def _cohort(n: int, parts, rnd: int, family: str = "cnn", seed: int = 0):
    """Round ``rnd``'s cohort: k non-empty-shard devices with model index
    round-robin over the family's submodels.  Membership (and therefore
    every padded program shape) is fixed across rounds; the per-round seeds
    reshuffle each client's local schedule exactly as the engine does."""
    k = max(1, int(round(PARTICIPATION * n)))
    ids, j = [], 0
    while len(ids) < k and j < n:
        if len(parts[j]):
            ids.append(j)
        j += 1
    ms = [i % get_family(family).num_submodels() for i in ids]
    seeds = [fl_client.client_update_seed(seed, rnd, i) for i in ids]
    return ids, ms, seeds


def round_per_client(params, x, y, parts, ids, ms, seeds, family="cnn"):
    """Legacy hot path: per-client updates + list-based aggregation."""
    deltas, weights = [], []
    for i, m, s in zip(ids, ms, seeds):
        d, _ = fl_client.drfl_client_update(
            params, m, x[parts[i]], y[parts[i]], epochs=EPOCHS, batch=BATCH,
            lr=LR, seed=s, family=family)
        deltas.append(d)
        weights.append(float(len(parts[i])))
    new = fl_server.aggregate_drfl(params, deltas, ms, weights,
                                   server_lr=SERVER_LR, family=family)
    jax.block_until_ready(new)
    return new


def round_batched(params, x_dev, y_dev, parts, ids, ms, seeds, family="cnn"):
    """Bucketed hot path: <= n_buckets executor programs + stacked
    aggregation."""
    res = fl_batch.run_cohort(
        "drfl", params, x_dev, y_dev, [parts[i] for i in ids], ids, ms,
        seeds, epochs=EPOCHS, batch=BATCH, lr=LR, family=family)
    new = fl_server.aggregate_drfl_stacked(
        params, [(b.model_idx, b.stacked_delta, b.weights, None)
                 for b in res.buckets], server_lr=SERVER_LR, family=family)
    jax.block_until_ready(new)
    return new


def bench_one(n: int, rounds: int, family: str = "cnn", seed: int = 0
              ) -> dict:
    x, y, parts, params = _setup(n, family, seed)
    x_dev, y_dev = jnp.asarray(x), jnp.asarray(y)

    # warmup round 0 (compiles both paths) then time rounds 1..R
    ids, ms, seeds = _cohort(n, parts, 0, family, seed)
    round_per_client(params, x, y, parts, ids, ms, seeds, family)
    fl_batch.reset_counters()
    round_batched(params, x_dev, y_dev, parts, ids, ms, seeds, family)
    warm_compiles = fl_batch.COUNTERS["compiles"]

    # per-round MEDIAN wall time: interleaved per-path timing on a small
    # shared-CPU box is noisy, and the per-client path (hundreds of tiny
    # ops) is hit hardest by scheduling jitter
    pc_steps, pc_times, b_times = 0, [], []
    for r in range(1, rounds + 1):
        ids, ms, seeds = _cohort(n, parts, r, family, seed)
        t0 = time.time()
        round_per_client(params, x, y, parts, ids, ms, seeds, family)
        pc_times.append(time.time() - t0)
        pc_steps += sum(
            len(fl_batch.client_schedule(parts[i], s, EPOCHS, BATCH))
            for i, s in zip(ids, seeds))
    t_pc = float(np.median(pc_times))

    fl_batch.reset_counters()
    for r in range(1, rounds + 1):
        ids, ms, seeds = _cohort(n, parts, r, family, seed)
        t0 = time.time()
        round_batched(params, x_dev, y_dev, parts, ids, ms, seeds, family)
        b_times.append(time.time() - t0)
    t_b = float(np.median(b_times))
    execs = fl_batch.COUNTERS["executions"] / rounds
    compiles = fl_batch.COUNTERS["compiles"]

    n_buckets = get_family(family).num_submodels()
    r = {"n": n, "k": len(ids), "rounds": rounds, "family": family,
         "per_client_s_per_round": t_pc,
         "batched_s_per_round": t_b,
         "speedup": t_pc / max(t_b, 1e-12),
         "per_client_dispatches_per_round": pc_steps / rounds + len(ids) + 1,
         "batched_executions_per_round": execs,
         "batched_compiles_steady": compiles,
         "batched_compiles_warm": warm_compiles}
    assert execs <= n_buckets, (execs, n_buckets)
    # CNN keeps its PR 3 emit names so recorded numbers stay comparable
    tag = f"client_bench/n{n}" if family == "cnn"         else f"client_bench/{family}/n{n}"
    emit(tag, t_b * 1e6,
         f"speedup={r['speedup']:.1f}x over per-client "
         f"({t_pc*1e3:.0f}ms -> {t_b*1e3:.0f}ms/round) "
         f"execs/round={execs:.1f} "
         f"pc_dispatches/round={r['per_client_dispatches_per_round']:.0f}")
    return r


def main(argv=None) -> dict:
    argv = list(sys.argv[1:] if argv is None else argv)
    smoke = "--smoke" in argv
    json_out = None
    if "--json" in argv:
        json_out = argv[argv.index("--json") + 1]
    families = ([argv[argv.index("--family") + 1]] if "--family" in argv
                else list(FAMILIES))
    sizes = [64] if smoke else [64, 256, 1024]
    rounds = 2 if smoke else 4
    results = [bench_one(n, rounds, family=fam)
               for fam in families for n in sizes]
    out = {"participation": PARTICIPATION, "epochs": EPOCHS, "batch": BATCH,
           "hw": HW, "width_mult": WIDTH, "families": families,
           "results": results}
    if json_out:
        with open(json_out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {json_out}")
    return out


if __name__ == "__main__":
    main()
