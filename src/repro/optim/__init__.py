from repro.optim.optimizers import (adamw_init, adamw_update, global_norm,
                                    sgd_init, sgd_update)  # noqa: F401
from repro.optim.schedules import make_schedule  # noqa: F401
