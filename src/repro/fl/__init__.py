from repro.fl.simulation import FLConfig, run_simulation  # noqa: F401
from repro.fl.engine import RoundEngine, build_world, sync_task_budget  # noqa: F401
from repro.fl.environment import FLEnv, FLEnvConfig  # noqa: F401
from repro.core.fleet import FleetState, make_fleet_state  # noqa: F401
