"""Phi-3-mini-3.8B — RoPE SwiGLU GQA dense decoder [arXiv:2404.14219]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    head_dim=96,
    exit_points=(8, 16, 24, 32),
    source="arXiv:2404.14219",
)
