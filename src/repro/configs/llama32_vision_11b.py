"""Llama-3.2-11B-Vision backbone — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].  Vision encoder is a stub frontend
(precomputed patch embeddings), per the assignment carve-out."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    head_dim=128, rope_theta=500_000.0,
    cross_attn_every=5, num_image_tokens=1601,
    exit_points=(10, 20, 30, 40),
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
