"""Episode replay buffer for QMIX (host-side numpy ring buffer).

Stores whole episodes (one FL run = one episode) so the GRU hidden state can
be unrolled from t=0 during learning.  Episodes are fixed-length ``T`` with
a validity mask (FL runs end early when the fleet dies).

Sampled-agent replay (``agent_budget=``): at fleet scale the per-agent
observation block ``[T+1, n, obs_dim]`` is the only O(n) axis left in QMIX
training, so the buffer can cap its stored agent width at a fixed budget.
Episodes wider than the budget are column-subsampled uniformly without
replacement (one draw per episode, so the GRU unroll sees a consistent
agent set across its timesteps) and the batch carries per-agent log
importance weights (``agent_logw``; zero under uniform sampling — softmax
attention pooling is self-normalising, so equal weights cancel exactly,
and a future non-uniform sampler stays unbiased through the same slot).
Replay memory then stops scaling with fleet size.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, episode_len: int, n_agents: int,
                 obs_dim: int, state_dim: int, seed: int = 0,
                 agent_budget: Optional[int] = None):
        self.capacity = capacity
        self.T = episode_len
        self.n_full = n_agents
        self.agent_budget = agent_budget
        n_store = min(n_agents, agent_budget) if agent_budget else n_agents
        self.N = n_store
        self.size = 0
        self.ptr = 0
        self.rng = np.random.default_rng(seed)
        self.obs = np.zeros((capacity, episode_len + 1, n_store, obs_dim), np.float32)
        self.state = np.zeros((capacity, episode_len + 1, state_dim), np.float32)
        self.actions = np.zeros((capacity, episode_len, n_store), np.int64)
        self.rewards = np.zeros((capacity, episode_len), np.float32)
        self.mask = np.zeros((capacity, episode_len), np.float32)
        if agent_budget is not None:
            self.agent_idx = np.zeros((capacity, n_store), np.int64)
            self.agent_logw = np.zeros((capacity, n_store), np.float32)
        else:
            self.agent_idx = None
            self.agent_logw = None

    def add_episode(self, obs, state, actions, rewards, agent_idx=None,
                    agent_logw=None):
        """obs: [t+1, N, obs_dim]; state: [t+1, state_dim];
        actions: [t, N]; rewards: [t] — t <= T.

        ``N`` may exceed the stored agent width (a full-fleet episode fed
        to a budgeted buffer): the columns are then subsampled here.
        Callers that pre-sample (``MarlSelector`` in set-mixer mode) pass
        already-narrow episodes plus their ``agent_idx``/``agent_logw``.
        """
        obs = np.asarray(obs)
        actions = np.asarray(actions)
        if obs.shape[1] > self.N:
            # uniform without replacement: equal self-normalised importance
            # weights, so the stored log-weights stay zero
            agent_idx = np.sort(self.rng.choice(obs.shape[1], self.N,
                                                replace=False))
            obs = obs[:, agent_idx]
            actions = actions[:, agent_idx]
            agent_logw = None
        t = len(rewards)
        i = self.ptr
        self.obs[i, :t + 1] = obs
        self.obs[i, t + 1:] = obs[-1]
        self.state[i, :t + 1] = state
        self.state[i, t + 1:] = state[-1]
        self.actions[i, :t] = actions
        self.actions[i, t:] = 0
        self.rewards[i, :t] = rewards
        self.rewards[i, t:] = 0.0
        self.mask[i, :t] = 1.0
        self.mask[i, t:] = 0.0
        if self.agent_idx is not None:
            self.agent_idx[i] = (np.arange(self.N) if agent_idx is None
                                 else agent_idx)
            self.agent_logw[i] = 0.0 if agent_logw is None else agent_logw
        self.ptr = (self.ptr + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def sample(self, batch: int) -> Optional[Dict[str, np.ndarray]]:
        if self.size == 0:
            return None
        idx = self.rng.integers(0, self.size, size=min(batch, self.size))
        out = {
            "obs": self.obs[idx],
            "state": self.state[idx],
            "actions": self.actions[idx],
            "rewards": self.rewards[idx],
            "mask": self.mask[idx],
        }
        if self.agent_logw is not None:
            out["agent_logw"] = self.agent_logw[idx]
        return out

    def state_dict(self) -> Dict:
        """Checkpointable snapshot incl. the sampled-agent columns and the
        numpy Generator state (arbitrary-precision ints, JSON-able)."""
        return {
            "obs": self.obs, "state": self.state, "actions": self.actions,
            "rewards": self.rewards, "mask": self.mask,
            "agent_idx": self.agent_idx, "agent_logw": self.agent_logw,
            "ptr": self.ptr, "size": self.size,
            "rng": self.rng.bit_generator.state,
        }

    def load_state_dict(self, state: Dict) -> None:
        for name in ("obs", "state", "actions", "rewards", "mask"):
            arr = np.asarray(state[name])
            if arr.shape != getattr(self, name).shape:
                raise ValueError(f"replay buffer {name} shape mismatch: "
                                 f"ckpt {arr.shape} vs "
                                 f"{getattr(self, name).shape}")
            setattr(self, name, arr)
        for name in ("agent_idx", "agent_logw"):
            have = getattr(self, name) is not None
            got = state.get(name) is not None
            if have != got:
                raise ValueError(f"replay buffer {name} presence mismatch "
                                 "(agent_budget differs from checkpoint)")
            if got:
                setattr(self, name, np.asarray(state[name]))
        self.ptr = int(state["ptr"])
        self.size = int(state["size"])
        self.rng.bit_generator.state = state["rng"]

    @property
    def nbytes(self) -> int:
        """Resident replay bytes (the BENCH_marl_train 'replay RSS' row)."""
        total = (self.obs.nbytes + self.state.nbytes + self.actions.nbytes
                 + self.rewards.nbytes + self.mask.nbytes)
        if self.agent_idx is not None:
            total += self.agent_idx.nbytes + self.agent_logw.nbytes
        return total

    def __len__(self):
        return self.size
