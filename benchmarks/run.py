# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One harness per paper artifact (Table 1, Fig. 5, Fig. 6, Table 2), plus the
sync-vs-async round-engine comparison, the kernel microbenches and the
roofline report over the dry-run artifacts.  REPRO_BENCH_FAST=0 switches to
the paper-scale (overnight) configuration.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (async_bench, fig5_energy, fig6_scalability,
                            fleet_bench, fleet_shard_bench, kernels_bench,
                            roofline, table1_accuracy, table2_valratio)
    print("name,us_per_call,derived")
    suites = [
        ("table1", table1_accuracy.main),
        ("fig5", fig5_energy.main),
        ("fig6", fig6_scalability.main),
        ("table2", table2_valratio.main),
        ("async", async_bench.main),
        ("kernels", kernels_bench.main),
        ("fleet", fleet_bench.main),
        # smoke only here (and a 1-device mesh unless XLA_FLAGS forced a
        # virtual multi-device runtime before this process started); the
        # recorded full-scale rows come from running the module directly
        ("fleet_shard", lambda: fleet_shard_bench.main(
            ["--smoke", "--no-write"])),
        ("roofline", roofline.main),
    ]
    failures = 0
    for name, fn in suites:
        t0 = time.time()
        try:
            fn()
            print(f"suite/{name},{(time.time() - t0) * 1e6:.1f},ok")
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"suite/{name},{(time.time() - t0) * 1e6:.1f},"
                  f"FAILED:{type(e).__name__}:{e}")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
