"""MARL networks (paper Fig. 3): shared-weight agent nets and QMIX mixer.

Agent: MLP -> GRU -> MLP head over M+1 actions (M layer-wise models + "do
not participate").  All agents share weights ("to decrease storage overhead
and accelerate convergence, all MLPs and GRUs within the MARL agents share
their weights") — per-agent behaviour differs through observations and GRU
hidden states, which are vmapped over the agent axis.

Mixer (QMIX): monotonic mixing of per-agent chosen Qs into Q_tot via
hypernetworks conditioned on the global state; weights pass through abs() to
keep dQ_tot/dq_i >= 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (dense_apply, dense_bias_init, gru_apply,
                                 gru_init, mlp_apply, mlp_init)


def agent_init(key, obs_dim: int, num_actions: int, hidden: int = 64):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "enc": mlp_init(k1, [obs_dim, hidden, hidden]),
        "gru": gru_init(k2, hidden, hidden),
        "head": mlp_init(k3, [hidden, hidden, num_actions]),
    }


def agent_step(params, obs, h):
    """obs: [N, obs_dim]; h: [N, hidden] -> (q [N, A], h' [N, hidden]).

    The same params serve every agent (shared weights); the leading axis is
    the agent axis."""
    z = mlp_apply(params["enc"], obs)
    h_new = gru_apply(params["gru"], h, z)
    q = mlp_apply(params["head"], h_new)
    return q, h_new


def agent_hidden_init(n_agents: int, hidden: int = 64):
    return jnp.zeros((n_agents, hidden), jnp.float32)


def mixer_init(key, n_agents: int, state_dim: int, embed: int = 32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "hyper_w1": mlp_init(k1, [state_dim, embed, n_agents * embed]),
        "hyper_b1": mlp_init(k2, [state_dim, embed]),
        "hyper_w2": mlp_init(k3, [state_dim, embed, embed]),
        "hyper_b2": mlp_init(k4, [state_dim, embed, 1]),
    }


def mixer_apply(params, qs, state, n_agents: int, embed: int = 32):
    """qs: [..., N]; state: [..., state_dim] -> Q_tot [...]."""
    n, e = n_agents, embed
    w1 = jnp.abs(mlp_apply(params["hyper_w1"], state))
    w1 = w1.reshape(state.shape[:-1] + (n, e))
    b1 = mlp_apply(params["hyper_b1"], state)
    hid = jax.nn.elu(jnp.einsum("...n,...ne->...e", qs, w1) + b1)
    w2 = jnp.abs(mlp_apply(params["hyper_w2"], state))
    b2 = mlp_apply(params["hyper_b2"], state)[..., 0]
    return jnp.einsum("...e,...e->...", hid, w2) + b2
