from repro.fl.simulation import FLConfig, run_simulation  # noqa: F401
from repro.fl.spec import (EnergySpec, EngineSpec, MarlSpec,  # noqa: F401
                           ModelSpec, ResilienceSpec, SimulationSpec,
                           ensure_flat_config)
from repro.fl.engine import (RoundEngine, build_world,  # noqa: F401
                             resolve_client_executor, sync_task_budget)
from repro.energy import (EnergyScenario,  # noqa: F401
                          known_availability_profiles, known_charge_profiles,
                          register_availability_profile,
                          register_charge_profile, scenario_from_config)
from repro.fl.environment import FLEnv, FLEnvConfig  # noqa: F401
from repro.fl.faults import FaultEvent, FaultPlan  # noqa: F401
from repro.core.fleet import (FleetState, fleet_summary,  # noqa: F401
                              make_fleet_state, sample_fleet_state,
                              summary_width)
from repro.core.selection import (marl_state_dim,  # noqa: F401
                                  resolve_state_mode)
from repro.models.family import (ModelFamily, get_family,  # noqa: F401
                                 known_families, register_family,
                                 resolve_family)
