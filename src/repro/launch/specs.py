"""ShapeDtypeStruct input specs + sharding specs for every
(architecture × input-shape × mesh) combination — no device allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import extra_inputs
from repro.sharding.rules import batch_axes, cache_specs, param_specs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_spec(mesh) -> P:
    b = batch_axes(mesh)
    return P(b if len(b) > 1 else b[0])


def train_inputs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    out = {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
    }
    for k, (shp, dt) in extra_inputs(cfg, B, S).items():
        out[k] = _sds(shp, dt)
    return out


def train_input_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh):
    bs = batch_spec(mesh)
    out = {"tokens": NamedSharding(mesh, P(*bs, None)),
           "labels": NamedSharding(mesh, P(*bs, None))}
    for k in extra_inputs(cfg, shape.global_batch, shape.seq_len):
        out[k] = NamedSharding(mesh, P(*bs, None, None))
    return out


def decode_inputs(model, cfg: ModelConfig, shape: ShapeConfig,
                  window_override=None) -> Tuple[Any, Any, Any]:
    """(cache_shape, tokens, pos) ShapeDtypeStructs for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    extras = {k: _sds(shp, dt) for k, (shp, dt) in extra_inputs(cfg, B, S).items()}
    kw = {}
    if window_override is not None:
        kw["window"] = window_override
    cache = jax.eval_shape(
        lambda p, ex: model.decode_init(p, B, S, extras=ex, **kw),
        _params_shape(model), extras)
    tokens = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    return cache, tokens, pos


def _params_shape(model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def decode_cache_shardings(cache_shape, mesh):
    specs = cache_specs(cache_shape, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def state_shardings(state_shape, mesh):
    """NamedShardings for a {'params','opt'} train state.  Moments follow
    their parameters, except under ZeRO-1 (replicated weights, data-sharded
    optimizer state)."""
    from repro.sharding.rules import get_sharding_policy
    pol = get_sharding_policy()
    pspecs = param_specs(state_shape["params"], mesh)
    mspecs = (param_specs(state_shape["params"], mesh, force_fsdp=True)
              if pol.get("zero1") else pspecs)
    ospecs = {
        "step": P(),
        "mu": mspecs,
        "nu": mspecs,
    }
    specs = {"params": pspecs, "opt": ospecs}
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def params_shardings(params_shape, mesh):
    pspecs = param_specs(params_shape, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
