"""Unified model API: ``build(cfg)`` -> a :class:`Model` namespace.

Every family exposes the same surface:
    init(key) -> params
    apply(params, **batch, layer_mask=..., remat=..., use_pallas=...)
        -> (hidden [B,S,d], aux_loss)
    logits(params, hidden) -> [B,S,V] float32
    decode_init(params, batch, seq_len, **extras) -> cache
    decode_step(params, cache, tokens, pos, layer_mask=...) -> (logits, cache)

``extra_inputs(cfg, batch, seq)`` names the stub-frontend tensors
(image/audio embeddings) each family consumes — used by both the data
pipeline and the dry-run ShapeDtypeStruct specs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, transformer, vlm, xlstm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    apply: Callable            # (params, tokens, extras, ...) -> (hidden, aux)
    logits: Callable
    decode_init: Callable
    decode_step: Callable
    sub_quadratic: bool        # native O(S) decode state / windowed attention


def extra_inputs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, tuple]:
    """name -> (shape, dtype) of stub-frontend inputs."""
    if cfg.family == "vlm":
        return {"image_embeds": ((batch, cfg.num_image_tokens, cfg.d_model),
                                 jnp.dtype(cfg.dtype))}
    if cfg.family == "audio":
        return {"audio_frames": ((batch, cfg.num_audio_frames, cfg.d_model),
                                 jnp.dtype(cfg.dtype))}
    return {}


def build(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe"):
        mod = transformer
    elif fam == "ssm":
        mod = xlstm
    elif fam == "mamba-hybrid":
        mod = hybrid
    elif fam == "vlm":
        mod = vlm
    elif fam == "audio":
        mod = encdec
    else:
        raise ValueError(f"unknown family {fam!r}")

    def init(key):
        return mod.init(key, cfg)

    def apply(params, tokens, extras=None, **kw):
        extras = extras or {}
        if fam == "vlm":
            return mod.apply(params, cfg, tokens, extras["image_embeds"], **kw)
        if fam == "audio":
            return mod.apply(params, cfg, tokens, extras["audio_frames"], **kw)
        return mod.apply(params, cfg, tokens, **kw)

    def logits(params, hidden):
        return mod.logits_fn(params, cfg, hidden)

    def decode_init(params, batch, seq_len, extras=None, **kw):
        extras = extras or {}
        if fam == "vlm":
            return mod.decode_init(params, cfg, batch, seq_len,
                                   image_embeds=extras.get("image_embeds"), **kw)
        if fam == "audio":
            return mod.decode_init(params, cfg, batch, seq_len,
                                   audio_frames=extras.get("audio_frames"), **kw)
        return mod.decode_init(params, cfg, batch, seq_len, **kw)

    def decode_step(params, cache, tokens, pos, **kw):
        return mod.decode_step(params, cfg, cache, tokens, pos, **kw)

    sub_quadratic = fam in ("ssm", "mamba-hybrid") or cfg.window > 0
    return Model(cfg=cfg, init=init, apply=apply, logits=logits,
                 decode_init=decode_init, decode_step=decode_step,
                 sub_quadratic=sub_quadratic)
