"""Jit'd public wrapper: model-layout in/out + CPU interpret fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    """Model layout: q [B, Sq, Hq, D]; k/v [B, Sk, Hkv, D] -> [B, Sq, Hq, D].

    ``interpret=None`` auto-selects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    qb = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kb = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vb = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    o = flash_attention_bhsd(qb, kb, vb, causal=causal, window=window,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return o.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
