"""Qwen3-MoE-235B-A22B — 128 experts, top-8 routing [hf:Qwen/Qwen3-30B-A3B
family].  d_ff is the per-expert FFN width."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    d_ff=1536, vocab_size=151936,
    head_dim=128, rope_theta=1_000_000.0, qk_norm=True,
    num_experts=128, experts_per_token=8,
    exit_points=(24, 47, 71, 94),
    source="hf:Qwen/Qwen3-30B-A3B",
)
