"""Quickstart: a 5-minute DR-FL run on one CPU core.

    PYTHONPATH=src python examples/quickstart.py

Runs a small fleet of battery-powered heterogeneous devices training the
4-exit layer-wise ResNet with MARL dual-selection, and prints the round-by-
round accuracy / energy / fleet-survival trace.
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.fl import FLConfig, run_simulation


def main():
    cfg = FLConfig(
        n_devices=8,          # heterogeneous fleet (small/medium/large tiers)
        n_rounds=8,
        participation=0.4,    # Top-K = 3 clients per round
        local_epochs=2,
        method="drfl",
        selector="marl",      # the paper's QMIX dual-selection
        alpha=0.5,            # Dirichlet non-IID
        n_train=1200,
        energy_scale=0.05,    # make the battery budget binding
        seed=0,
    )
    print(f"DR-FL quickstart: {cfg.n_devices} devices, {cfg.n_rounds} rounds, "
          f"alpha={cfg.alpha}, selector={cfg.selector}")
    hist = run_simulation(cfg, verbose=True)
    print("\nbest accuracy per layer-wise model (Models 1-4):",
          np.round(hist["best_acc"], 3))
    print("devices alive at end:", hist["alive"][-1], "/", cfg.n_devices)
    print("total energy remaining: %.0f J" % hist["energy"][-1])


if __name__ == "__main__":
    main()
