"""jaxlint core: repo index, findings, pragma suppression.

The linter is AST-based and *repo-aware*: rules do not just pattern-match
single files, they resolve imports across ``src/repro``, walk the call
graph from the engine/selection hot-path roots, and cross-check companion
files (the sharding rule table, the kernel ``ops.py``/``ref.py`` pairs,
the frozen-reference hash ledger).  This module holds the pieces every
rule shares:

* :class:`Finding` — one diagnostic (rule id, file, line, message) plus
  its suppression state after pragma matching.
* :class:`Pragma` / :func:`collect_pragmas` — the suppression syntax::

      some_call()   # jaxlint: allow(host-sync) -- one pull per round

  A pragma suppresses findings of the named rule(s) on its own line.  A
  pragma on a standalone comment line applies to the next code line, and
  a pragma attached to a ``def``/``class`` header (or its decorators)
  covers the whole body — for functions that are host-side *by design*
  (constructors, compat views, the frozen reference loop).  The reason
  string after ``--`` is REQUIRED: a pragma without one is itself a
  finding (rule ``bad-pragma``), so every suppression carries a written
  justification the next reader can audit.
* :class:`Module` / :class:`RepoIndex` — parsed sources, import alias
  tables, and the function index (top-level functions and methods with
  their spans; nested defs belong to their enclosing function's body).

Rules are callables ``rule(index, config) -> list[Finding]`` registered
in :data:`repro.analysis.rules.ALL_RULES`; the driver in
:mod:`repro.analysis.lint` runs them, applies pragmas, and renders the
text/JSON reports.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple

PRAGMA_RE = re.compile(
    r"#\s*jaxlint:\s*allow\(\s*([\w\-, ]+?)\s*\)\s*(?:--\s*(.*\S))?\s*$")

#: rule id reserved for malformed pragmas (missing reason, unknown syntax)
BAD_PRAGMA = "bad-pragma"


@dataclasses.dataclass
class Finding:
    rule: str
    file: str                 # repo-relative path
    line: int
    message: str
    suppressed: bool = False
    reason: Optional[str] = None    # pragma reason when suppressed

    def key(self) -> Tuple[str, str, int]:
        return (self.rule, self.file, self.line)

    def to_json(self) -> dict:
        return {"rule": self.rule, "file": self.file, "line": self.line,
                "message": self.message, "suppressed": self.suppressed,
                "reason": self.reason}

    def render(self) -> str:
        tag = " [suppressed]" if self.suppressed else ""
        return f"{self.file}:{self.line}: {self.rule}: {self.message}{tag}"


@dataclasses.dataclass
class Pragma:
    line: int                 # line the pragma comment sits on
    rules: Tuple[str, ...]
    reason: Optional[str]
    standalone: bool          # comment-only line (applies to next code line)


def collect_pragmas(source_lines: Sequence[str]) -> List[Pragma]:
    out = []
    for i, text in enumerate(source_lines, start=1):
        m = PRAGMA_RE.search(text)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        reason = m.group(2)
        standalone = text.lstrip().startswith("#")
        out.append(Pragma(line=i, rules=rules, reason=reason,
                          standalone=standalone))
    return out


@dataclasses.dataclass
class FuncInfo:
    """One top-level function or method.  ``span`` covers decorators
    through ``end_lineno``; nested defs are part of the body (their calls
    and findings attribute to this function)."""
    qualname: str             # "repro.fl.engine:RoundEngine._run_sync"
    module: str               # "repro.fl.engine"
    name: str                 # bare name ("_run_sync")
    class_name: Optional[str]
    node: ast.AST
    header_lines: Tuple[int, ...]   # def line + decorator lines
    span: Tuple[int, int]           # (first line incl. decorators, end line)


class Module:
    """One parsed source file plus its import-alias tables."""

    def __init__(self, path: str, relpath: str, modname: str):
        self.path = path
        self.relpath = relpath
        self.modname = modname
        with open(path, encoding="utf-8") as fh:
            self.source = fh.read()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=relpath)
        self.pragmas = collect_pragmas(self.lines)
        # alias -> imported module name ("jnp" -> "jax.numpy",
        # "fl_batch" -> "repro.fl.batch")
        self.module_aliases: Dict[str, str] = {}
        # local name -> (module, original name) from `from m import n [as a]`
        self.from_imports: Dict[str, Tuple[str, str]] = {}
        # local module-level alias -> underlying function name, for
        # `x_jit = jax.jit(x, ...)`-style wrappers
        self.jit_aliases: Dict[str, Tuple[str, ast.Call]] = {}
        self._scan_imports()

    def _scan_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.module_aliases[a.asname or a.name.split(".")[0]] = \
                        a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                mod = node.module
                if node.level:        # relative import: resolve best-effort
                    base = self.modname.rsplit(".", node.level)[0]
                    mod = f"{base}.{node.module}"
                for a in node.names:
                    self.from_imports[a.asname or a.name] = (mod, a.name)
        for node in self.tree.body:
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)):
                target = unwrap_jit_call(node.value)
                if target is not None:
                    self.jit_aliases[node.targets[0].id] = (target,
                                                            node.value)


def is_jax_jit_func(mod: Module, func: ast.AST) -> bool:
    """True when ``func`` (a Call's .func node) denotes ``jax.jit``."""
    if isinstance(func, ast.Attribute) and func.attr == "jit":
        root = func.value
        return (isinstance(root, ast.Name)
                and mod.module_aliases.get(root.id, root.id) == "jax")
    if isinstance(func, ast.Name):
        imp = mod.from_imports.get(func.id)
        return imp == ("jax", "jit")
    return False


def unwrap_jit_call(call: ast.Call) -> Optional[str]:
    """For ``jax.jit(f, ...)`` or ``jax.jit(functools.partial(f, ...))``
    return the wrapped function's bare name, else None.  Module-agnostic
    (only shape-based), used for the module-level jit-alias table."""
    func = call.func
    is_jit = (isinstance(func, ast.Attribute) and func.attr == "jit") or \
        (isinstance(func, ast.Name) and func.id == "jit")
    if not is_jit or not call.args:
        return None
    arg = call.args[0]
    if isinstance(arg, ast.Name):
        return arg.id
    if (isinstance(arg, ast.Call) and isinstance(arg.func, ast.Attribute)
            and arg.func.attr == "partial" and arg.args
            and isinstance(arg.args[0], ast.Name)):
        return arg.args[0].id
    return None


class RepoIndex:
    """Parsed view of every python file under the lint roots."""

    def __init__(self, repo_root: str, src_rel: str = "src",
                 package: str = "repro",
                 exclude: Sequence[str] = ("_vendor",)):
        self.repo_root = os.path.abspath(repo_root)
        self.package = package
        self.modules: Dict[str, Module] = {}
        self.functions: Dict[str, FuncInfo] = {}
        self.parse_errors: List[Finding] = []
        src_dir = os.path.join(self.repo_root, src_rel, package)
        for dirpath, dirnames, filenames in os.walk(src_dir):
            dirnames[:] = sorted(d for d in dirnames if d not in exclude
                                 and not d.startswith("."))
            for name in sorted(filenames):
                if name.endswith(".py"):
                    self._add(os.path.join(dirpath, name), src_dir)

    def _add(self, path: str, src_dir: str) -> None:
        rel_in_pkg = os.path.relpath(path, src_dir)
        modname = self.package
        parts = rel_in_pkg[:-3].split(os.sep)
        if parts != ["__init__"]:
            modname += "." + ".".join(p for p in parts if p != "__init__")
        relpath = os.path.relpath(path, self.repo_root)
        try:
            mod = Module(path, relpath, modname)
        except SyntaxError as e:
            self.parse_errors.append(Finding(
                rule="parse-error", file=relpath, line=e.lineno or 1,
                message=f"does not parse: {e.msg}"))
            return
        self.modules[modname] = mod
        self._index_functions(mod)

    def _index_functions(self, mod: Module) -> None:
        def add(node, class_name):
            qual = (f"{mod.modname}:{class_name}.{node.name}" if class_name
                    else f"{mod.modname}:{node.name}")
            deco_lines = tuple(d.lineno for d in node.decorator_list)
            header = deco_lines + (node.lineno,)
            self.functions[qual] = FuncInfo(
                qualname=qual, module=mod.modname, name=node.name,
                class_name=class_name, node=node, header_lines=header,
                span=(min(header), node.end_lineno or node.lineno))

        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                add(node, None)
            elif isinstance(node, ast.ClassDef):
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        add(sub, node.name)

    # -- lookups -----------------------------------------------------------

    def module_by_relpath(self, relpath: str) -> Optional[Module]:
        norm = relpath.replace("\\", "/")
        for mod in self.modules.values():
            if mod.relpath.replace("\\", "/") == norm:
                return mod
        return None

    def functions_in(self, modname: str) -> List[FuncInfo]:
        return [f for f in self.functions.values() if f.module == modname]

    def enclosing_function(self, modname: str,
                           line: int) -> Optional[FuncInfo]:
        for f in self.functions_in(modname):
            if f.span[0] <= line <= f.span[1]:
                return f
        return None


def apply_pragmas(findings: List[Finding], index: RepoIndex) -> List[Finding]:
    """Mark findings suppressed per the pragma rules; emit ``bad-pragma``
    findings for pragmas missing a reason string."""
    by_file: Dict[str, Module] = {m.relpath: m for m in
                                  index.modules.values()}
    extra: List[Finding] = []
    for mod in index.modules.values():
        for p in mod.pragmas:
            if not p.reason:
                extra.append(Finding(
                    rule=BAD_PRAGMA, file=mod.relpath, line=p.line,
                    message="pragma without a reason — write "
                            "'# jaxlint: allow(<rule>) -- <why>'"))
    for f in findings:
        mod = by_file.get(f.file)
        if mod is None:
            continue
        for p in mod.pragmas:
            if not p.reason or f.rule not in p.rules:
                continue
            if _pragma_covers(p, f, mod, index):
                f.suppressed = True
                f.reason = p.reason
                break
    return findings + extra


def _pragma_covers(p: Pragma, f: Finding, mod: Module,
                   index: RepoIndex) -> bool:
    target = p.line
    if p.standalone:
        # standalone comment: applies to the next non-comment, non-blank line
        for j in range(p.line + 1, len(mod.lines) + 1):
            text = mod.lines[j - 1].strip()
            if text and not text.startswith("#"):
                target = j
                break
    if f.line == target:
        return True
    # def/class-header pragma covers the whole body
    for fn in index.functions_in(mod.modname):
        if target in fn.header_lines and fn.span[0] <= f.line <= fn.span[1]:
            return True
    return False
