"""DR-FL core: layerwise masks, aggregation (incl. property tests), energy."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core import (aggregation, energy, layerwise)
from repro.core.energy import DeviceProfile, DeviceState


# ---------------------------------------------------------------------------
# layerwise
# ---------------------------------------------------------------------------


def test_exit_points_and_masks():
    cfg = get_config("yi-34b")
    assert layerwise.exit_points(cfg) == (15, 30, 45, 60)
    m0 = layerwise.layer_mask(cfg, 0)
    m3 = layerwise.layer_mask(cfg, 3)
    assert float(m0.sum()) == 15 and float(m3.sum()) == 60
    # monotone prefix
    assert bool(jnp.all(m0 <= m3))
    assert layerwise.submodel_fraction(cfg, 0) == pytest.approx(0.25)


def test_stacked_update_mask_shapes():
    cfg = get_smoke_config("yi-34b")
    from repro.models import build
    params = build(cfg).init(jax.random.PRNGKey(0))
    masks = layerwise.stacked_update_mask(cfg, 0, params)
    # stacked block leaves get [L,1,...] masks; embed gets scalar 1
    blk_mask = jax.tree.leaves(masks["blocks"])[0]
    assert blk_mask.shape[0] == cfg.num_layers
    assert float(masks["embed"]["emb"]) == 1.0


# ---------------------------------------------------------------------------
# aggregation properties
# ---------------------------------------------------------------------------


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {"a": scale * jax.random.normal(k1, (4, 3)),
            "b": scale * jax.random.normal(k2, (2,))}


def test_fedavg_identity_and_mean():
    key = jax.random.PRNGKey(0)
    t = _tree(key)
    out = aggregation.fedavg([t, t, t])
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(t["a"]), rtol=1e-6)
    t2 = jax.tree.map(lambda x: -x, t)
    out = aggregation.fedavg([t, t2])
    np.testing.assert_allclose(np.asarray(out["a"]), 0.0, atol=1e-6)


@hypothesis.given(
    w1=st.floats(1.0, 100.0), w2=st.floats(1.0, 100.0),
    m_a=st.integers(0, 1), m_b=st.integers(0, 1))
@hypothesis.settings(max_examples=20, deadline=None)
def test_layerwise_aggregate_properties(w1, w2, m_a, m_b):
    """(1) untouched layers stay exactly; (2) single-client layers copy that
    client; (3) overlap = weighted mean."""
    gp = {"x": jnp.zeros((2, 3))}
    u1 = {"x": jnp.ones((2, 3))}
    u2 = {"x": 3.0 * jnp.ones((2, 3))}
    mask1 = {"x": jnp.asarray([[1.0], [m_a]])}   # layer 0 trained, layer 1 maybe
    mask2 = {"x": jnp.asarray([[1.0], [m_b]])}
    out = aggregation.layerwise_aggregate(gp, [u1, u2], [mask1, mask2],
                                          weights=[w1, w2])
    # layer 0: both trained
    exp0 = (w1 * 1.0 + w2 * 3.0) / (w1 + w2)
    np.testing.assert_allclose(np.asarray(out["x"][0]), exp0, rtol=1e-5)
    den = w1 * m_a + w2 * m_b
    exp1 = 0.0 if den == 0 else (w1 * m_a * 1.0 + w2 * m_b * 3.0) / den
    np.testing.assert_allclose(np.asarray(out["x"][1]), exp1, rtol=1e-5)


def test_fl_allreduce_matches_host_aggregation():
    """Masked psum over a 'pod' axis == layerwise_aggregate (1-device mesh,
    pod size 1 degenerates to identity; also check 1-pod math directly)."""
    try:
        from jax import shard_map
    except ImportError:                      # older jax: experimental only
        from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    u = {"x": jnp.ones((2, 3))}
    m = {"x": jnp.ones((2, 1))}

    def f(u, m):
        return aggregation.fl_allreduce(u, m, 2.0, "pod")

    out = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=P())(u, m)
    np.testing.assert_allclose(np.asarray(out["x"]), 1.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# energy model (Eq. 3-7) properties
# ---------------------------------------------------------------------------


@hypothesis.given(
    data=st.integers(50, 2000),
    frac=st.sampled_from([0.25, 0.5, 0.75, 1.0]),
    mbytes=st.floats(1e4, 1e7))
@hypothesis.settings(max_examples=30, deadline=None)
def test_round_cost_eq57(data, frac, mbytes):
    prof = DeviceProfile.from_tier("medium")
    dev = DeviceState(profile=prof, remaining=prof.battery, data_size=data)
    t_tra, t_com, e_tra, e_com = energy.round_cost(dev, mbytes, frac,
                                                   local_epochs=5)
    assert t_tra > 0 and t_com > 0
    # Eq. 7: E = P * T
    assert e_tra == pytest.approx(dev.train_power() * t_tra, rel=1e-6)
    assert e_com == pytest.approx(prof.p_com * t_com, rel=1e-6)
    # Eq. 5: T_com linear in model size; T_tra linear in data
    t_tra2, t_com2, _, _ = energy.round_cost(dev, 2 * mbytes, frac, local_epochs=5)
    assert t_com2 == pytest.approx(2 * t_com, rel=1e-6)
    # a smaller submodel is cheaper to train
    t_small, _, _, _ = energy.round_cost(dev, mbytes, frac / 2, local_epochs=5)
    assert t_small < t_tra


def test_charge_battery_exhaustion():
    prof = DeviceProfile.from_tier("small")
    dev = DeviceState(profile=prof, remaining=10.0, data_size=100)
    ok = energy.charge(dev, 6.0, 3.0)
    assert ok and dev.remaining == pytest.approx(1.0)
    ok = energy.charge(dev, 6.0, 3.0)   # not enough: dies, energy wasted
    assert not ok and dev.remaining == 0.0 and not dev.alive
    assert not energy.charge(dev, 0.1, 0.1)   # dead stays dead


def test_fleet_heterogeneous():
    fleet = energy.make_fleet(30, seed=1)
    tiers = {d.profile.tier for d in fleet}
    assert len(tiers) >= 2
    assert all(d.remaining == d.profile.battery for d in fleet)
    assert energy.total_remaining(fleet) == pytest.approx(
        sum(d.profile.battery for d in fleet))
