"""Substrate subsystems: optimizers, schedules, data, checkpointing."""
import os

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree, latest_step
from repro.data import dirichlet_partition, synthetic_image_dataset
from repro.data.synthetic import lm_batches, synthetic_lm_dataset
from repro.optim import (adamw_init, adamw_update, global_norm, make_schedule,
                         sgd_init, sgd_update)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def test_adamw_minimises_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt, _ = adamw_update(g, opt, params, lr=5e-2)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip_caps_norm():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    g = {"w": jnp.full(4, 100.0)}
    _, _, m = adamw_update(g, opt, params, lr=0.1, grad_clip=1.0)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_sgd_momentum_moves_params():
    params = {"w": jnp.asarray([1.0])}
    opt = sgd_init(params, momentum=0.9)
    g = {"w": jnp.asarray([1.0])}
    p2, opt, _ = sgd_update(g, opt, params, lr=0.1, momentum=0.9)
    assert float(p2["w"][0]) == pytest.approx(0.9)
    p3, _, _ = sgd_update(g, opt, p2, lr=0.1, momentum=0.9)
    assert float(p3["w"][0]) < 0.9 - 0.1   # momentum accelerates


def test_schedules():
    for kind in ("constant", "linear", "cosine"):
        fn = make_schedule(kind, 1e-3, warmup_steps=10, total_steps=100)
        assert float(fn(0)) == pytest.approx(1e-4, rel=1e-3)  # (s+1)/warmup
        assert float(fn(10)) == pytest.approx(1e-3, rel=1e-3)
        if kind != "constant":
            assert float(fn(100)) < 1e-4


def test_bf16_params_fp32_moments():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    opt = adamw_init(params)
    assert opt["mu"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    p2, opt, _ = adamw_update(g, opt, params, lr=0.1)
    assert p2["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


@hypothesis.given(alpha=st.sampled_from([0.1, 0.5, 1.0]),
                  n_clients=st.integers(2, 12))
@hypothesis.settings(max_examples=10, deadline=None)
def test_dirichlet_partition_covers_everything(alpha, n_clients):
    _, y = synthetic_image_dataset(600, 10, hw=8, seed=1)
    parts = dirichlet_partition(y, n_clients, alpha, seed=0)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(y)
    assert len(np.unique(all_idx)) == len(y)
    assert min(len(p) for p in parts) >= 8


def test_dirichlet_alpha_controls_skew():
    _, y = synthetic_image_dataset(4000, 10, hw=8, seed=2)

    def skew(alpha):
        parts = dirichlet_partition(y, 10, alpha, seed=3)
        # mean per-client class-distribution entropy (low = non-IID)
        ents = []
        for p in parts:
            c = np.bincount(y[p], minlength=10) / max(len(p), 1)
            ents.append(-(c[c > 0] * np.log(c[c > 0])).sum())
        return np.mean(ents)

    assert skew(0.1) < skew(1.0)   # smaller alpha => more non-IID


def test_synthetic_lm_has_structure():
    toks = synthetic_lm_dataset(5000, vocab=64, seed=0)
    assert toks.min() >= 0 and toks.max() < 64
    b = next(lm_batches(toks, 4, 32, seed=0))
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # order-2 structure: bigram-conditional entropy far below uniform
    pairs = {}
    for t in range(2, len(toks)):
        pairs.setdefault((toks[t - 2], toks[t - 1]), set()).add(toks[t])
    mean_succ = np.mean([len(v) for v in pairs.values()])
    assert mean_succ < 16   # vastly fewer than 64 possible successors


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": [jnp.ones(2), {"c": jnp.zeros((), jnp.int32)}]}
    p = save_pytree(str(tmp_path / "ck"), tree, step=7)
    assert latest_step(str(tmp_path / "ck")) == p
    out = load_pytree(p, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    p = save_pytree(str(tmp_path / "x.ckpt"), {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        load_pytree(p, {"a": jnp.ones((3, 2))})
