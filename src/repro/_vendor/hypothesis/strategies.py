"""Minimal strategies for the vendored hypothesis fallback.

Each strategy draws from a seeded ``random.Random`` via ``example(rng)``.
The first few examples are boundary values (min/max/first element) so the
deterministic sweep still probes edges the way hypothesis tends to.
"""
from __future__ import annotations

import random
from typing import Any, Callable, List, Sequence


class SearchStrategy:
    def __init__(self, draw: Callable[[random.Random], Any],
                 boundary: Sequence[Any] = ()):
        self._draw = draw
        self._boundary = list(boundary)
        self._emitted = 0

    def example(self, rng: random.Random) -> Any:
        if self._emitted < len(self._boundary):
            v = self._boundary[self._emitted]
        else:
            v = self._draw(rng)
        self._emitted += 1
        return v

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        parent = self
        return SearchStrategy(lambda rng: fn(parent.example(rng)))

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        parent = self

        def draw(rng):
            for _ in range(1000):
                v = parent.example(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate rejected 1000 examples")

        return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                          boundary=(min_value, max_value))


def floats(min_value: float, max_value: float, **_ignored) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value),
                          boundary=(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.getrandbits(1)),
                          boundary=(False, True))


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    elems = list(elements)
    if not elems:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(lambda rng: elems[rng.randrange(len(elems))],
                          boundary=elems[:1])


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def lists(element: SearchStrategy, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:

    def draw(rng) -> List[Any]:
        n = rng.randint(min_size, max_size)
        return [element.example(rng) for _ in range(n)]

    return SearchStrategy(draw)


def tuples(*elements: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(e.example(rng) for e in elements))
