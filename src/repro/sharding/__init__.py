from repro.sharding.rules import (activation_spec, batch_axes, cache_specs,
                                  constrain, param_specs, set_activation_mesh,
                                  spec_for)  # noqa: F401
