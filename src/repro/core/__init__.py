"""DR-FL core: the paper's contribution, model-family- and scale-agnostic.

* layerwise    — depth-prefix submodels + masks (§4.2)
* aggregation  — FedAvg + layer-aligned masked aggregation (Step 2), incl.
                 the stacked segment-row path behind the Pallas layer_agg
                 kernel; layout-generic via ``repro.models.family`` stack
                 templates (no concrete architecture imported here)
* energy       — Eq. 3–7 time/energy system model + device fleet (scalar
                 reference semantics)
* fleet        — vectorized struct-of-arrays FleetState engine (batched
                 Eq. 3–7 kernels; numpy parity + jax/jit backends; shards
                 over a ``jax.sharding`` "fleet" mesh via
                 ``repro.sharding.fleet``) + the fixed-width
                 ``fleet_summary`` factored MARL state
* selection    — dual-selection strategies (MARL / greedy / random /
                 static), consumed by the event-driven round engine in
                 ``repro.fl.engine`` (sync barrier and async timeline
                 modes); flat and factored QMIX state modes
* marl         — QMIX learner (agents, mixer, replay, TD updates)
* baselines    — HeteroFL / ScaleFL comparison arms

Model-specific machinery (masks per family, client updates, cost models)
lives behind the ``repro.models.family.ModelFamily`` registry; round
scheduling lives in ``repro.fl.engine.RoundEngine``.
"""
from repro.core.aggregation import fedavg, fl_allreduce, layerwise_aggregate  # noqa: F401
from repro.core.energy import (BATTERY_JOULES, DeviceProfile, DeviceState,  # noqa: F401
                               make_fleet, round_cost, charge, total_remaining)
from repro.core.fleet import (FleetState, as_fleet_state,  # noqa: F401
                              fleet_affordability, fleet_charge,
                              fleet_connect, fleet_cost_matrix,
                              fleet_disconnect, fleet_round_cost,
                              fleet_summary, fleet_topk_mask,
                              fleet_total_remaining, make_fleet_state,
                              sample_fleet_state, set_modes, summary_width)
from repro.core.layerwise import (exit_points, layer_mask, num_submodels,  # noqa: F401
                                  stacked_update_mask, submodel_fraction)
from repro.core.selection import (GreedySelector, MarlSelector,  # noqa: F401
                                  RandomSelector, Selection,
                                  StaticTierSelector, marl_state_dim,
                                  resolve_state_mode)
