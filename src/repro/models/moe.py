"""Mixture-of-Experts FFN (token-choice top-k router, capacity dispatch).

Two execution paths:

* **capacity dispatch** (training / prefill, ``S > 1``): tokens are scattered
  into per-sequence expert buffers ``[B, E, C, d]`` (capacity
  ``C = S*K/E * capacity_factor`` per sequence row), experts run as one
  batched einsum over the stacked ``[E, d, f]`` tensors, results gather back.
  Grouping per batch row keeps scatter indices local so GSPMD shards the
  whole dispatch over the data axis; the expert einsum shards ``E`` (or
  ``f``) over the model axis — expert parallelism with the all-to-all
  materialising at the group/expert boundary.
* **gather path** (decode, ``S == 1``): per-token expert weights are gathered
  (weight streaming) and applied directly — realistic for low-batch decode.

An auxiliary load-balance loss (Switch-style) is returned alongside.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import _normal


def moe_init(key, cfg, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "router": _normal(ks[0], (d, E), s, jnp.float32),
        "w_gate": _normal(ks[1], (E, d, f), s, dtype),
        "w_up": _normal(ks[2], (E, d, f), s, dtype),
        "w_down": _normal(ks[3], (E, f, d), 1.0 / math.sqrt(f), dtype),
    }


def _route(p, cfg, x):
    """x: [..., d] -> (weights [..., K], idx [..., K], aux_loss)."""
    logits = x.astype(jnp.float32) @ p["router"]                # [..., E]
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, cfg.experts_per_token)
    topw = topw / (topw.sum(-1, keepdims=True) + 1e-9)
    # Switch load-balance aux loss.
    E = cfg.num_experts
    me = jnp.mean(gates.reshape(-1, E), axis=0)
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)
    ce = jnp.mean(onehot.sum(-2).reshape(-1, E), axis=0) / cfg.experts_per_token
    aux = E * jnp.sum(me * ce)
    return topw, topi, aux


def _experts(p, xb):
    """xb: [..., C, d] grouped per expert axis E at ``-3``."""
    h = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", xb, p["w_gate"]))
    h = h * jnp.einsum("...ecd,edf->...ecf", xb, p["w_up"])
    return jnp.einsum("...ecf,efd->...ecd", h, p["w_down"])


def moe_apply(p, cfg, x, *, capacity_factor: float = 0.0):
    """x: [B, S, d] -> (y, aux_loss)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    if S == 1:
        if cfg.moe_decode_impl == "dispatch":
            # decode via the capacity-dispatch path, batch-as-sequence:
            # tokens move to the (model-axis-sharded) experts through an
            # all-to-all instead of streaming expert weights to every token
            # (the gather path all-gathers ~3x[E,d,f] per layer — measured
            # 930 GB/device/step on qwen3-235b decode_32k; see §Perf).
            y, aux = moe_apply(p, cfg, x.transpose(1, 0, 2),
                               capacity_factor=capacity_factor or 2.0)
            return y.transpose(1, 0, 2), aux
        return _moe_gather(p, cfg, x)
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    topw, topi, aux = _route(p, cfg, x)                         # [B,S,K]
    C = max(K, int(math.ceil(S * K / E * capacity_factor)))

    flat_e = topi.reshape(B, S * K)                             # [B, T]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # [B, T, E]
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot              # [B, T, E]
    pos = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    keep = (pos < C).astype(x.dtype)                            # [B, T]
    pos = jnp.minimum(pos, C - 1)

    xr = jnp.repeat(x, K, axis=1)                               # [B, T, d]
    buf = jnp.zeros((B, E, C, d), x.dtype)
    bidx = jnp.arange(B)[:, None]
    buf = buf.at[bidx, flat_e, pos].add(xr * keep[..., None])
    yb = _experts(p, buf)                                       # [B, E, C, d]
    y = yb[bidx, flat_e, pos] * keep[..., None]                 # [B, T, d]
    y = y.reshape(B, S, K, d) * topw[..., None].astype(x.dtype)
    return y.sum(axis=2), aux


def _moe_gather(p, cfg, x):
    """Decode path: gather per-token expert weights. x: [B, 1, d]."""
    B, _, d = x.shape
    topw, topi, aux = _route(p, cfg, x)                         # [B,1,K]
    ti = topi[:, 0]                                             # [B,K]
    wg = p["w_gate"][ti]                                        # [B,K,d,f]
    wu = p["w_up"][ti]
    wd = p["w_down"][ti]
    xt = x[:, 0]                                                # [B,d]
    h = jax.nn.silu(jnp.einsum("bd,bkdf->bkf", xt, wg))
    h = h * jnp.einsum("bd,bkdf->bkf", xt, wu)
    y = jnp.einsum("bkf,bkfd->bkd", h, wd)
    y = (y * topw[:, 0, :, None].astype(x.dtype)).sum(axis=1)
    return y[:, None, :], aux
