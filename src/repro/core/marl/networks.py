"""MARL networks (paper Fig. 3): shared-weight agent nets and QMIX mixers.

Agent: MLP -> GRU -> MLP head over M+1 actions (M layer-wise models + "do
not participate").  All agents share weights ("to decrease storage overhead
and accelerate convergence, all MLPs and GRUs within the MARL agents share
their weights") — per-agent behaviour differs through observations and GRU
hidden states, which are vmapped over the agent axis.

Two QMIX mixers share the monotonicity contract (every weight on a q path
passes through abs() so dQ_tot/dq_i >= 0):

* ``mixer_init`` / ``mixer_apply`` — the original flat hypernet mixer:
  one weight row PER AGENT (``hyper_w1`` emits ``n_agents * embed``), so
  parameters grow linearly with the fleet.  Kept bit-for-bit as the
  small-fleet legacy path.
* ``set_mixer_init`` / ``set_mixer_apply`` — the permutation-invariant
  set/attention mixer: per-agent Q values are embedded into monotone
  value vectors, reduced by softmax attention of a few state-conditioned
  seed queries over agent-observation keys, and mixed through abs
  hypernet output weights.  Parameter count and per-step cost are
  independent of ``n_agents`` (beyond the attended set), so QMIX trains
  at 1M agents on sampled-agent replay minibatches.  The attention
  reduction is routed through the ``kernels/flash_attention`` ops/ref
  parity contract (:func:`attention_reduce`).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import (dense_apply, dense_bias_init, gru_apply,
                                 gru_init, mlp_apply, mlp_init)


def agent_init(key, obs_dim: int, num_actions: int, hidden: int = 64):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "enc": mlp_init(k1, [obs_dim, hidden, hidden]),
        "gru": gru_init(k2, hidden, hidden),
        "head": mlp_init(k3, [hidden, hidden, num_actions]),
    }


def agent_step(params, obs, h):
    """obs: [N, obs_dim]; h: [N, hidden] -> (q [N, A], h' [N, hidden]).

    The same params serve every agent (shared weights); the leading axis is
    the agent axis."""
    z = mlp_apply(params["enc"], obs)
    h_new = gru_apply(params["gru"], h, z)
    q = mlp_apply(params["head"], h_new)
    return q, h_new


def agent_hidden_init(n_agents: int, hidden: int = 64):
    return jnp.zeros((n_agents, hidden), jnp.float32)


def mixer_init(key, n_agents: int, state_dim: int, embed: int = 32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "hyper_w1": mlp_init(k1, [state_dim, embed, n_agents * embed]),
        "hyper_b1": mlp_init(k2, [state_dim, embed]),
        "hyper_w2": mlp_init(k3, [state_dim, embed, embed]),
        "hyper_b2": mlp_init(k4, [state_dim, embed, 1]),
    }


def mixer_apply(params, qs, state, n_agents: int, embed: int = 32):
    """qs: [..., N]; state: [..., state_dim] -> Q_tot [...]."""
    n, e = n_agents, embed
    w1 = jnp.abs(mlp_apply(params["hyper_w1"], state))
    w1 = w1.reshape(state.shape[:-1] + (n, e))
    b1 = mlp_apply(params["hyper_b1"], state)
    hid = jax.nn.elu(jnp.einsum("...n,...ne->...e", qs, w1) + b1)
    w2 = jnp.abs(mlp_apply(params["hyper_w2"], state))
    b2 = mlp_apply(params["hyper_b2"], state)[..., 0]
    return jnp.einsum("...e,...e->...", hid, w2) + b2


# ---------------------------------------------------------------------------
# permutation-invariant set/attention mixer (the scale-free path)
# ---------------------------------------------------------------------------

#: agent-set size at which the attention reduction switches from the
#: pure-jnp ``attention_ref`` oracle to the Pallas ``flash_attention``
#: kernel on TPU (below it the kernel's grid/DMA overhead loses to one
#: small fused XLA softmax; the CPU fallback always uses the oracle)
FLASH_ATTENTION_MIN_AGENTS = 65536


def attention_reduce(q, k, v):
    """Softmax-attention pooling over the agent axis.

    ``q`` [B, Sq, D] (state-conditioned seed queries); ``k``/``v``
    [B, N, D] (per-agent keys/values) -> [B, Sq, D].  Routed through the
    ``kernels/flash_attention`` ops/ref parity contract: the Pallas
    kernel on TPU at :data:`FLASH_ATTENTION_MIN_AGENTS`-plus
    block-aligned agent sets, the identical-math ``attention_ref``
    oracle everywhere else (CPU fallback and small/ragged sets).
    """
    n = k.shape[-2]
    sq = q.shape[-2]
    if (jax.default_backend() == "tpu"
            and n >= FLASH_ATTENTION_MIN_AGENTS
            and n % 128 == 0 and sq % min(128, sq) == 0):
        from repro.kernels.flash_attention import flash_attention
        out = flash_attention(q[:, :, None, :], k[:, :, None, :],
                              v[:, :, None, :], causal=False)
        return out[:, :, 0, :]
    from repro.kernels.flash_attention import attention_ref
    return attention_ref(q, k, v, causal=False)


def set_mixer_init(key, state_dim: int, obs_dim: int, embed: int = 32,
                   n_seeds: int = 4):
    """Mixer parameters whose count is independent of ``n_agents``."""
    ks = jax.random.split(key, 8)
    d = embed
    return {
        # per-agent observation features: keys + value context
        "obs_embed": mlp_init(ks[0], [obs_dim, d, d]),
        # attention keys use d-1 learned dims; slot -1 carries the agent's
        # log importance weight (see set_mixer_apply)
        "key_proj": dense_bias_init(ks[1], d, d - 1, jnp.float32),
        "hyper_q": mlp_init(ks[2], [state_dim, d, n_seeds * (d - 1)]),
        # abs-constrained per-dim scale on the scalar q_i (monotone path)
        "hyper_w1": mlp_init(ks[3], [state_dim, d, d]),
        "hyper_b1": mlp_init(ks[4], [state_dim, d]),
        "val_obs": dense_bias_init(ks[5], d, d, jnp.float32),
        "hyper_w2": mlp_init(ks[6], [state_dim, d, n_seeds * d]),
        "hyper_b2": mlp_init(ks[7], [state_dim, d, 1]),
    }


def set_mixer_apply(params, qs, obs, state, n_seeds: int = 4,
                    embed: int = 32, logw=None):
    """qs: [..., N]; obs: [..., N, obs_dim]; state: [..., state_dim];
    ``logw`` (optional, broadcastable to [..., N]): per-agent log
    importance weights from sampled-agent replay -> Q_tot [...].

    Monotone in every ``q_i``: the only q path is ``elu(q_i * |w1(s)| +
    ...)`` into non-negative attention weights and ``|w2(s)|`` output
    weights.  Permutation-invariant over agents: the reduction is a
    softmax-attention mean over the agent axis.  Importance reweighting
    is exact self-normalised IS — the query's constant ``sqrt(d)`` in
    slot -1 cancels the kernel's ``1/sqrt(d)`` logit scale, so slot -1
    of the key adds ``logw_i`` to the logits on the Pallas and ref
    paths alike.
    """
    d = embed
    batch = qs.shape[:-1]
    n = qs.shape[-1]
    z = mlp_apply(params["obs_embed"], obs)                    # [..., N, d]
    keys = dense_apply(params["key_proj"], z)                  # [..., N, d-1]
    if logw is None:
        logw_col = jnp.zeros(batch + (n, 1), qs.dtype)
    else:
        logw_col = jnp.broadcast_to(
            jnp.asarray(logw, qs.dtype)[..., None], batch + (n, 1))
    keys = jnp.concatenate([keys, logw_col], axis=-1)          # [..., N, d]
    seeds = mlp_apply(params["hyper_q"], state)
    seeds = seeds.reshape(batch + (n_seeds, d - 1))
    const = jnp.full(batch + (n_seeds, 1), math.sqrt(d), seeds.dtype)
    seeds = jnp.concatenate([seeds, const], axis=-1)           # [..., S, d]
    w1 = jnp.abs(mlp_apply(params["hyper_w1"], state))         # [..., d]
    b1 = mlp_apply(params["hyper_b1"], state)
    vals = jax.nn.elu(qs[..., None] * w1[..., None, :]
                      + dense_apply(params["val_obs"], z)
                      + b1[..., None, :])                      # [..., N, d]
    pooled = attention_reduce(seeds.reshape((-1, n_seeds, d)),
                              keys.reshape((-1, n, d)),
                              vals.reshape((-1, n, d)))
    pooled = pooled.reshape(batch + (n_seeds * d,))
    w2 = jnp.abs(mlp_apply(params["hyper_w2"], state))
    b2 = mlp_apply(params["hyper_b2"], state)[..., 0]
    return jnp.sum(pooled * w2, axis=-1) + b2
