"""Roofline-term extraction from compiled dry-run artifacts.

Sources
-------
* ``compiled.cost_analysis()``   -> per-device HLO FLOPs and bytes accessed
* ``compiled.as_text()``         -> post-SPMD per-device HLO; collective
  bytes are summed over every all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute op (per-device operand/output sizes,
  ring-adjusted where the factor is known without parsing replica groups).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16 per chip, 819 GB/s
HBM, ~50 GB/s/link ICI.  cost_analysis of the partitioned module is
per-device, so each roofline term is per-chip by construction (equivalent to
the global quantity divided by #chips for an SPMD program).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (use 1 link conservatively)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# `bf16[8,128,2048]{2,1,0}` shapes; tuples handled by summing members.
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved per collective kind (ring-adjusted approx)."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    seen_done = set()
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if "-done(" in line:
            continue  # -start carries the shape; avoid double count
        b = _shape_bytes(shape_str)
        if kind == "all-reduce":
            b *= 2  # reduce-scatter + all-gather phases of a ring AR
        out[kind] += b
    out["total"] = sum(out.values())
    return out


def roofline_terms(compiled, *, peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW,
                   ici_bw=ICI_BW) -> Dict[str, float]:
    """Three-term roofline from the compiled per-device HLO.

    Primary numbers come from the loop-aware static cost model
    (:mod:`repro.launch.hlo_cost`) because XLA's ``cost_analysis()`` counts
    ``while`` bodies once regardless of trip count — catastrophic for
    scan-over-layers programs.  XLA's own numbers are retained as
    ``xla_*`` cross-check fields (they are lower bounds)."""
    from repro.launch import hlo_cost
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    text = compiled.as_text()
    corrected = hlo_cost.analyze(text)
    flops = corrected["flops"]
    bytes_accessed = corrected["hbm_bytes"]
    coll_total = corrected["collective_bytes"]
    terms = {
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_total,
        "collectives": corrected["collectives"],
        "xla_flops_per_device": float(ca.get("flops", 0.0)),
        "xla_bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "t_compute_s": flops / peak_flops,
        "t_memory_s": bytes_accessed / hbm_bw,
        "t_collective_s": coll_total / ici_bw,
    }
    dom = max(("compute", "memory", "collective"),
              key=lambda k: terms[f"t_{k}_s"])
    terms["dominant"] = dom
    terms["t_bound_s"] = terms[f"t_{dom}_s"]
    return terms


def memory_stats(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(ma, name, None)
        if v is not None:
            out[name] = int(v)
    out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                              + out.get("output_size_in_bytes", 0)
                              + out.get("temp_size_in_bytes", 0)
                              - out.get("alias_size_in_bytes", 0))
    return out


def model_flops_per_step(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); D = tokens processed per step.

    Decode steps process one token per sequence; train includes the 3x
    backward factor already via the 6 (fwd 2 + bwd 4)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch
