"""Shared helpers for the benchmark harnesses.

All paper-table benchmarks run on synthetic data (offline container) at a
CPU-scale configuration (16x16 images, 0.25-width ResNet backbone, tens of
rounds).  The claims validated are DIRECTIONAL (orderings and dynamics),
not the paper's absolute accuracy numbers — see DESIGN.md §1.

``FAST`` mode (env REPRO_BENCH_FAST=1, default on) shrinks rounds/devices so
``python -m benchmarks.run`` finishes on a single CPU core; set
REPRO_BENCH_FAST=0 for the paper-scale overnight runs.
"""
from __future__ import annotations

import os
import time
from typing import Optional

FAST = os.environ.get("REPRO_BENCH_FAST", "1") != "0"

# every paper-table harness builds its FLConfig through bench_params(), so
# REPRO_BENCH_FAMILY=mlp re-runs the whole artifact suite on a different
# registered model family (repro.models.family)
MODEL_FAMILY = os.environ.get("REPRO_BENCH_FAMILY", "cnn")


def bench_params(model_family: Optional[str] = None):
    p = (dict(n_devices=10, n_rounds=20, n_train=1200, local_epochs=2,
              participation=0.4, energy_scale=0.08) if FAST
         else dict(n_devices=40, n_rounds=120, n_train=6000, local_epochs=5,
                   participation=0.1, energy_scale=0.6))
    p["model_family"] = model_family or MODEL_FAMILY
    return p


def family_supports(params: dict, method: str) -> bool:
    """Whether the configured model family can train ``method`` — harnesses
    skip unsupported baseline arms (e.g. heterofl under
    REPRO_BENCH_FAMILY=mlp) instead of crashing mid-suite."""
    from repro.models.family import get_family
    return get_family(params.get("model_family")).supports(method)


def emit(name: str, us_per_call: float, derived: str):
    """CSV contract used by benchmarks.run: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0

    @property
    def us(self):
        return (time.time() - self.t0) * 1e6
