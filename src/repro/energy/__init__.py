"""Pluggable energy scenarios: charge/harvesting profiles, availability
waves and fleet-wide energy budgets driving :class:`repro.core.fleet.
FleetState` through time (docs/ENERGY.md).

Public surface (one-line contracts):

* :class:`ChargeProfile` / :class:`AvailabilityProfile` — the vectorized
  profile protocols (pure ``[n]``-array functions of ``(fleet, sim_time)``).
* ``register_charge_profile`` / ``get_charge_profile`` /
  ``known_charge_profiles`` — the charge-profile registry (mirrors the
  :mod:`repro.models.family` registry idiom); likewise the
  ``*_availability_profile`` trio.
* :class:`EnergyScenario` — one run's resolved scenario: charge +
  availability profiles, per-device profile arrays, the global joule
  budget, and the trivial-path predicates that keep the default
  configuration bit-for-bit with profile-free releases.
* :func:`scenario_from_config` — build the scenario a flat ``FLConfig``
  (or anything with the same fields) asks for.
"""
from repro.energy.profiles import (AvailabilityProfile, ChargeProfile,
                                   EnergyScenario, get_availability_profile,
                                   get_charge_profile,
                                   known_availability_profiles,
                                   known_charge_profiles,
                                   register_availability_profile,
                                   register_charge_profile,
                                   scenario_from_config)

__all__ = [
    "AvailabilityProfile", "ChargeProfile", "EnergyScenario",
    "get_availability_profile", "get_charge_profile",
    "known_availability_profiles", "known_charge_profiles",
    "register_availability_profile", "register_charge_profile",
    "scenario_from_config",
]
