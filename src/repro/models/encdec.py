"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The mel-spectrogram + conv feature extractor is a **stub** per the
assignment carve-out: ``audio_frames`` arrive as precomputed frame
embeddings ``[B, num_audio_frames, d_model]``.

Encoder: bidirectional self-attention, LayerNorm + biases + GELU (Whisper
convention).  Decoder: causal self-attention + cross-attention to the
encoder output.  Positional encoding: RoPE (deviation from Whisper's
learned/sinusoidal embeddings — noted in DESIGN.md; keeps the cache-relative
decode machinery uniform across the framework).

DR-FL: layer mask covers the decoder stack only (an early-exited encoder
cannot feed cross-attention) — partial applicability, see DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.rules import constrain


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def enc_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": L.layernorm_init(cfg.d_model, dtype),
        "attn": L.attention_init(ks[0], cfg, dtype),
        "mlp_norm": L.layernorm_init(cfg.d_model, dtype),
        "mlp": L.gelu_mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def dec_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    p = enc_block_init(ks[0], cfg, dtype)
    p["cross_norm"] = L.layernorm_init(cfg.d_model, dtype)
    p["cross"] = L.attention_init(ks[1], cfg, dtype, cross=True)
    return p


def init(key, cfg):
    dtype = _dt(cfg)
    k_emb, k_enc, k_dec, k_out = jax.random.split(key, 4)
    return {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "encoder": jax.vmap(lambda k: enc_block_init(k, cfg, dtype))(
            jax.random.split(k_enc, cfg.encoder_layers)),
        "enc_norm": L.layernorm_init(cfg.d_model, dtype),
        "decoder": jax.vmap(lambda k: dec_block_init(k, cfg, dtype))(
            jax.random.split(k_dec, cfg.num_layers)),
        "final_norm": L.layernorm_init(cfg.d_model, dtype),
        "unembed": L.dense_init(k_out, cfg.d_model, cfg.vocab_size, dtype),
    }


def unembed_matrix(params, cfg):
    return params["unembed"]["w"]


def encode(params, cfg, audio_frames, *, remat="full"):
    """audio_frames: [B, T_a, d] (stub frontend output) -> [B, T_a, d]."""
    x = audio_frames.astype(_dt(cfg))
    positions = jnp.arange(x.shape[1])

    def body(x, bp):
        h = L.layernorm_apply(bp["attn_norm"], x, cfg.norm_eps)
        a, _ = L.attention_apply(bp["attn"], cfg, h, positions, causal=False,
                                 norm_eps=cfg.norm_eps)
        x = x + a
        h = L.layernorm_apply(bp["mlp_norm"], x, cfg.norm_eps)
        return constrain(x + L.gelu_mlp_apply(bp["mlp"], h)), None

    body = jax.checkpoint(body) if remat != "none" else body
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.layernorm_apply(params["enc_norm"], x, cfg.norm_eps)


def _dec_block(bp, cfg, x, enc_out, positions, gate, *, self_cache=None,
               cross_cache=None, use_pallas=False, attn_chunk=0):
    h = L.layernorm_apply(bp["attn_norm"], x, cfg.norm_eps)
    a, new_self = L.attention_apply(bp["attn"], cfg, h, positions, causal=True,
                                    cache=self_cache, use_pallas=use_pallas,
                                    attn_chunk=attn_chunk,
                                    norm_eps=cfg.norm_eps)
    x = x + gate * a
    h = L.layernorm_apply(bp["cross_norm"], x, cfg.norm_eps)
    c, _ = L.attention_apply(bp["cross"], cfg, h, positions, causal=False,
                             kv_src=enc_out if cross_cache is None else h,
                             cache=cross_cache, norm_eps=cfg.norm_eps)
    x = x + gate * c
    h = L.layernorm_apply(bp["mlp_norm"], x, cfg.norm_eps)
    x = x + gate * L.gelu_mlp_apply(bp["mlp"], h)
    return x, new_self


def apply(params, cfg, tokens, audio_frames, *, layer_mask=None, window=None,
          use_pallas=False, attn_chunk=0, remat="full"):
    """tokens: [B,S] decoder input; audio_frames: [B,T_a,d]."""
    enc_out = encode(params, cfg, audio_frames, remat=remat)
    B, S = tokens.shape
    x = params["embed"]["emb"][tokens]
    positions = jnp.arange(S)
    mask = (jnp.ones((cfg.num_layers,), jnp.float32)
            if layer_mask is None else layer_mask.astype(jnp.float32))

    def body(x, scanned):
        bp, gate = scanned
        x, _ = _dec_block(bp, cfg, x, enc_out, positions, gate.astype(x.dtype),
                          use_pallas=use_pallas, attn_chunk=attn_chunk)
        return constrain(x), None

    body = jax.checkpoint(body) if remat != "none" else body
    x, _ = jax.lax.scan(body, x, (params["decoder"], mask))
    x = L.layernorm_apply(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def logits_fn(params, cfg, hidden):
    return (hidden @ unembed_matrix(params, cfg)).astype(jnp.float32)


def decode_init(params, cfg, batch: int, seq_len: int, *, window=None,
                audio_frames=None):
    w = cfg.window if window is None else window
    clen = min(seq_len, w) if w else seq_len
    dtype = _dt(cfg)
    Ld, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
    if audio_frames is None:
        audio_frames = jnp.zeros((batch, cfg.num_audio_frames, cfg.d_model), dtype)
    enc_out = encode(params, cfg, audio_frames, remat="none")

    def cross_kv(bp):
        k = L.dense_apply(bp["cross"]["wk"], enc_out).reshape(batch, -1, Hkv, hd)
        v = L.dense_apply(bp["cross"]["wv"], enc_out).reshape(batch, -1, Hkv, hd)
        return {"k": k, "v": v, "pos": jnp.zeros((), jnp.int32)}

    return {
        "self": {
            "k": jnp.zeros((Ld, batch, clen, Hkv, hd), dtype),
            "v": jnp.zeros((Ld, batch, clen, Hkv, hd), dtype),
            "pos": jnp.zeros((Ld,), jnp.int32),
        },
        "cross": jax.vmap(cross_kv)(params["decoder"]),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg, cache, tokens, pos, *, layer_mask=None, window=None):
    x = params["embed"]["emb"][tokens]
    mask = (jnp.ones((cfg.num_layers,), jnp.float32)
            if layer_mask is None else layer_mask.astype(jnp.float32))
    positions = pos[None] if jnp.ndim(pos) == 0 else pos

    def body(x, scanned):
        bp, sc, cc, gate = scanned
        x, sc = _dec_block(bp, cfg, x, None, positions, gate.astype(x.dtype),
                           self_cache=sc, cross_cache=cc)
        return x, sc

    x, new_self = jax.lax.scan(
        body, x, (params["decoder"], cache["self"], cache["cross"], mask))
    new_cache = {"self": new_self, "cross": cache["cross"], "pos": cache["pos"] + 1}
    x = L.layernorm_apply(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, x), new_cache
