"""Heterogeneous-FL baselines the paper compares against (§5.1.1).

* **HeteroFL** (Diao et al., ICLR'21): clients train *width-sliced*
  subnetworks of a single global model (channel fraction p in {1/4, 1/2,
  3/4, 1}); aggregation averages each weight entry over the clients whose
  slice contains it.
* **ScaleFL** (Ilhan et al., CVPR'23): 2D (depth + width) scaling with
  self-distillation.  Our variant: depth prefix (exit m) x width slice p_m;
  local training distils the deepest held exit into shallower ones.  (The
  paper's ScaleFL also uses superposition coding for aggregation — out of
  scope; noted in DESIGN.md.)

Both operate on the ResNet CNN used by the paper repro.  Width slicing is
structural (channel prefixes), so aggregation masks are computed from slice
shapes rather than stored.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

WIDTH_LEVELS = (0.25, 0.5, 0.75, 1.0)


def _slice_arr(a: jnp.ndarray, frac: float, axes: Sequence[int]):
    sl = [slice(None)] * a.ndim
    for ax in axes:
        n = a.shape[ax]
        sl[ax] = slice(0, max(1, math.ceil(n * frac)))
    return a[tuple(sl)]


def _conv_axes(path_has_stem_in: bool):
    # conv kernels are [kh, kw, cin, cout]; stem keeps cin=3 full.
    return (3,) if path_has_stem_in else (2, 3)


def width_slice_cnn(params: Dict, frac: float) -> Dict:
    """HeteroFL submodel: channel-prefix slice of every layer."""
    out = {"stem": {"conv": _slice_arr(params["stem"]["conv"], frac, (3,)),
                    "gn": jax.tree.map(lambda a: _slice_arr(a, frac, (0,)),
                                       params["stem"]["gn"])},
           "stages": [], "exits": []}
    for stage in params["stages"]:
        blocks = []
        for bp in stage:
            nb = {
                "conv1": _slice_arr(bp["conv1"], frac, (2, 3)),
                "gn1": jax.tree.map(lambda a: _slice_arr(a, frac, (0,)), bp["gn1"]),
                "conv2": _slice_arr(bp["conv2"], frac, (2, 3)),
                "gn2": jax.tree.map(lambda a: _slice_arr(a, frac, (0,)), bp["gn2"]),
            }
            if "proj" in bp:
                nb["proj"] = _slice_arr(bp["proj"], frac, (2, 3))
            blocks.append(nb)
        out["stages"].append(blocks)
    for ep in params["exits"]:
        out["exits"].append({
            "bottleneck": _slice_arr(ep["bottleneck"], frac, (2, 3)),
            "gn": jax.tree.map(lambda a: _slice_arr(a, frac, (0,)), ep["gn"]),
            "w": _slice_arr(ep["w"], frac, (0,)),
            "b": ep["b"],
        })
    return out


def heterofl_aggregate(global_params: Dict, updates: List[Dict],
                       fracs: List[float], weights: List[float] = None):
    """Scatter-average width-sliced client updates into the global tree.

    Each client's update has the sliced shapes; entry (i,j,...) of a global
    weight is averaged over the clients whose slice covers it."""
    if weights is None:
        weights = [1.0] * len(updates)

    def agg(gp, *ups):
        num = jnp.zeros(gp.shape, jnp.float32)
        den = jnp.zeros(gp.shape, jnp.float32)
        for u, w in zip(ups, weights):
            pad = [(0, gs - us) for gs, us in zip(gp.shape, u.shape)]
            up = jnp.pad(u.astype(jnp.float32), pad)
            mk = jnp.pad(jnp.ones(u.shape, jnp.float32), pad)
            num = num + w * up
            den = den + w * mk
        avg = jnp.where(den > 0, num / jnp.maximum(den, 1e-12), 0.0)
        return (gp.astype(jnp.float32) + avg).astype(gp.dtype)

    # tree structures differ (sliced vs full) only in leaf shapes -> same treedef
    return jax.tree.map(agg, global_params, *updates)


def scalefl_submodel(params: Dict, model_idx: int) -> Dict:
    """ScaleFL 2D scaling: depth prefix (exit model_idx) + width p_m."""
    frac = WIDTH_LEVELS[model_idx]
    sliced = width_slice_cnn(params, frac)
    return {"stem": sliced["stem"],
            "stages": sliced["stages"][:model_idx + 1],
            "exits": sliced["exits"][:model_idx + 1]}


def kd_loss(student_logits, teacher_logits, temp: float = 2.0):
    """Self-distillation: deepest held exit teaches shallower exits."""
    t = jax.nn.softmax(teacher_logits / temp, axis=-1)
    ls = jax.nn.log_softmax(student_logits / temp, axis=-1)
    return -jnp.mean(jnp.sum(t * ls, axis=-1)) * temp * temp
