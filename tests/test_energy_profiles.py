"""Energy-scenario subsystem (repro.energy): profiles, budgets, parity.

Contracts:
* the DEFAULT scenario (``charge_profile="constant"``, ``charge_rate=0``,
  ``availability_profile="always"``, ``global_budget_j=0``) is bit-for-bit
  identical to the pre-profile engine — pinned against the frozen n=8
  trajectories in ``tests/data/frozen_energy_n8.json`` for BOTH engine
  modes;
* ``EnergySpec`` profile fields survive ``from_flat``/``to_flat`` exactly,
  and invalid names/params raise at construction;
* profile kernels behave: solar clips at zero, the carbon window opens and
  closes with local intensity, diurnal availability waves follow
  ``tz_phase``, and each host twin agrees with its device mask;
* the global joule budget is a HARD constraint for every selector, and
  exhausting it terminates the run with ``reason="budget_exhausted"``;
* infeasible ``energy_scale`` (no fresh device can afford its cheapest
  submodel) raises at build time instead of wiping the fleet in round 0;
* the new per-device arrays ride the kill-and-resume checkpoint contract
  (``FLEET_CHECKPOINT_FIELDS`` covers every FleetState array field).
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.fleet import make_fleet_state
from repro.energy import (EnergyScenario, get_availability_profile,
                          get_charge_profile, known_availability_profiles,
                          known_charge_profiles, scenario_from_config)
from repro.energy.profiles import (CARBON_INTENSITY_CUTOFF, AlwaysAvailable,
                                   CarbonWindowCharge, ConstantCharge,
                                   DiurnalAvailability, SolarCharge)
from repro.fl import FLConfig, run_simulation
from repro.fl.spec import EnergySpec, SimulationSpec

FROZEN = os.path.join(os.path.dirname(__file__), "data",
                      "frozen_energy_n8.json")


def _np_fleet(n=8, seed=0):
    return make_fleet_state(n, seed, backend="numpy")


def _scenario(**kw):
    kw.setdefault("charge", ConstantCharge())
    kw.setdefault("availability", AlwaysAvailable())
    return EnergyScenario(**kw)


# ---------------------------------------------------------------------------
# registries + spec surface
# ---------------------------------------------------------------------------


def test_registries_know_the_builtin_profiles():
    assert set(known_charge_profiles()) >= {"constant", "solar",
                                            "carbon_window"}
    assert set(known_availability_profiles()) >= {"always", "diurnal"}
    with pytest.raises(ValueError, match="unknown charge profile"):
        get_charge_profile("fusion")
    with pytest.raises(ValueError, match="unknown availability profile"):
        get_availability_profile("sometimes")


def test_energy_spec_validates_profiles():
    with pytest.raises(ValueError, match="charge_profile"):
        EnergySpec(charge_profile="fusion")
    with pytest.raises(ValueError, match="availability_profile"):
        EnergySpec(availability_profile="sometimes")
    with pytest.raises(ValueError, match="charge_rate"):
        EnergySpec(charge_rate=-1.0)
    with pytest.raises(ValueError, match="charge_period"):
        EnergySpec(charge_period=0.0)
    with pytest.raises(ValueError, match="availability_duty"):
        EnergySpec(availability_duty=0.0)
    with pytest.raises(ValueError, match="availability_duty"):
        EnergySpec(availability_duty=1.5)
    with pytest.raises(ValueError, match="global_budget_j"):
        EnergySpec(global_budget_j=-5.0)


def test_energy_spec_round_trips_through_flat_config():
    cfg = FLConfig(n_devices=4, n_rounds=2, charge_profile="solar",
                   charge_rate=3.5, charge_period=1234.0,
                   availability_profile="diurnal", availability_duty=0.4,
                   global_budget_j=777.0)
    spec = SimulationSpec.from_flat(cfg)
    assert spec.energy.charge_profile == "solar"
    assert spec.energy.charge_rate == 3.5
    assert spec.energy.charge_period == 1234.0
    assert spec.energy.availability_profile == "diurnal"
    assert spec.energy.availability_duty == 0.4
    assert spec.energy.global_budget_j == 777.0
    back = spec.to_flat()
    for f in dataclasses.fields(FLConfig):
        assert getattr(back, f.name) == getattr(cfg, f.name), f.name


def test_scenario_from_config_resolves_profiles():
    cfg = FLConfig(n_devices=4, n_rounds=2, charge_profile="carbon_window",
                   charge_rate=2.0, charge_period=500.0,
                   availability_profile="diurnal", availability_duty=0.3)
    sc = scenario_from_config(cfg)
    assert isinstance(sc.charge, CarbonWindowCharge)
    assert sc.charge.period == 500.0
    assert isinstance(sc.availability, DiurnalAvailability)
    assert sc.availability.duty == 0.3
    assert not sc.is_trivial
    # the default config is the trivial scenario — no hooks run at all
    assert scenario_from_config(FLConfig(n_devices=4, n_rounds=2)).is_trivial


# ---------------------------------------------------------------------------
# profile kernels
# ---------------------------------------------------------------------------


def test_solar_rate_is_clipped_sinusoid():
    fleet = _scenario(charge=SolarCharge(period=100.0),
                      charge_rate=4.0).init_fleet(_np_fleet(), seed=7)
    prof = SolarCharge(period=100.0)
    tz = np.asarray(fleet.tz_phase, np.float64)
    amp = np.asarray(fleet.charge_rate, np.float64)
    for t in (0.0, 13.0, 37.5, 80.0):
        want = amp * np.maximum(np.sin(2 * np.pi * (t / 100.0 + tz)), 0.0)
        np.testing.assert_allclose(prof.rate(fleet, t), want, rtol=1e-6)
    # night side of every phase is exactly zero, never negative
    assert (prof.rate(fleet, 0.0) >= 0.0).all()


def test_carbon_window_gates_and_reopens():
    prof = CarbonWindowCharge(period=100.0)
    tz = np.zeros(1)
    # local midnight: intensity 0 -> open, full charge rate
    assert prof.ok_host(tz, 0.0).all()
    # local peak (t = period/2): intensity 1 -> blocked, zero charge
    assert not prof.ok_host(tz, 50.0).any()
    fleet = _np_fleet(1, seed=1).replace(charge_rate=np.ones(1),
                                         tz_phase=np.zeros(1))
    np.testing.assert_allclose(prof.rate(fleet, 50.0), [0.0], atol=1e-12)
    # next_ok from the blocked peak lands exactly where the gate reopens
    t_open = float(prof.next_ok_host(tz, 50.0)[0])
    assert t_open > 50.0
    assert prof.ok_host(tz, t_open + 1e-6).all()
    assert not prof.ok_host(tz, t_open - 1.0).any()
    # already-open devices report "now"
    assert float(prof.next_ok_host(tz, 0.0)[0]) == 0.0


def test_diurnal_availability_follows_local_day():
    prof = DiurnalAvailability(period=100.0, duty=0.5)
    tz = np.array([0.0, 0.5])          # one device half a day offset
    assert list(prof.available_host(tz, 10.0)) == [True, False]
    assert list(prof.available_host(tz, 60.0)) == [False, True]
    # device-side mask agrees with the host twin
    fleet = _np_fleet(2, seed=2).replace(tz_phase=tz.copy())
    np.testing.assert_array_equal(prof.available(fleet, 10.0),
                                  prof.available_host(tz, 10.0))
    # a blocked device's next opening is the start of its next local day
    nxt = prof.next_available_host(tz, 60.0)
    assert float(nxt[0]) == pytest.approx(100.0)
    assert float(nxt[1]) == 60.0


def test_scenario_availability_combines_wave_and_carbon_gate():
    sc = _scenario(charge=CarbonWindowCharge(period=100.0),
                   availability=DiurnalAvailability(period=100.0, duty=0.6),
                   charge_rate=1.0)
    assert not sc.trivial_availability
    tz = np.array([0.0])
    fleet = _np_fleet(1, seed=3).replace(tz_phase=tz.copy(),
                                         charge_rate=np.ones(1))
    for t in (5.0, 30.0, 50.0, 70.0, 95.0):
        av = sc.available(fleet, t)
        host = sc.available_host(tz, t)
        np.testing.assert_array_equal(np.asarray(av), host)
        # the AND of the two gates, by hand
        want = ((t / 100.0 % 1.0) < 0.6) and (
            0.5 - 0.5 * np.cos(2 * np.pi * t / 100.0)
            <= CARBON_INTENSITY_CUTOFF)
        assert bool(host[0]) == want, t
    # wake time is strictly in the future when the gate is shut
    t_wake = sc.next_available_host(tz, 70.0)
    assert t_wake > 70.0


def test_apply_charge_caps_and_never_resurrects():
    fleet = _np_fleet(3, seed=4)
    sc = _scenario(charge_rate=10.0, energy_scale=0.01)
    fleet = sc.init_fleet(fleet, seed=4)
    cap = np.asarray(fleet.battery) * 0.01
    low = cap * 0.1
    fleet = fleet.replace(remaining=low.copy(),
                          alive=np.array([True, True, False]))
    out = sc.apply_charge(fleet, 0.0, 1e9)   # absurdly long: must hit cap
    rem = np.asarray(out.remaining)
    np.testing.assert_allclose(rem[:2], cap[:2], rtol=1e-6)
    assert rem[2] == low[2]                  # dead device holds its charge
    # zero-length interval is the identity
    assert sc.apply_charge(fleet, 5.0, 5.0) is fleet


def test_init_fleet_is_seed_stable_across_scenarios():
    f1 = _scenario(charge=SolarCharge(), charge_rate=2.0).init_fleet(
        _np_fleet(16, seed=9), seed=9)
    f2 = _scenario(charge=CarbonWindowCharge(), charge_rate=2.0).init_fleet(
        _np_fleet(16, seed=9), seed=9)
    # same seed -> same phases, whatever the profile
    np.testing.assert_array_equal(f1.tz_phase, f2.tz_phase)
    np.testing.assert_array_equal(f1.charge_rate, f2.charge_rate)
    assert (np.asarray(f1.tz_phase) >= 0).all()
    assert (np.asarray(f1.tz_phase) < 1).all()


# ---------------------------------------------------------------------------
# checkpoint coverage of the new arrays
# ---------------------------------------------------------------------------


def test_checkpoint_fields_cover_profile_arrays():
    from repro.checkpoint.io import FLEET_CHECKPOINT_FIELDS
    from repro.core.fleet import _ARRAY_FIELDS
    assert set(FLEET_CHECKPOINT_FIELDS) == set(_ARRAY_FIELDS)
    assert {"charge_rate", "tz_phase"} <= set(FLEET_CHECKPOINT_FIELDS)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

_BASE = dict(n_devices=8, n_rounds=6, participation=0.5, n_train=600,
             local_epochs=1, method="drfl", selector="marl",
             energy_scale=0.05, seed=3)


def test_infeasible_energy_scale_raises_at_build():
    cfg = FLConfig(**{**_BASE, "energy_scale": 1e-5})
    with pytest.raises(ValueError, match="cheapest submodel"):
        run_simulation(cfg, verbose=False)


@pytest.mark.parametrize("selector", ["random", "greedy", "static", "marl"])
def test_global_budget_is_a_hard_constraint(selector):
    cfg = FLConfig(**{**_BASE, "selector": selector, "n_rounds": 4,
                      "n_train": 400}, global_budget_j=150.0)
    h = run_simulation(cfg, verbose=False)
    assert h["budget"]["limit"] == 150.0
    assert h["budget"]["spent"] <= 150.0 + 1e-6
    if h["terminated"]["reason"] == "budget_exhausted":
        assert h["terminated"]["budget"] == "energy"


def test_budget_exhaustion_terminates_async():
    cfg = FLConfig(**{**_BASE, "n_rounds": 6, "n_train": 400},
                   engine_mode="async", global_budget_j=150.0)
    h = run_simulation(cfg, verbose=False)
    assert h["budget"]["spent"] <= 150.0 + 1e-6
    assert h["terminated"]["reason"] == "budget_exhausted"
    assert h["terminated"]["budget"] == "energy"


def test_solar_recharge_extends_the_fleet():
    base = FLConfig(**{**_BASE, "n_rounds": 4, "n_train": 400})
    solar = dataclasses.replace(base, charge_profile="solar",
                                charge_rate=5.0)
    h0 = run_simulation(base, verbose=False)
    h1 = run_simulation(solar, verbose=False)
    # harvesting strictly adds energy on the same trajectory of picks
    assert h1["energy"][-1] > h0["energy"][-1]


def test_diurnal_availability_gates_participants():
    # duty so small every device is offline most of its day; period longer
    # than the run so the mask is static: only the ~duty fraction of
    # devices whose local morning overlaps t=0 may ever participate
    cfg = FLConfig(**{**_BASE, "n_rounds": 3, "n_train": 400},
                   availability_profile="diurnal", availability_duty=0.25,
                   charge_period=1e9)
    h = run_simulation(cfg, verbose=False)
    sc = scenario_from_config(cfg)
    from repro.fl import build_world
    wfleet = build_world(cfg).fleet
    open_now = np.flatnonzero(
        sc.available_host(np.asarray(wfleet.tz_phase, np.float64), 0.0))
    seen = {i for p in h["participants"] for i in p}
    assert seen <= set(open_now.tolist())


# ---------------------------------------------------------------------------
# bit-for-bit parity: default scenario vs the frozen trajectories
# ---------------------------------------------------------------------------


def _assert_frozen(mode):
    with open(FROZEN) as fh:
        ref = json.load(fh)
    cfg = FLConfig(**{**ref["config"], "engine_mode": mode,
                      # explicit defaults: the trivial scenario spelled out
                      "charge_profile": "constant",
                      "availability_profile": "always",
                      "global_budget_j": 0.0})
    h = run_simulation(cfg, verbose=False)
    r = ref[mode]
    np.testing.assert_array_equal(np.asarray(h["acc_mean"]), r["acc_mean"])
    np.testing.assert_array_equal(np.asarray(h["energy"]), r["energy"])
    np.testing.assert_array_equal(np.asarray(h["reward"]), r["reward"])
    np.testing.assert_array_equal(np.asarray(h["sim_time"]), r["sim_time"])
    assert [list(p) for p in h["participants"]] == r["participants"]
    assert [list(m) for m in h["model_choices"]] == r["model_choices"]
    assert list(h["alive"]) == r["alive"]
    assert h["dropouts"] == r["dropouts"]


def test_default_scenario_bit_for_bit_sync():
    _assert_frozen("sync")


def test_default_scenario_bit_for_bit_async():
    _assert_frozen("async")
