"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.layer_agg import layer_agg_op, layer_agg_ref
from repro.kernels.rmsnorm import rmsnorm_op, rmsnorm_ref


def _fa_ref(q, k, v, causal, window):
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    qb = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kb = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vb = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    o = attention_ref(qb, kb, vb, causal=causal, window=window)
    return o.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("B,S,Hq,Hkv,D,causal,window,bq,bk", [
    (2, 128, 4, 2, 64, True, 0, 64, 64),
    (1, 256, 8, 1, 32, True, 0, 128, 64),
    (2, 128, 2, 2, 128, True, 32, 32, 32),
    (1, 64, 4, 4, 64, False, 0, 64, 64),
    (1, 128, 6, 2, 64, True, 0, 128, 128),
])
def test_flash_attention_sweep(B, S, Hq, Hkv, D, causal, window, bq, bk,
                               dtype, tol):
    key = jax.random.PRNGKey(B * S + Hq)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    ref = _fa_ref(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,Sq,Sk,D,bq,bk", [
    # the set-mixer regime: a few seed queries pooling over a large
    # (block-aligned) agent axis, non-causal, rectangular Sq != Sk
    (2, 4, 512, 32, 4, 128),
    (1, 4, 4096, 32, 4, 256),
    (3, 8, 256, 64, 8, 64),
])
def test_flash_attention_rectangular_noncausal(B, Sq, Sk, D, bq, bk):
    """ops/ref parity for the attention-reduce shape class (Pallas
    interpret mode) — seed queries over agent keys, no masking."""
    key = jax.random.PRNGKey(Sq * Sk + D)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, 1, D))
    k = jax.random.normal(ks[1], (B, Sk, 1, D))
    v = jax.random.normal(ks[2], (B, Sk, 1, D))
    out = flash_attention(q, k, v, causal=False, block_q=bq, block_k=bk,
                          interpret=True)
    ref = _fa_ref(q, k, v, False, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_attention_reduce_matches_ref():
    """The set mixer's pooling entry point is the oracle off-TPU (and the
    kernel's math on it): [B, S, D] queries over [B, N, D] keys/values."""
    from repro.core.marl.networks import attention_reduce
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 4, 32))
    k = jax.random.normal(ks[1], (2, 100, 32))
    v = jax.random.normal(ks[2], (2, 100, 32))
    out = attention_reduce(q, k, v)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)


@hypothesis.given(
    n=st.integers(1, 8), l=st.integers(1, 6),
    dpow=st.integers(4, 9), seed=st.integers(0, 99),
    zero_col=st.booleans())
@hypothesis.settings(max_examples=15, deadline=None)
def test_layer_agg_property(n, l, dpow, seed, zero_col):
    D = 2 ** dpow
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    U = jax.random.normal(ks[0], (n, l, D))
    M = (jax.random.uniform(ks[1], (n, l)) > 0.3).astype(jnp.float32)
    if zero_col:
        M = M.at[:, 0].set(0.0)        # a layer NO client trained
    w = jax.random.uniform(ks[2], (n,)) * 10 + 0.1
    out = layer_agg_op(U, M, w, block_d=64, interpret=True)
    ref = layer_agg_ref(U, M, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-4)
    if zero_col:
        np.testing.assert_allclose(np.asarray(out[0]), 0.0)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-6), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("shape", [(8, 64), (2, 16, 128), (3, 4, 5, 256),
                                   # odd row counts: block_rows degrades to 1
                                   (7, 64), (3, 11, 128), (1, 256)])
def test_rmsnorm_sweep(shape, dtype, tol):
    key = jax.random.PRNGKey(sum(shape))
    x = (jax.random.normal(key, shape) * 3).astype(dtype)
    s = jax.random.normal(jax.random.fold_in(key, 1), shape[-1:]).astype(dtype)
    out = rmsnorm_op(x, s, interpret=True)
    ref = rmsnorm_ref(x.reshape(-1, shape[-1]), s).reshape(shape)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_aggregate_stacked_leaf_matches_layerwise():
    """Kernel path == repro.core.aggregation.layerwise_aggregate on stacked
    leaves (the production Step-2 path)."""
    from repro.core.aggregation import layerwise_aggregate
    from repro.kernels.layer_agg import aggregate_stacked_leaf
    key = jax.random.PRNGKey(0)
    L, shape = 4, (4, 8, 16)
    gp = jax.random.normal(key, shape)
    ups = [jax.random.normal(jax.random.fold_in(key, i), shape) for i in range(3)]
    masks = [jnp.asarray([1., 1., 0., 0.]), jnp.asarray([1., 1., 1., 0.]),
             jnp.asarray([1., 0., 0., 0.])]
    w = [2.0, 1.0, 3.0]
    out_k = aggregate_stacked_leaf(gp, ups, masks, w, interpret=True)
    masks_b = [{"x": m.reshape(L, 1, 1)} for m in masks]
    out_r = layerwise_aggregate({"x": gp}, [{"x": u} for u in ups], masks_b, w)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r["x"]),
                               atol=1e-5, rtol=1e-4)
