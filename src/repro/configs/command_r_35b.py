"""Command-R-35B — GQA, no-bias dense decoder [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22528, vocab_size=256000,
    head_dim=128, rope_theta=8_000_000.0,
    attn_bias=False, mlp_bias=False, tie_embeddings=True,
    exit_points=(10, 20, 30, 40),
    source="hf:CohereForAI/c4ai-command-r-v01",
)
