"""Typed ``SimulationSpec`` layer over the flat :class:`FLConfig`.

``FLConfig`` is the stable flat compatibility surface — 30+ keyword
arguments, validated nowhere, so a typo like ``selector="mral"`` or
``engine_mode="asynch"`` used to fail deep inside a run (or worse, run the
wrong arm silently).  ``SimulationSpec`` groups the same knobs into typed
sub-specs with ``__post_init__`` validation:

* :class:`ModelSpec`  — which :class:`repro.models.family.ModelFamily` to
  train (``family="cnn"`` is the registered default; ``"mlp"`` is the
  early-exit MLP), plus the local-training knobs (width, image size,
  epochs, batch, lr).
* :class:`EngineSpec` — round scheduling: sync/async mode, staleness decay,
  async budgets, client-update executor, fleet sharding mesh.
* :class:`MarlSpec`   — dual-selection strategy, QMIX training cadence and
  global-state mode (flat vs the fixed-width factored summary).
* :class:`EnergySpec` — battery scaling and hot-plug scenario.

``from_flat`` / ``to_flat`` bridge the two representations bit-for-bit
(`to_flat(from_flat(cfg)) == cfg` for every valid flat config), so every
existing ``FLConfig(...)`` callsite keeps working unchanged —
``run_simulation`` accepts either and validates both through this module.

    from repro.fl import SimulationSpec, ModelSpec, run_simulation
    spec = SimulationSpec(n_devices=64, n_rounds=10,
                          model=ModelSpec(family="mlp"),
                          marl=MarlSpec(selector="greedy"))
    hist = run_simulation(spec)

Public surface (one-line contracts):

* :class:`ModelSpec` / :class:`EngineSpec` / :class:`MarlSpec` /
  :class:`EnergySpec` — validated sub-specs (each field documented
  inline; construction raises ``ValueError`` on any bad knob).
* :class:`SimulationSpec` — one experiment-grid cell; composes the four
  sub-specs and cross-validates method x family support.
* :meth:`SimulationSpec.from_flat` — lift + validate a flat ``FLConfig``.
* :meth:`SimulationSpec.to_flat` — lower to the flat engine surface
  (exact inverse of ``from_flat``).
* :func:`ensure_flat_config` — accept either representation, validate,
  return the ``FLConfig`` the engine runs on (flat inputs are returned
  by identity, keeping the compatibility surface bit-for-bit).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.selection import MIXER_MODES as _CONCRETE_MIXER_MODES
from repro.core.selection import STATE_MODES as _CONCRETE_STATE_MODES
from repro.fl.simulation import FLConfig
from repro.models.family import get_family, known_families

METHODS = ("drfl", "heterofl", "scalefl")
SELECTORS = ("marl", "greedy", "random", "static")
ENGINE_MODES = ("sync", "async")
CLIENT_EXECUTORS = ("auto", "perclient", "batched")
# config level adds "auto" on top of the selector's concrete modes, so a
# mode added in repro.core.selection is accepted here automatically
STATE_MODES = ("auto",) + _CONCRETE_STATE_MODES
MIXER_MODES = ("auto",) + _CONCRETE_MIXER_MODES


def _check(cond, msg):
    if not cond:
        raise ValueError(msg)


def _check_choice(value, choices, field):
    _check(value in choices,
           f"{field}={value!r} is not one of {', '.join(choices)}")


@dataclasses.dataclass
class ModelSpec:
    """What each client trains: a registered model family + local knobs."""
    family: str = "cnn"                 # repro.models.family registry key
    width_mult: float = 0.25            # backbone slimming (CPU budget)
    hw: int = 16                        # image size
    num_classes: int = 10
    local_epochs: int = 5               # paper §5
    batch_size: int = 32                # paper §5
    lr: float = 0.05                    # paper §5

    def __post_init__(self):
        _check_choice(self.family, known_families(), "model.family")
        _check(self.width_mult > 0, "model.width_mult must be > 0")
        _check(self.hw >= 1, "model.hw must be >= 1")
        _check(self.num_classes >= 2, "model.num_classes must be >= 2")
        _check(self.local_epochs >= 1, "model.local_epochs must be >= 1")
        _check(self.batch_size >= 1, "model.batch_size must be >= 1")
        _check(self.lr > 0, "model.lr must be > 0")


@dataclasses.dataclass
class EngineSpec:
    """Round scheduling (repro.fl.engine) + client-update executor."""
    mode: str = "sync"                  # sync | async
    client_executor: str = "auto"       # auto | perclient | batched
    staleness_decay: float = 0.5        # FedAsync (1+s)^-decay
    async_eval_every: int = 1
    async_time_horizon: float = 0.0     # sim-seconds (0 = task budget)
    async_task_budget: int = 0          # client tasks (0 = sync-equivalent)
    fleet_mesh: int = 0                 # FleetState shards (0/1 off, -1 all)

    def __post_init__(self):
        _check_choice(self.mode, ENGINE_MODES, "engine.mode")
        _check_choice(self.client_executor, CLIENT_EXECUTORS,
                      "engine.client_executor")
        _check(self.staleness_decay >= 0,
               "engine.staleness_decay must be >= 0")
        _check(self.async_eval_every >= 1,
               "engine.async_eval_every must be >= 1")
        _check(self.async_time_horizon >= 0,
               "engine.async_time_horizon must be >= 0")
        _check(self.async_task_budget >= 0,
               "engine.async_task_budget must be >= 0")
        _check(self.fleet_mesh >= -1,
               "engine.fleet_mesh must be >= -1 (-1 = all local devices)")


@dataclasses.dataclass
class MarlSpec:
    """Dual-selection strategy + QMIX training cadence (paper §4.3)."""
    selector: str = "marl"              # marl | greedy | random | static
    reward_weights: Tuple[float, float, float] = (1000.0, 0.01, 1.0)
    train_every: int = 2
    updates_per_round: int = 2
    episodes: int = 1                   # selector pre-training episodes
    state_mode: str = "auto"            # auto | flat | factored QMIX state
    mixer_mode: str = "auto"            # auto | flat | set QMIX mixer
    agent_budget: int = 4096            # sampled-agent replay cap (set mixer)

    def __post_init__(self):
        _check_choice(self.selector, SELECTORS, "marl.selector")
        _check_choice(self.state_mode, STATE_MODES, "marl.state_mode")
        _check_choice(self.mixer_mode, MIXER_MODES, "marl.mixer_mode")
        _check(len(tuple(self.reward_weights)) == 3,
               "marl.reward_weights must have exactly 3 entries (w1,w2,w3)")
        _check(self.train_every >= 1, "marl.train_every must be >= 1")
        _check(self.updates_per_round >= 0,
               "marl.updates_per_round must be >= 0")
        _check(self.episodes >= 1, "marl.episodes must be >= 1")
        _check(self.agent_budget >= 1, "marl.agent_budget must be >= 1")


@dataclasses.dataclass
class EnergySpec:
    """Battery scaling, the paper's §4.2 hot-plug scenario, and the
    pluggable energy scenarios (repro.energy; docs/ENERGY.md): harvesting
    charge profiles, availability waves, and the fleet-wide joule budget.
    The profile defaults are the trivial scenario — bit-for-bit identical
    to profile-free runs."""
    scale: float = 1.0                  # scales batteries to stress budgets
    hotplug_round: int = 0
    hotplug_n: int = 0
    charge_profile: str = "constant"    # repro.energy charge registry key
    charge_rate: float = 0.0            # fleet-mean harvest amplitude, J/s
    charge_period: float = 86400.0      # profile day length, sim-seconds
    availability_profile: str = "always"  # availability registry key
    availability_duty: float = 1.0      # fraction of the local day online
    global_budget_j: float = 0.0        # fleet-wide joule budget (0 = off)

    def __post_init__(self):
        from repro.energy import (known_availability_profiles,
                                  known_charge_profiles)
        _check(self.scale > 0, "energy.scale must be > 0")
        _check(self.hotplug_round >= 0,
               "energy.hotplug_round must be >= 0")
        _check(self.hotplug_n >= 0, "energy.hotplug_n must be >= 0")
        _check_choice(self.charge_profile, known_charge_profiles(),
                      "energy.charge_profile")
        _check_choice(self.availability_profile,
                      known_availability_profiles(),
                      "energy.availability_profile")
        _check(self.charge_rate >= 0, "energy.charge_rate must be >= 0")
        _check(self.charge_period > 0, "energy.charge_period must be > 0")
        _check(0 < self.availability_duty <= 1,
               "energy.availability_duty must be in (0, 1]")
        _check(self.global_budget_j >= 0,
               "energy.global_budget_j must be >= 0")


@dataclasses.dataclass
class ResilienceSpec:
    """Crash safety: engine checkpoint/resume cadence + seeded fault
    injection (repro.checkpoint.engine, repro.fl.faults;
    docs/RESILIENCE.md)."""
    checkpoint_dir: str = ""            # empty = checkpointing off
    checkpoint_every: int = 0           # save every N (virtual) rounds
    checkpoint_keep: int = 3            # manifests kept before rotation
    resume: bool = False                # resume from latest manifest
    fault_crashes: int = 0              # seeded churn counts (async only)
    fault_timeouts: int = 0
    fault_disconnects: int = 0
    fault_corrupts: int = 0
    fault_horizon: float = 0.0          # event window (0 = async horizon)
    fault_seed: int = -1                # -1 = reuse the run seed
    task_deadline_factor: float = 4.0   # lost-task reap at factor * t_cost

    def n_faults(self) -> int:
        return (self.fault_crashes + self.fault_timeouts
                + self.fault_disconnects + self.fault_corrupts)

    def __post_init__(self):
        _check(self.checkpoint_every >= 0,
               "resilience.checkpoint_every must be >= 0")
        _check(self.checkpoint_keep >= 1,
               "resilience.checkpoint_keep must be >= 1")
        for f in ("fault_crashes", "fault_timeouts", "fault_disconnects",
                  "fault_corrupts"):
            _check(getattr(self, f) >= 0, f"resilience.{f} must be >= 0")
        _check(self.fault_horizon >= 0,
               "resilience.fault_horizon must be >= 0")
        _check(self.task_deadline_factor > 1,
               "resilience.task_deadline_factor must be > 1 (a deadline at "
               "or before the task's own completion would reap live work)")
        _check(not self.resume or self.checkpoint_dir,
               "resilience.resume needs checkpoint_dir")


@dataclasses.dataclass
class SimulationSpec:
    """One cell of the paper's experiment grid, fully typed + validated."""
    n_devices: int = 40
    n_rounds: int = 30
    participation: float = 0.10         # paper: 10% per round
    method: str = "drfl"                # drfl | heterofl | scalefl
    seed: int = 0
    server_lr: float = 0.7
    # data (synthetic CIFAR-like shards)
    n_train: int = 4000
    alpha: float = 0.5                  # Dirichlet non-IID
    n_val_fraction: float = 0.04        # paper Table 2 optimum
    noise: float = 1.0
    # nested sub-specs
    model: ModelSpec = dataclasses.field(default_factory=ModelSpec)
    engine: EngineSpec = dataclasses.field(default_factory=EngineSpec)
    marl: MarlSpec = dataclasses.field(default_factory=MarlSpec)
    energy: EnergySpec = dataclasses.field(default_factory=EnergySpec)
    resilience: ResilienceSpec = dataclasses.field(
        default_factory=ResilienceSpec)

    def __post_init__(self):
        _check(self.n_devices >= 1, "n_devices must be >= 1")
        _check(self.n_rounds >= 1, "n_rounds must be >= 1")
        _check(0 < self.participation <= 1,
               "participation must be in (0, 1]")
        _check_choice(self.method, METHODS, "method")
        _check(self.server_lr > 0, "server_lr must be > 0")
        _check(self.n_train >= 1, "n_train must be >= 1")
        _check(self.alpha > 0, "alpha must be > 0")
        _check(0 < self.n_val_fraction < 1,
               "n_val_fraction must be in (0, 1)")
        _check(self.noise >= 0, "noise must be >= 0")
        family = get_family(self.model.family)
        _check(family.supports(self.method),
               f"model family {family.name!r} does not support "
               f"method {self.method!r} (supported: "
               f"{', '.join(family.supported_methods)})")
        if self.resilience.n_faults():
            _check(self.engine.mode == "async",
                   "fault injection rides the async event timeline: "
                   "fault_* counts need engine.mode='async'")
            _check(self.resilience.fault_horizon > 0
                   or self.engine.async_time_horizon > 0,
                   "fault injection needs a time window: set "
                   "resilience.fault_horizon or engine.async_time_horizon")

    # -- bridges ----------------------------------------------------------
    @classmethod
    def from_flat(cls, cfg: FLConfig) -> "SimulationSpec":
        """Lift a flat :class:`FLConfig` into the typed spec (validating
        it); ``to_flat`` inverts this bit-for-bit."""
        return cls(
            n_devices=cfg.n_devices, n_rounds=cfg.n_rounds,
            participation=cfg.participation, method=cfg.method,
            seed=cfg.seed, server_lr=cfg.server_lr, n_train=cfg.n_train,
            alpha=cfg.alpha, n_val_fraction=cfg.n_val_fraction,
            noise=cfg.noise,
            model=ModelSpec(
                family=cfg.model_family, width_mult=cfg.width_mult,
                hw=cfg.hw, num_classes=cfg.num_classes,
                local_epochs=cfg.local_epochs, batch_size=cfg.batch_size,
                lr=cfg.lr),
            engine=EngineSpec(
                mode=cfg.engine_mode, client_executor=cfg.client_executor,
                staleness_decay=cfg.staleness_decay,
                async_eval_every=cfg.async_eval_every,
                async_time_horizon=cfg.async_time_horizon,
                async_task_budget=cfg.async_task_budget,
                fleet_mesh=cfg.fleet_mesh),
            marl=MarlSpec(
                selector=cfg.selector, reward_weights=cfg.reward_weights,
                train_every=cfg.marl_train_every,
                updates_per_round=cfg.marl_updates_per_round,
                episodes=cfg.marl_episodes,
                state_mode=cfg.state_mode,
                mixer_mode=cfg.mixer_mode,
                agent_budget=cfg.marl_agent_budget),
            energy=EnergySpec(
                scale=cfg.energy_scale, hotplug_round=cfg.hotplug_round,
                hotplug_n=cfg.hotplug_n,
                charge_profile=cfg.charge_profile,
                charge_rate=cfg.charge_rate,
                charge_period=cfg.charge_period,
                availability_profile=cfg.availability_profile,
                availability_duty=cfg.availability_duty,
                global_budget_j=cfg.global_budget_j),
            resilience=ResilienceSpec(
                checkpoint_dir=cfg.checkpoint_dir,
                checkpoint_every=cfg.checkpoint_every,
                checkpoint_keep=cfg.checkpoint_keep,
                resume=cfg.resume,
                fault_crashes=cfg.fault_crashes,
                fault_timeouts=cfg.fault_timeouts,
                fault_disconnects=cfg.fault_disconnects,
                fault_corrupts=cfg.fault_corrupts,
                fault_horizon=cfg.fault_horizon,
                fault_seed=cfg.fault_seed,
                task_deadline_factor=cfg.task_deadline_factor))

    def to_flat(self) -> FLConfig:
        """Lower to the flat compatibility surface consumed by the engine."""
        return FLConfig(
            n_devices=self.n_devices, n_rounds=self.n_rounds,
            participation=self.participation,
            local_epochs=self.model.local_epochs,
            batch_size=self.model.batch_size, lr=self.model.lr,
            alpha=self.alpha, num_classes=self.model.num_classes,
            n_train=self.n_train, n_val_fraction=self.n_val_fraction,
            noise=self.noise, hw=self.model.hw,
            width_mult=self.model.width_mult, seed=self.seed,
            model_family=self.model.family, method=self.method,
            selector=self.marl.selector,
            reward_weights=self.marl.reward_weights,
            marl_train_every=self.marl.train_every,
            marl_updates_per_round=self.marl.updates_per_round,
            marl_episodes=self.marl.episodes,
            hotplug_round=self.energy.hotplug_round,
            hotplug_n=self.energy.hotplug_n,
            energy_scale=self.energy.scale,
            charge_profile=self.energy.charge_profile,
            charge_rate=self.energy.charge_rate,
            charge_period=self.energy.charge_period,
            availability_profile=self.energy.availability_profile,
            availability_duty=self.energy.availability_duty,
            global_budget_j=self.energy.global_budget_j,
            server_lr=self.server_lr,
            engine_mode=self.engine.mode,
            staleness_decay=self.engine.staleness_decay,
            async_eval_every=self.engine.async_eval_every,
            async_time_horizon=self.engine.async_time_horizon,
            async_task_budget=self.engine.async_task_budget,
            client_executor=self.engine.client_executor,
            state_mode=self.marl.state_mode,
            mixer_mode=self.marl.mixer_mode,
            marl_agent_budget=self.marl.agent_budget,
            fleet_mesh=self.engine.fleet_mesh,
            checkpoint_dir=self.resilience.checkpoint_dir,
            checkpoint_every=self.resilience.checkpoint_every,
            checkpoint_keep=self.resilience.checkpoint_keep,
            resume=self.resilience.resume,
            fault_crashes=self.resilience.fault_crashes,
            fault_timeouts=self.resilience.fault_timeouts,
            fault_disconnects=self.resilience.fault_disconnects,
            fault_corrupts=self.resilience.fault_corrupts,
            fault_horizon=self.resilience.fault_horizon,
            fault_seed=self.resilience.fault_seed,
            task_deadline_factor=self.resilience.task_deadline_factor)


def ensure_flat_config(cfg) -> FLConfig:
    """Accept a :class:`SimulationSpec` or :class:`FLConfig`, validate,
    and return the flat config the engine runs on.

    Flat configs round-trip through :meth:`SimulationSpec.from_flat` purely
    for validation — the ORIGINAL object is returned, so the flat path
    stays bit-for-bit (`==` and identity) what the caller built."""
    if isinstance(cfg, SimulationSpec):
        return cfg.to_flat()
    if isinstance(cfg, FLConfig):
        SimulationSpec.from_flat(cfg)      # validation only
        return cfg
    raise TypeError(f"expected SimulationSpec or FLConfig, got "
                    f"{type(cfg).__name__}")
