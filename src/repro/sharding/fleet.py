"""Data-parallel FleetState sharding — the million-device fleet mesh.

:class:`repro.core.fleet.FleetState` is a registered pytree of ``[n]``
arrays, and every Eq. 3–7 kernel (cost matrices, affordability masks,
charge, Top-K cut, factored summary) is elementwise or a small reduction
over that axis.  This module places those arrays across devices/hosts with
``jax.sharding`` — a 1-D :class:`Mesh` over a ``"fleet"`` axis and
:class:`NamedSharding` per field — so the jitted kernels run SPMD
data-parallel: each device owns ``n / mesh_size`` fleet rows, per-device
work never materialises the whole fleet, and the only cross-device traffic
per selection+energy step is the ``summary_width``-sized all-reduce inside
:func:`repro.core.fleet.fleet_summary` plus the tiny Top-K merge.

The rule machinery mirrors :mod:`repro.sharding.rules` (name-based logical
axes + divisibility fallback to replication): FleetState fields map to the
``("fleet",)`` logical axis through :data:`FLEET_RULES`, and any field
whose leading dim does not divide the mesh falls back to ``P()``
(replicated) instead of erroring — the same policy that lets one rule
table cover every model in ``rules.py``.

Public surface (one-line contracts):

* :data:`FLEET_AXIS` — the mesh-axis name (``"fleet"``).
* :func:`fleet_mesh` — 1-D Mesh over the local devices (or a prefix).
* :func:`fleet_spec_for` — PartitionSpec for one field (rule lookup +
  divisibility fallback).
* :func:`fleet_shardings` — FleetState-shaped pytree of NamedShardings.
* :func:`shard_fleet` — device_put the fleet onto the mesh.
* :func:`shard_agent_array` — row-shard one companion ``[n, ...]`` array
  (GRU hidden states, obs matrices) with the same fallback policy.
* :func:`unshard_fleet` — gather back to single-device host arrays.
* :func:`maybe_shard_fleet` — config-level entry: no-op below 2 shards.
* :func:`is_sharded` — True when a fleet's arrays live on a >1 mesh.

CPU note: a multi-device mesh on one host needs
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set BEFORE jax
initialises (the shard-smoke CI job and ``benchmarks/fleet_shard_bench.py``
do this); under the default single-device CPU runtime everything here
degrades to a no-op placement.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.fleet import FleetState
from repro.sharding.rules import _mesh_size

#: mesh axis carrying the fleet's device axis
FLEET_AXIS = "fleet"

# field-name regex -> logical axes of the [n] array (rules.py-style table;
# every FleetState array field is 1-D over the fleet axis today, but the
# table keeps the mapping declarative and extensible, e.g. per-device
# feature matrices would add (r"features$", ("fleet", None))).
FLEET_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    (r".*", (FLEET_AXIS,)),
)

LOGICAL_TO_MESH = {FLEET_AXIS: (FLEET_AXIS,)}


def fleet_mesh(n_shards: Optional[int] = None, devices=None) -> Mesh:
    """1-D ``("fleet",)`` mesh over ``devices`` (default: all local jax
    devices), truncated to ``n_shards`` when given.  ``None``, ``0`` and
    ``-1`` all mean "all local devices" (matching the config convention
    ``fleet_mesh=-1``)."""
    devs = list(devices if devices is not None else jax.devices())
    if n_shards is not None and int(n_shards) >= 1:
        devs = devs[:int(n_shards)]
    return Mesh(np.array(devs), (FLEET_AXIS,))


def fleet_spec_for(name: str, shape, mesh: Mesh) -> P:
    """PartitionSpec for one FleetState field: first matching rule in
    :data:`FLEET_RULES`, with silent fallback to replication when the
    fleet dim does not divide the mesh (same policy as
    :func:`repro.sharding.rules.spec_for`)."""
    if len(shape) == 0:
        return P()
    for pat, logical in FLEET_RULES:
        if re.search(pat, name):
            out = []
            for dim, ax in zip(shape, logical):
                mesh_axes = LOGICAL_TO_MESH.get(ax, ())
                if (mesh_axes and dim % _mesh_size(mesh, mesh_axes) == 0
                        and dim >= _mesh_size(mesh, mesh_axes)):
                    out.append(mesh_axes[0] if len(mesh_axes) == 1
                               else tuple(mesh_axes))
                else:
                    out.append(None)
            return P(*out)
    return P()


def shard_agent_array(x, mesh: Mesh, axis: int = 0):
    """Place one per-agent array (``[n, ...]``) on the mesh, row-sharded
    over :data:`FLEET_AXIS` along ``axis`` — the companion to
    :func:`shard_fleet` for arrays that ride WITH the fleet but live
    outside :class:`FleetState` (QMIX GRU hidden states ``[n, hidden]``,
    observation matrices ``[n, OBS_DIM]``).  Same divisibility policy as
    :func:`fleet_spec_for`: an agent dim that does not divide the mesh
    falls back to replication instead of erroring."""
    shape = np.shape(x)
    size = _mesh_size(mesh, (FLEET_AXIS,))
    if (len(shape) > axis and shape[axis] % size == 0
            and shape[axis] >= size):
        spec = [None] * len(shape)
        spec[axis] = FLEET_AXIS
        return jax.device_put(x, NamedSharding(mesh, P(*spec)))
    return jax.device_put(x, NamedSharding(mesh, P()))


def fleet_shardings(fleet: FleetState, mesh: Mesh) -> dict:
    """``{field: NamedSharding}`` placements for every FleetState array
    field (rule lookup + divisibility fallback per field)."""
    from repro.core.fleet import _ARRAY_FIELDS
    return {f: NamedSharding(
                mesh, fleet_spec_for(f, np.shape(getattr(fleet, f)), mesh))
            for f in _ARRAY_FIELDS}


def shard_fleet(fleet: FleetState, mesh: Mesh) -> FleetState:
    """Place every fleet array on the mesh (row-sharded over
    :data:`FLEET_AXIS`, replicated where indivisible).  numpy-backend
    fleets are promoted to jax arrays by the placement."""
    placements = fleet_shardings(fleet, mesh)
    return fleet.replace(**{f: jax.device_put(getattr(fleet, f), s)
                            for f, s in placements.items()})


def unshard_fleet(fleet: FleetState) -> FleetState:
    """Gather a (possibly sharded) fleet back to host numpy arrays — the
    DeviceState-compatibility / debugging path, NOT the hot loop."""
    from repro.core.fleet import _ARRAY_FIELDS
    return FleetState(
        **{f: np.asarray(getattr(fleet, f)) for f in _ARRAY_FIELDS},
        tiers=fleet.tiers, modes=fleet.modes)


def is_sharded(fleet: FleetState) -> bool:
    """True when the fleet's arrays are placed on a multi-device mesh."""
    r = fleet.remaining
    return (isinstance(r, jax.Array)
            and len(getattr(r.sharding, "device_set", ())) > 1)


def maybe_shard_fleet(fleet: FleetState, n_shards: int = 0) -> FleetState:
    """Config-level entry point (``FLConfig.fleet_mesh``): shard over
    ``min(n_shards, local devices)`` when that is >= 2, otherwise return
    the fleet unchanged.  ``n_shards <= 1`` (the config default 0) keeps
    the legacy single-placement fleet — sharding is always opt-in; ``-1``
    means "all local devices"."""
    avail = len(jax.devices())
    want = avail if n_shards == -1 else min(int(n_shards), avail)
    if want < 2:
        return fleet
    return shard_fleet(fleet, fleet_mesh(want))
