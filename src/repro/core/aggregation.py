"""Aggregation operators: FedAvg (Eq. 2) and DR-FL layer-aligned averaging.

Paper Step 2: "layer-align averaging — the same parts of the network will be
aggregated".  A layer of the global model is updated with the data-size-
weighted mean of exactly those client gradients whose submodel contains the
layer; layers no client trained keep the previous global value.

Two deployment forms:
* :func:`layerwise_aggregate` — host/driver-side over a list of client
  updates (the FL simulation and the paper repro use this).
* :func:`fl_allreduce` — the same op expressed as a masked ``psum`` over the
  ``pod`` mesh axis (multi-pod production mapping; each pod is a client).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp


def fedavg(updates: Sequence, weights: Optional[Sequence[float]] = None):
    """Plain FedAvg over pytrees (Eq. 2). ``weights`` ~ client data sizes."""
    n = len(updates)
    if weights is None:
        w = [1.0 / n] * n
    else:
        tot = float(sum(weights))
        w = [float(x) / tot for x in weights]
    return jax.tree.map(
        lambda *xs: sum(wi * x.astype(jnp.float32) for wi, x in zip(w, xs)
                        ).astype(xs[0].dtype),
        *updates)


def layerwise_aggregate(global_params, client_updates: List, client_masks: List,
                        weights: Optional[Sequence[float]] = None,
                        server_lr: float = 1.0):
    """DR-FL layer-aligned aggregation.

    global_params : pytree W_t
    client_updates: list of pytrees (client gradient/delta, SAME structure —
                    clients zero-fill layers they did not train)
    client_masks  : list of pytrees of 0/1 masks (from
                    :func:`repro.core.layerwise.stacked_update_mask`),
                    broadcastable leaf-wise against the updates
    weights       : client data sizes L_n (paper Eq. 2)

    Returns W_{t+1} = W_t + server_lr * masked weighted mean of updates.
    """
    n = len(client_updates)
    if weights is None:
        weights = [1.0] * n
    w = [float(x) for x in weights]

    def agg(gp, *leaves):
        ups = leaves[:n]
        msks = leaves[n:]
        num = sum(wi * m.astype(jnp.float32) * u.astype(jnp.float32)
                  for wi, u, m in zip(w, ups, msks))
        den = sum(wi * m.astype(jnp.float32) for wi, m in zip(w, msks))
        den = jnp.broadcast_to(den, num.shape) if hasattr(den, "shape") else den
        avg = jnp.where(den > 0, num / jnp.maximum(den, 1e-12), 0.0)
        return (gp.astype(jnp.float32) + server_lr * avg).astype(gp.dtype)

    return jax.tree.map(agg, global_params, *client_updates, *client_masks)


def fl_allreduce(update, mask, weight, axis_name: str = "pod"):
    """Masked layer-aligned aggregation as a collective (inside shard_map).

    Each pod contributes ``update`` (zero outside its submodel), ``mask``
    (its update mask) and scalar ``weight`` (data size).  Returns the
    aggregated delta every pod applies to its replica of the global model —
    DR-FL Step 2 as a single psum pair over the pod axis.
    """
    def one(u, m):
        num = jax.lax.psum(weight * m * u.astype(jnp.float32), axis_name)
        den = jax.lax.psum(weight * m, axis_name)
        return jnp.where(den > 0, num / jnp.maximum(den, 1e-12), 0.0).astype(u.dtype)

    return jax.tree.map(one, update, mask)
