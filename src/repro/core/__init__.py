"""DR-FL core: the paper's contribution.

* layerwise    — depth-prefix submodels + masks (§4.2)
* aggregation  — FedAvg + layer-aligned masked aggregation (Step 2)
* energy       — Eq. 3–7 time/energy system model + device fleet
* selection    — dual-selection strategies (MARL / greedy / random / static)
* marl         — QMIX learner (agents, mixer, replay, TD updates)
* baselines    — HeteroFL / ScaleFL comparison arms
"""
from repro.core.aggregation import fedavg, fl_allreduce, layerwise_aggregate  # noqa: F401
from repro.core.energy import (BATTERY_JOULES, DeviceProfile, DeviceState,  # noqa: F401
                               make_fleet, round_cost, charge, total_remaining)
from repro.core.layerwise import (exit_points, layer_mask, num_submodels,  # noqa: F401
                                  stacked_update_mask, submodel_fraction)
from repro.core.selection import (GreedySelector, MarlSelector,  # noqa: F401
                                  RandomSelector, Selection, StaticTierSelector)
