"""Yi-34B — llama-arch GQA dense decoder [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    head_dim=128, rope_theta=5_000_000.0,
    exit_points=(15, 30, 45, 60),
    source="arXiv:2403.04652",
)
