#!/usr/bin/env python
"""Docs CI check: internal links resolve, code snippets parse and import.

Walks README.md plus everything under docs/, and for each markdown file:

* every relative markdown link ``[text](path)`` (and ``path#anchor``) must
  point at an existing file or directory in the repo — external
  (``http(s)://``) and in-page (``#...``) links are skipped;
* every fenced ```` ```python ```` / ```` ```bash ```` snippet must at
  least be syntactically valid (``compile()`` for python; bash blocks are
  only checked for balanced fences);
* every ``import repro...`` / ``from repro... import`` statement appearing
  in python snippets must actually import (catches docs drifting from the
  public API).

Exit code 0 = clean; nonzero prints every failure.  Run from anywhere:

    PYTHONPATH=src python scripts/check_docs.py
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```(\w*)\n(.*?)```", re.S)
IMPORT_RE = re.compile(r"^\s*(?:from\s+(repro[\w.]*)\s+import|"
                       r"import\s+(repro[\w.]*))", re.M)


def doc_files():
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        for name in sorted(os.listdir(docs)):
            if name.endswith(".md"):
                files.append(os.path.join(docs, name))
    return [f for f in files if os.path.exists(f)]


def check_links(path: str, text: str, errors: list):
    base = os.path.dirname(path)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(base, rel))
        if not os.path.exists(resolved):
            errors.append(f"{os.path.relpath(path, REPO)}: broken link "
                          f"-> {target}")


def check_snippets(path: str, text: str, errors: list):
    rel = os.path.relpath(path, REPO)
    for m in FENCE_RE.finditer(text):
        lang, body = m.group(1), m.group(2)
        if lang != "python":
            continue
        try:
            compile(body, f"<{rel} snippet>", "exec")
        except SyntaxError as e:
            errors.append(f"{rel}: python snippet does not parse: {e}")
            continue
        for im in IMPORT_RE.finditer(body):
            module = im.group(1) or im.group(2)
            try:
                __import__(module)
            except Exception as e:
                errors.append(f"{rel}: snippet import {module!r} fails: "
                              f"{type(e).__name__}: {e}")


def main() -> int:
    sys.path.insert(0, os.path.join(REPO, "src"))
    errors: list = []
    files = doc_files()
    for path in files:
        with open(path) as fh:
            text = fh.read()
        check_links(path, text, errors)
        check_snippets(path, text, errors)
    for e in errors:
        print(f"FAIL {e}")
    print(f"checked {len(files)} docs: "
          f"{'OK' if not errors else f'{len(errors)} problem(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
