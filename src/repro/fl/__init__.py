from repro.fl.simulation import FLConfig, run_simulation  # noqa: F401
from repro.fl.environment import FLEnv, FLEnvConfig  # noqa: F401
