"""Paper Table 2 (RQ4): DR-FL accuracy vs server validation-set ratio
(1%%-10%%; paper finds ~4%% optimal — more validation data steals training
data from clients)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FAST, bench_params, emit
from repro.fl import FLConfig, run_simulation

RATIOS = (0.02, 0.04, 0.08) if FAST else (0.01, 0.02, 0.04, 0.06, 0.08, 0.10)


def main(seed=0, verbose=False):
    p = bench_params()
    accs = {}
    for r in RATIOS:
        t0 = time.time()
        cfg = FLConfig(method="drfl", selector="marl", seed=seed,
                       n_val_fraction=r, alpha=0.1, marl_episodes=2, **p)
        h = run_simulation(cfg, verbose=verbose)
        accs[r] = float(np.mean(h["best_acc"]))
        emit(f"table2/ratio{int(r * 100)}pct", (time.time() - t0) * 1e6,
             f"best_acc_mean={accs[r]:.3f}")
    best = max(accs, key=accs.get)
    emit("table2/optimum", 0.0, f"best_ratio={best};acc={accs[best]:.3f}")
    return accs


if __name__ == "__main__":
    main(verbose=True)
