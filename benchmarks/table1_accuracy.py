"""Paper Table 1: test accuracy of DR-FL vs HeteroFL vs ScaleFL across
Dirichlet alpha, per layer-wise model (4 exits), under energy constraints.

Directional claim checked: DR-FL's mean/best accuracy >= the baselines under
the same battery budget (the paper reports DR-FL winning 29/36 cells)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_params, emit, family_supports
from repro.fl import FLConfig, run_simulation

ALPHAS = (0.1, 0.5, 1.0)
# drfl/marl = the paper's full method (QMIX dual-selection; undertrained at
# CPU-scale round counts — see EXPERIMENTS.md §Paper for the caveat);
# drfl/greedy = the DR-FL framework with a greedy policy (selector ablation).
ARMS = (("drfl", "marl"), ("drfl+greedy", None), ("heterofl", "greedy"),
        ("scalefl", "greedy"))


def main(alphas=ALPHAS, seed=0, verbose=False):
    p = bench_params()
    rows = []
    for alpha in alphas:
        for method, sel in ARMS:
            t0 = time.time()
            if method == "drfl+greedy":
                method_, sel_ = "drfl", "greedy"
            else:
                method_, sel_ = method, sel or "greedy"
            if not family_supports(p, method_):
                emit(f"table1/{method}/alpha{alpha}", 0.0,
                     f"skipped=unsupported_by_{p['model_family']}")
                continue
            cfg = FLConfig(alpha=alpha, method=method_, selector=sel_,
                           seed=seed, marl_episodes=4, **p)
            h = run_simulation(cfg, verbose=verbose)
            best = np.asarray(h["best_acc"])
            rows.append((alpha, method, best, time.time() - t0))
            emit(f"table1/{method}/alpha{alpha}", (time.time() - t0) * 1e6,
                 "best_acc_per_exit=" + "|".join(f"{a:.3f}" for a in best))
    # directional summary: DR-FL mean(best exits) vs baselines per alpha
    for alpha in alphas:
        cells = {m: float(np.mean(r)) for a, m, r, _ in rows if a == alpha}
        winner = max(cells, key=cells.get)
        emit(f"table1/winner/alpha{alpha}", 0.0,
             f"winner={winner};" + ";".join(f"{m}={v:.3f}" for m, v in cells.items()))
    return rows


if __name__ == "__main__":
    main(verbose=True)
