"""Synthetic datasets (offline container — no CIFAR/SVHN/FMNIST downloads).

``synthetic_image_dataset`` builds a *learnable* class-conditional Gaussian
mixture with CIFAR-like shapes: class prototypes are smooth random fields,
samples are prototype + noise.  Difficulty is controlled by ``noise`` —
at the default a small CNN separates classes well above chance but far from
perfectly, which is what the FL accuracy dynamics need (DESIGN.md §1:
directional validation of the paper's claims).

``synthetic_lm_dataset`` emits an order-2 Markov token stream so an LM has
actual structure to learn (loss decreases measurably within hundreds of
steps).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _smooth_field(rng, hw: int, ch: int, octaves: int = 3) -> np.ndarray:
    """Low-frequency random image so prototypes have spatial structure."""
    img = np.zeros((hw, hw, ch), np.float32)
    for o in range(octaves):
        k = 2 ** (o + 2)
        coarse = rng.normal(size=(k, k, ch)).astype(np.float32)
        reps = int(np.ceil(hw / k))
        up = np.kron(coarse, np.ones((reps, reps, 1), np.float32))[:hw, :hw]
        img += up / (o + 1)
    return img / octaves


def synthetic_image_dataset(n: int, num_classes: int = 10, hw: int = 32,
                            ch: int = 3, noise: float = 1.0, seed: int = 0
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x [n,hw,hw,ch] float32, y [n] int32), balanced classes."""
    rng = np.random.default_rng(seed)
    protos = np.stack([_smooth_field(rng, hw, ch) for _ in range(num_classes)])
    protos *= 2.0 / max(np.abs(protos).max(), 1e-6)
    y = rng.integers(0, num_classes, size=n).astype(np.int32)
    x = protos[y] + noise * rng.normal(size=(n, hw, hw, ch)).astype(np.float32)
    return x.astype(np.float32), y


def synthetic_lm_dataset(n_tokens: int, vocab: int, seed: int = 0,
                         branching: int = 4) -> np.ndarray:
    """Order-2 Markov chain over ``vocab`` tokens; each (a,b) context has
    ``branching`` likely successors.  Returns [n_tokens] int32."""
    rng = np.random.default_rng(seed)
    # hash-based sparse transition: successors of (a,b) are derived
    # deterministically; probabilities are a fixed random simplex.
    probs = rng.dirichlet(np.ones(branching) * 0.5)
    out = np.empty(n_tokens, np.uint64)
    out[0], out[1] = rng.integers(0, vocab, 2)
    mult1 = np.uint64(6364136223846793005)
    mult2 = np.uint64(1442695040888963407)
    inc = np.uint64(1013904223)
    ctx_choice = rng.choice(branching, size=n_tokens, p=probs).astype(np.uint64)
    with np.errstate(over="ignore"):
        for t in range(2, n_tokens):
            h = (out[t - 2] * mult1 + out[t - 1] * mult2
                 + inc * ctx_choice[t]) >> np.uint64(33)
            out[t] = h % np.uint64(vocab)
    return out.astype(np.int32)


def synthetic_token_dataset(n: int, vocab: int = 10, seq_len: int = 16,
                            noise: float = 1.0, seed: int = 0
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """Next-token prediction framed as classification over ``vocab``:
    returns (x [n, seq_len] int32 context windows, y [n] int32 next-token
    labels), windowed lm1b-style (stride 1) from the order-2 Markov stream.

    The label IS the class, so the FL stack's CE loss, per-exit accuracy
    evaluation and label-based Dirichlet sharding all apply unchanged —
    this is the corpus :meth:`repro.models.transformer_family
    .TransformerFamily.make_dataset` serves ``run_simulation`` offline.
    ``noise`` resamples a fraction (``0.05 * noise``, capped at 0.5) of
    context tokens uniformly, the difficulty knob mirroring the image
    set's additive noise."""
    toks = synthetic_lm_dataset(n + seq_len + 1, vocab, seed=seed)
    idx = np.arange(n)[:, None] + np.arange(seq_len)[None, :]
    x = toks[idx].astype(np.int32)
    y = toks[np.arange(n) + seq_len].astype(np.int32)
    if noise > 0:
        rng = np.random.default_rng(seed + 1)
        flip = rng.random(x.shape) < min(0.5, 0.05 * float(noise))
        x = np.where(flip, rng.integers(0, vocab, x.shape), x)
    return x.astype(np.int32), y


def lm_batches(tokens: np.ndarray, batch: int, seq_len: int, seed: int = 0):
    """Infinite iterator of {'tokens','labels'} windows."""
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq_len - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        tok = np.stack([tokens[i:i + seq_len] for i in idx])
        lab = np.stack([tokens[i + 1:i + seq_len + 1] for i in idx])
        yield {"tokens": tok, "labels": lab}
