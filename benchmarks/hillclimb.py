"""§Perf hillclimb driver: run one (arch × shape) under a named config
variant, print the three roofline terms + memory, and append to
perf_iterations.json for the EXPERIMENTS.md §Perf log.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch yi-34b \
        --shape train_4k --variant repeat_kv --set repeat_kv=1
"""
import argparse
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
LOG = os.path.join(os.path.dirname(__file__), "..", "perf_iterations.json")


def run_variant(arch, shape, variant, flags, mesh="single", step="default"):
    out = f"/tmp/hc_{arch}_{shape}_{variant}.json".replace("/", "_")
    out = "/tmp/" + out.lstrip("_tmp_")
    args = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
            "--shape", shape, "--mesh", mesh, "--step", step,
            "--json", out] + flags
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(args, capture_output=True, text=True, env=env,
                       timeout=3600)
    if r.returncode != 0:
        print(r.stdout[-3000:], r.stderr[-3000:])
        raise SystemExit(1)
    res = json.load(open(out))[0]
    rf = res["roofline"]
    rec = {
        "arch": arch, "shape": shape, "variant": variant, "mesh": mesh,
        "step": step, "flags": flags,
        "t_compute_s": rf["t_compute_s"], "t_memory_s": rf["t_memory_s"],
        "t_collective_s": rf["t_collective_s"], "dominant": rf["dominant"],
        "t_bound_s": rf["t_bound_s"],
        "hbm_gib": res["memory"]["total_hbm_bytes"] / 2**30,
        "useful": rf.get("useful_flops_ratio", 0.0),
        "collectives": rf["collectives"],
    }
    hist = json.load(open(LOG)) if os.path.exists(LOG) else []
    hist.append(rec)
    json.dump(hist, open(LOG, "w"), indent=2)
    print(f"[{variant}] dom={rec['dominant']} bound={rec['t_bound_s']:.3g}s "
          f"comp={rec['t_compute_s']:.3g} mem={rec['t_memory_s']:.3g} "
          f"coll={rec['t_collective_s']:.3g} hbm={rec['hbm_gib']:.1f}GiB "
          f"useful={rec['useful']:.2f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--step", default="default")
    ap.add_argument("flags", nargs="*", default=[])
    a = ap.parse_args()
    run_variant(a.arch, a.shape, a.variant, a.flags, a.mesh, a.step)


if __name__ == "__main__":
    main()
