"""Per-arch smoke tests (deliverable f): a REDUCED same-family variant runs
one forward and one train step on CPU; output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (INPUT_SHAPES, TrainConfig, get_config,
                           get_smoke_config, list_archs)
from repro.launch.steps import build_train_step
from repro.models import build, extra_inputs

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec
    assert cfg.source


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    extras = {k: jnp.zeros(shp, dt)
              for k, (shp, dt) in extra_inputs(cfg, B, S).items()}
    hidden, aux = m.apply(params, tokens, extras, remat="none")
    assert hidden.shape == (B, S, cfg.d_model)
    logits = m.logits(params, hidden)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    tcfg = TrainConfig(loss_chunk=8, warmup_steps=1, total_steps=10)
    model, step = build_train_step(cfg, tcfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    from repro.optim import adamw_init
    state = {"params": params, "opt": adamw_init(params)}
    B, S = 2, 16
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(key, 7), (B, S), 0,
                                     cfg.vocab_size),
    }
    for k, (shp, dt) in extra_inputs(cfg, B, S).items():
        batch[k] = jnp.zeros(shp, dt)
    new_state, metrics = jax.jit(step)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed (somewhere in the tree)
    changed = any(
        not np.allclose(np.asarray(b, np.float32), np.asarray(a, np.float32))
        for b, a in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])))
    assert changed
