"""Early-exit residual MLP — the second registered :class:`ModelFamily`.

Proof that the FL stack (round engine, bucketed-vmap executor, stacked
Pallas aggregation, energy accounting) is family-generic: a layer-wise
model with the canonical ``{"stem", "stages", "exits"}`` layout whose
blocks are built from :mod:`repro.models.layers` primitives (LayerNorm +
GELU MLP residual blocks, dense exit heads) instead of convolutions.

Submodel m = stem + stages[:m+1] + exit heads <= m, exactly the DR-FL
depth-prefix contract; images are flattened at the stem, so the model is
a per-sample GEMM stack — the bucketed executor vmaps it with no special
trace context (unlike the CNN's patches-conv CPU workaround).

Paper-scale calibration (``cost_model``): width 1.0 on 32x32x3 inputs.
The default width is deliberately small — this family exists to exercise
heterogeneity scenarios (Arouj et al.; Banerjee et al. run energy-aware FL
over widely different client architectures), not to chase CNN accuracy.
"""
from __future__ import annotations

import math
from typing import List

import jax
import jax.numpy as jnp

from repro.models.family import LayerwiseFamily, register_family
from repro.models.layers import (dense_apply, dense_bias_init, gelu_mlp_apply,
                                 gelu_mlp_init, layernorm_apply,
                                 layernorm_init)

N_STAGES = 4
BLOCKS_PER_STAGE = 2
BASE_WIDTH = 256          # d_model at width_mult=1.0
MLP_RATIO = 2             # hidden = MLP_RATIO * d


def _width(width_mult: float) -> int:
    return max(16, int(BASE_WIDTH * width_mult))


def init(key, num_classes: int = 10, width_mult: float = 1.0, hw: int = 32,
         in_channels: int = 3):
    """Canonical layer-wise tree: stem (flatten + project + LN), N_STAGES
    stages of residual GELU-MLP blocks, one LN + linear exit per stage."""
    d = _width(width_mult)
    f = MLP_RATIO * d
    in_dim = hw * hw * in_channels
    ks = jax.random.split(key, 1 + N_STAGES * (BLOCKS_PER_STAGE + 1))
    it = iter(ks)
    params = {
        "stem": {"proj": dense_bias_init(next(it), in_dim, d, jnp.float32),
                 "ln": layernorm_init(d, jnp.float32)},
        "stages": [],
        "exits": [],
    }
    for _ in range(N_STAGES):
        blocks = []
        for _ in range(BLOCKS_PER_STAGE):
            bk = next(it)
            blocks.append({"ln": layernorm_init(d, jnp.float32),
                           "mlp": gelu_mlp_init(bk, d, f, jnp.float32)})
        params["stages"].append(blocks)
        ek = next(it)
        params["exits"].append({
            "ln": layernorm_init(d, jnp.float32),
            "head": dense_bias_init(ek, d, num_classes, jnp.float32,
                                    scale=1.0 / math.sqrt(d)),
        })
    return params


def num_submodels() -> int:
    return N_STAGES


def _stem(params, x):
    h = x.reshape(x.shape[0], -1)
    h = dense_apply(params["stem"]["proj"], h)
    return layernorm_apply(params["stem"]["ln"], h)


def _block(bp, h):
    return h + gelu_mlp_apply(bp["mlp"], layernorm_apply(bp["ln"], h))


def _exit_head(ep, h):
    return dense_apply(ep["head"], layernorm_apply(ep["ln"], h))


def apply(params, x, model_idx: int):
    """x: [B, H, W, C] -> logits at exit ``model_idx``."""
    h = _stem(params, x)
    for si in range(model_idx + 1):
        for bp in params["stages"][si]:
            h = _block(bp, h)
    return _exit_head(params["exits"][model_idx], h)


def apply_all_exits(params, x) -> List[jnp.ndarray]:
    """Logits from every exit held by ``params`` (truncated trees ok)."""
    h = _stem(params, x)
    outs = []
    for si in range(len(params["stages"])):
        for bp in params["stages"][si]:
            h = _block(bp, h)
        outs.append(_exit_head(params["exits"][si], h))
    return outs


def flops_per_sample(model_idx: int, image_hw: int = 32,
                     width_mult: float = 1.0,
                     in_channels: int = 3, num_classes: int = 10) -> float:
    """Analytic forward FLOPs for Model_{idx+1} (energy-model input)."""
    d = _width(width_mult)
    f = MLP_RATIO * d
    total = 2.0 * image_hw * image_hw * in_channels * d          # stem proj
    per_block = 2.0 * (d * f + f * d)                            # in + out
    total += (model_idx + 1) * BLOCKS_PER_STAGE * per_block
    total += 2.0 * d * num_classes                               # exit head
    return total


class MlpFamily(LayerwiseFamily):
    """Early-exit MLP as a pluggable family (``model_family="mlp"``).

    DR-FL (depth-prefix) only: width slicing dense residual blocks is a
    different baseline design, so HeteroFL/ScaleFL stay CNN-territory and
    :class:`repro.fl.spec.SimulationSpec` rejects the combination up
    front."""

    name = "mlp"
    supported_methods = ("drfl",)

    def init(self, key, num_classes: int = 10, width_mult: float = 1.0,
             hw: int = 32):
        return init(key, num_classes, width_mult=width_mult, hw=hw)

    def num_submodels(self) -> int:
        return num_submodels()

    def apply_all_exits(self, params, x):
        return apply_all_exits(params, x)

    def flops_per_sample(self, model_idx: int, image_hw: int = 32,
                         width_mult: float = 1.0) -> float:
        return flops_per_sample(model_idx, image_hw, width_mult)


register_family(MlpFamily())
