"""Batched serving demo: prefill (scoring) + greedy decode with a KV cache
(ring buffer under sliding-window configs).

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x22b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.steps import build_serve_step
from repro.models import build, extra_inputs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model, serve_step = build_serve_step(cfg)
    serve_step = jax.jit(serve_step, donate_argnums=(1,))
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    B = args.batch
    total = args.prompt_len + args.gen
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab_size)
    extras = {k: jax.random.normal(key, shp).astype(dt) for k, (shp, dt)
              in extra_inputs(cfg, B, total).items()}
    cache = model.decode_init(params, B, total, extras=extras)

    # prefill by teacher-forcing the prompt through decode steps (exercises
    # the cache path end to end; batch-scoring prefill uses launch.steps.
    # build_prefill_step).
    t0 = time.time()
    tok = prompts[:, :1]
    for t in range(args.prompt_len):
        tok = prompts[:, t:t + 1]
        next_tok, cache = serve_step(params, cache, tok, jnp.int32(t))
    t_prefill = time.time() - t0

    outs = []
    t0 = time.time()
    tok = next_tok
    for t in range(args.prompt_len, total):
        tok, cache = serve_step(params, cache, tok, jnp.int32(t))
        outs.append(np.asarray(tok[:, 0]))
    t_decode = time.time() - t0

    gen = np.stack(outs, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill:.2f}s  decode: {t_decode:.2f}s "
          f"({t_decode / max(args.gen, 1) * 1000:.0f} ms/token/batch)")
    print("generated token ids (first 2 rows):")
    print(gen[:2])


if __name__ == "__main__":
    main()
