from repro.checkpoint.io import load_pytree, save_pytree, latest_step  # noqa: F401
