"""Event-driven FL round engine: sync (barrier) and async (timeline) modes.

The paper's §4.2 workflow assumes devices come and go on their own clocks,
but a synchronous round loop is a barrier: every round waits ``max(t_cost)``
over its participants, so one slow straggler sets the fleet's wall-clock —
the "wooden barrel effect" DR-FL is supposed to beat.  This module replaces
the monolithic loop with a scheduler over *events* on a simulated timeline:

* ``mode="sync"``  — one DISPATCH + one barrier COMPLETION per round; a
  verbatim port of the legacy loop, bit-for-bit identical to the frozen
  reference (:func:`repro.fl.simulation._run_once_reference`, enforced by
  ``tests/test_engine.py``).
* ``mode="async"`` — dispatch (selection + energy charge at send time) and
  completion (delta arrival + staleness-aware aggregation at finish time)
  are separate events on a heap keyed by per-device virtual clocks
  (``FleetState.busy_until``).  The server keeps ~k tasks in flight: each
  completion aggregates immediately (FedAsync-style, down-weighted by
  :func:`repro.fl.server.staleness_scale`) and back-fills the freed slot,
  so no device ever waits at a barrier.  Hot-plug joins, dropouts, and
  battery depletion are timeline events, not round-boundary hacks.

Async bookkeeping groups completions into *virtual rounds* of k tasks so
histories stay row-comparable with sync runs; rewards are credited at
EVENT time (energy at dispatch, duration and accuracy-delta at arrival)
and committed to the selector in dispatch order, which keeps the MARL
episode trace (obs/action/reward) aligned.

Fairness accounting reported in the history (``benchmarks/async_bench.py``):

* ``idle_time`` — straggler wait: how long each finished client update sat
  before entering the global model.  Sync pays ``t_round - t_cost_i`` per
  surviving participant (the barrier); async aggregates at the completion
  event, so the wait is zero by construction (computed, not assumed, so
  the metric stays honest if scheduling ever batches arrivals).
* ``wait_for_work`` (async only) — time between a device completing a task
  and its NEXT dispatch; spare capacity, the analogue of sync devices
  sitting out a round, reported for scheduling diagnostics.

Public surface (one-line contracts):

* :class:`RoundEngine` — runs one FL episode under ``cfg.engine_mode``;
  ``run()`` returns the history dict (selector/buffer owned by caller).
* :class:`World` — per-episode immutable setup bundle (data shards,
  fleet, global model, family, paper-scale cost calibration).
* :func:`build_world` — build a :class:`World` from a config; shards the
  fleet over the ``"fleet"`` mesh when ``cfg.fleet_mesh`` asks for it.
* :func:`resolve_client_executor` — map ``cfg.client_executor`` ("auto" /
  "perclient" / "batched") to the concrete executor for this backend.
* :func:`sync_task_budget` — total client tasks a sync run dispatches at
  most (the async engine's default work budget).
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fleet import (FleetState, fleet_charge_jit, fleet_connect,
                              fleet_cost_matrix_jit, fleet_disconnect,
                              fleet_is_jax, fleet_set_busy,
                              fleet_total_remaining, make_fleet_state)
from repro.core.selection import MarlSelector
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import synthetic_image_dataset
from repro.fl import batch as fl_batch
from repro.fl import client as fl_client
from repro.fl import server as fl_server
from repro.models.family import ModelFamily, get_family


# ---------------------------------------------------------------------------
# shared episode setup (data shards, fleet, global model, cost calibration)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class World:
    """Everything one simulation episode needs, built once per episode."""
    x_tr: np.ndarray
    y_tr: np.ndarray
    x_val: np.ndarray
    y_val: np.ndarray
    parts: List[np.ndarray]
    fleet: FleetState
    global_params: Any
    n_models: int
    sizes: tuple
    fractions: tuple
    n_total: int
    family: ModelFamily = None


def build_world(cfg) -> World:
    """Exact port of the legacy ``_run_once`` setup (shared by the engine
    and the frozen reference loop, so parity starts from identical state)."""
    key = jax.random.PRNGKey(cfg.seed)
    x, y = synthetic_image_dataset(cfg.n_train, cfg.num_classes, hw=cfg.hw,
                                   noise=cfg.noise, seed=cfg.seed)
    n_val = max(64, int(cfg.n_val_fraction * cfg.n_train))
    x_val, y_val = x[:n_val], y[:n_val]          # server-side validation set
    x_tr, y_tr = x[n_val:], y[n_val:]
    parts = dirichlet_partition(y_tr, cfg.n_devices + cfg.hotplug_n,
                                cfg.alpha, cfg.seed)

    n_total = cfg.n_devices + cfg.hotplug_n
    fleet = make_fleet_state(n_total, cfg.seed,
                             data_sizes=[len(p) for p in parts],
                             backend="jax")
    fleet = fleet.replace(remaining=fleet.battery * cfg.energy_scale)
    if cfg.hotplug_n:                   # hot-plug devices: not yet connected
        fleet = fleet_disconnect(fleet, cfg.n_devices)
    if getattr(cfg, "fleet_mesh", 0) not in (0, 1):
        # opt-in data-parallel placement: [n] arrays row-sharded over the
        # "fleet" mesh so the per-round kernels run SPMD (no-op when the
        # runtime has a single device)
        from repro.sharding.fleet import maybe_shard_fleet
        fleet = maybe_shard_fleet(fleet, cfg.fleet_mesh)
    family = get_family(getattr(cfg, "model_family", None))
    global_params = family.init(key, cfg.num_classes,
                                width_mult=cfg.width_mult, hw=cfg.hw)
    M = family.num_submodels()
    # Energy/time accounting (Eq. 5 & 7) is calibrated to the PAPER-scale
    # backbone (full-width model on 32x32): the slim model is only the
    # CPU-budget compute proxy; batteries must see paper-scale costs for the
    # wooden-barrel dynamics to reproduce.
    sizes, fractions = family.cost_model(cfg.num_classes)
    return World(x_tr=x_tr, y_tr=y_tr, x_val=x_val, y_val=y_val, parts=parts,
                 fleet=fleet, global_params=global_params, n_models=M,
                 sizes=sizes, fractions=fractions, n_total=n_total,
                 family=family)


def _check_selection(sel, n_total: int) -> None:
    """The engine indexes ``model_choice`` by raw device id — a selector
    returning fewer entries than the fleet silently mis-indexes."""
    if len(sel.model_choice) != n_total:
        raise ValueError(
            f"selector returned {len(sel.model_choice)} model choices "
            f"for a fleet of {n_total}")


def _client_update(cfg, family, global_params, m, xi, yi, seed):
    return family.client_update(cfg.method, global_params, m, xi, yi,
                                epochs=cfg.local_epochs, batch=cfg.batch_size,
                                lr=cfg.lr, seed=seed)


# Above this per-step work, XLA CPU executes the per-client convs at
# BLAS-bound speed and batching them (vmapped GEMMs) cannot win — measured
# crossover between 1.8e7 (batched 2x faster) and 5.6e8 (batched 0.7x)
# FLOPs per training step on 2-core CPU; see benchmarks/client_bench.py.
_CPU_BATCHED_STEP_FLOPS = 5e7


def resolve_client_executor(cfg) -> str:
    """``cfg.client_executor``: "perclient" | "batched" | "auto".

    "auto" picks the bucketed-vmap executor (repro.fl.batch, <= 1 jit
    dispatch per submodel bucket per round) at 64+ device fleets — where
    per-participant dispatch dominates wall time — and the per-client path
    below that, which keeps small-fleet sync runs bit-for-bit equal to the
    frozen reference loop (vmap/scan fusion reorders float reductions at
    the ULP level, so the batched path is allclose, not bit-exact).  On
    CPU, large per-step models stay per-client: execution there is
    FLOP-bound, so bucketing only wins while per-op overhead dominates
    (small widths/images — exactly the CPU-budget large-fleet configs)."""
    mode = getattr(cfg, "client_executor", "auto")
    if mode == "auto":
        if cfg.n_devices < 64:
            return "perclient"
        if jax.default_backend() == "cpu":
            family = get_family(getattr(cfg, "model_family", None))
            step_flops = (family.flops_per_sample(
                family.num_submodels() - 1, cfg.hw, cfg.width_mult)
                * cfg.batch_size)
            return ("batched" if step_flops <= _CPU_BATCHED_STEP_FLOPS
                    else "perclient")
        return "batched"
    if mode in ("perclient", "batched"):
        return mode
    raise ValueError(f"unknown client_executor {mode!r} "
                     "(expected 'auto', 'perclient' or 'batched')")


def _run_batched_cohort(cfg, world, global_params, device_ids, model_idxs,
                        seeds, x_dev, y_dev) -> fl_batch.CohortResult:
    """One bucketed-vmap executor pass for ``device_ids`` (all must have
    local data).  Weights default to shard sizes inside run_cohort."""
    return fl_batch.run_cohort(
        cfg.method, global_params, x_dev, y_dev,
        [world.parts[i] for i in device_ids], device_ids, model_idxs, seeds,
        epochs=cfg.local_epochs, batch=cfg.batch_size, lr=cfg.lr,
        family=world.family)


def sync_task_budget(cfg) -> int:
    """Total client-task budget a sync run of ``cfg`` dispatches at most
    (sum over rounds of the connected-fleet Top-K k) — the async engine's
    default work budget, so both modes do the same amount of training."""
    k_pre = max(1, int(round(cfg.participation * cfg.n_devices)))
    if not cfg.hotplug_n:
        return cfg.n_rounds * k_pre
    hr = min(max(int(cfg.hotplug_round), 0), cfg.n_rounds)
    k_post = max(1, int(round(
        cfg.participation * (cfg.n_devices + cfg.hotplug_n))))
    return hr * k_pre + (cfg.n_rounds - hr) * k_post


def _marl_train(marl, buffer, hist, fleet, round_idx, n_updates):
    """Flush the episode trace into replay, run QMIX updates, and record
    effective-replay telemetry under ``hist["qmix"]`` (the resolved buffer
    capacity — possibly degraded by ``_make_buffer``'s obs budget — plus
    mixer mode, stored-agent width, update count and per-update TD loss),
    so fig5/table1 runs can report the replay the learner actually saw.

    Call order (episode_arrays → add_episode → sample/update loop) is
    byte-identical to the legacy inline blocks — the buffer RNG consumes
    the same draws, keeping sync parity with the frozen reference."""
    obs, state, actions, rewards = marl.episode_arrays(fleet, round_idx)
    buffer.add_episode(obs, state, actions, rewards)
    losses = []
    for _ in range(n_updates):
        batch = buffer.sample(marl.learner.cfg.batch_size)
        if batch:
            losses.append(marl.learner.update(batch)["td_loss"])
    q = hist.setdefault("qmix", {
        "mixer_mode": marl.mixer_mode,
        "replay_capacity": buffer.capacity,
        "replay_episode_len": buffer.T,
        "replay_agents": buffer.N,
        "replay_episodes": 0,
        "updates": 0,
        "td_loss": [],
    })
    q["replay_episodes"] = len(buffer)
    q["updates"] = marl.learner.updates
    q["td_loss"].extend(losses)


class RoundEngine:
    """Scheduler layer: runs one FL episode under ``cfg.engine_mode``.

    ``selector`` and (for MARL) ``buffer`` are owned by the caller —
    :func:`repro.fl.simulation.run_simulation` persists them across
    pre-training episodes exactly as the legacy loop did.
    """

    def __init__(self, cfg, selector, buffer=None, verbose: bool = False):
        self.cfg = cfg
        self.selector = selector
        self.buffer = buffer
        self.verbose = verbose
        self.mode = getattr(cfg, "engine_mode", "sync")
        self.executor = resolve_client_executor(cfg)

    def run(self) -> Dict:
        self.world = build_world(self.cfg)
        if self.mode == "sync":
            return self._run_sync()
        if self.mode == "async":
            return self._run_async()
        raise ValueError(f"unknown engine_mode {self.mode!r} "
                         "(expected 'sync' or 'async')")

    # ------------------------------------------------------------------
    # sync mode — barrier rounds, bit-for-bit the legacy loop
    # ------------------------------------------------------------------

    def _run_sync(self) -> Dict:
        cfg, w = self.cfg, self.world
        fleet = w.fleet
        global_params = w.global_params
        M = w.n_models
        selector, buffer = self.selector, self.buffer
        marl = selector if isinstance(selector, MarlSelector) else None

        x_dev = y_dev = None
        if self.executor == "batched":
            # training set stays device-resident: the bucketed executor
            # gathers mini-batches on device instead of per-step host copies
            x_dev, y_dev = jnp.asarray(w.x_tr), jnp.asarray(w.y_tr)

        hist = {"acc": [], "acc_mean": [], "energy": [], "round_time": [],
                "alive": [], "participants": [], "model_choices": [],
                "reward": [], "wall_clock": [], "sim_time": [], "idle": [],
                "dropouts": 0, "idle_time": 0.0, "engine": "sync"}
        prev_acc = float(np.mean(
            fl_server.evaluate(global_params, w.x_val, w.y_val,
                               family=w.family)))
        e_prev = fleet_total_remaining(fleet)
        w1, w2, w3 = cfg.reward_weights
        sim_time = 0.0
        n_agg = 0
        hotplug_done = False

        for t in range(cfg.n_rounds):
            t0 = time.time()
            if (cfg.hotplug_n and not hotplug_done
                    and t >= cfg.hotplug_round):
                # paper Step 1 hot-plug: new devices connect, receive the
                # global model (implicit — clients always pull W_t), start
                # with full batteries
                fleet = fleet_connect(fleet, cfg.n_devices, cfg.energy_scale)
                hotplug_done = True
            # Top-K budget tracks the CONNECTED fleet (see ISSUE 1 fix).
            n_connected = cfg.n_devices + (cfg.hotplug_n if hotplug_done
                                           else 0)
            k = max(1, int(round(cfg.participation * n_connected)))
            sel = selector.select(fleet, t, k, w.sizes, w.fractions,
                                  cfg.local_epochs, cfg.batch_size)
            _check_selection(sel, w.n_total)

            choice = np.asarray(sel.model_choice, np.int64)
            active = choice >= 0
            m_idx = np.clip(choice, 0, M - 1)
            t_tra_m, t_com_m, e_tra_m, e_com_m = fleet_cost_matrix_jit(
                fleet, w.sizes, w.fractions, cfg.local_epochs, cfg.batch_size)
            # gather each device's chosen-model column on device, charge,
            # then pull everything the round head needs in ONE sync
            m_col = jnp.asarray(m_idx)[:, None]
            t_cost_d = jnp.take_along_axis(t_tra_m + t_com_m, m_col, 1)[:, 0]
            need_d = jnp.take_along_axis(e_tra_m + e_com_m, m_col, 1)[:, 0]
            fleet, ok_d = fleet_charge_jit(fleet, need_d,
                                           jnp.asarray(active))
            # jaxlint: allow(host-sync-in-hot-path) -- the one batched pull per round head: charge outcome + per-device round times
            t_cost, ok = jax.device_get((t_cost_d, ok_d))
            hist["dropouts"] += int((active & ~ok).sum())
            survivors = active & ok
            t_round = float(t_cost[survivors].max()) if survivors.any() else 0.0
            # straggler wait: finished participants idle at the barrier
            idle_round = float((t_round - t_cost[survivors]).sum())

            # contributors: survivors with local data (large-fleet Dirichlet
            # splits can leave a device with no samples — it still paid the
            # round's mostly-comm energy but has nothing to contribute)
            cohort = [i for i in sel.participants
                      if survivors[i] and len(w.parts[i])]
            if self.executor == "batched" and cohort:
                # whole cohort in <= n_buckets jit dispatches (one per
                # populated submodel index), stacked deltas straight into
                # the Pallas layer-agg aggregation for DR-FL
                res = _run_batched_cohort(
                    cfg, w, global_params, cohort,
                    [int(choice[i]) for i in cohort],
                    [fl_client.client_update_seed(cfg.seed, t, i)
                     for i in cohort], x_dev, y_dev)
                if cfg.method == "drfl":
                    global_params = fl_server.aggregate_drfl_stacked(
                        global_params,
                        [(b.model_idx, b.stacked_delta, b.weights, None)
                         for b in res.buckets], server_lr=cfg.server_lr,
                        family=w.family)
                else:
                    contribs = res.unstacked()
                    global_params = fl_server.aggregate_sliced(
                        global_params, [c[2] for c in contribs],
                        [c[3] for c in contribs])
                n_agg += 1
            elif cohort:
                deltas, idxs, weights = [], [], []
                for i in cohort:
                    m = int(choice[i])
                    xi = w.x_tr[w.parts[i]]
                    yi = w.y_tr[w.parts[i]]
                    upd_seed = fl_client.client_update_seed(cfg.seed, t, i)
                    d_, _ = _client_update(cfg, w.family, global_params, m,
                                           xi, yi, upd_seed)
                    deltas.append(d_)
                    idxs.append(m)
                    weights.append(float(len(xi)))
                if cfg.method == "drfl":
                    global_params = fl_server.aggregate_drfl(
                        global_params, deltas, idxs, weights,
                        server_lr=cfg.server_lr, family=w.family)
                else:
                    global_params = fl_server.aggregate_sliced(
                        global_params, deltas, weights)
                n_agg += 1

            accs = fl_server.evaluate(global_params, w.x_val, w.y_val,
                                      family=w.family)
            acc = float(np.mean(accs))
            # jaxlint: allow(host-sync-in-hot-path) -- one batched pull per round tail: reward energy term + alive telemetry
            e_now_a, alive_a = jax.device_get((fleet.remaining.sum(),
                                               fleet.alive))
            e_now = float(e_now_a)
            reward = (w1 * (acc - prev_acc) - w2 * (e_prev - e_now)
                      - w3 * (t_round / 60.0))
            sim_time += t_round
            selector.observe_reward(reward, sim_time=sim_time)
            prev_acc, e_prev = acc, e_now

            if marl:
                if (t + 1) % cfg.marl_train_every == 0 and marl.ep_rewards:
                    _marl_train(marl, buffer, hist, fleet, t + 1,
                                cfg.marl_updates_per_round)

            alive_now = int(alive_a.sum())
            hist["acc"].append(np.asarray(accs))
            hist["acc_mean"].append(acc)
            hist["energy"].append(e_now)
            hist["round_time"].append(t_round)
            hist["alive"].append(alive_now)
            hist["participants"].append(list(sel.participants))
            hist["model_choices"].append(
                [sel.model_choice[i] for i in sel.participants])
            hist["reward"].append(reward)
            hist["wall_clock"].append(time.time() - t0)
            hist["sim_time"].append(sim_time)
            hist["idle"].append(idle_round)
            hist["idle_time"] += idle_round
            if self.verbose:
                print(f"  round {t:3d}: acc={acc:.3f} exits="
                      f"{np.round(np.asarray(accs), 3)} alive={alive_now}"
                      f" energy={e_now:,.0f}J time={t_round:.1f}s"
                      f" r={reward:+.2f}")
            if alive_now == 0:
                break

        hist["n_aggregations"] = n_agg
        hist["sim_time_total"] = sim_time
        return self._finalize(hist, global_params)

    # ------------------------------------------------------------------
    # async mode — event heap over per-device virtual clocks
    # ------------------------------------------------------------------

    def _run_async(self) -> Dict:
        cfg, w = self.cfg, self.world
        fleet = w.fleet
        global_params = w.global_params
        selector, buffer = self.selector, self.buffer
        marl = selector if isinstance(selector, MarlSelector) else None
        decay = getattr(cfg, "staleness_decay", 0.5)
        eval_every = max(1, int(getattr(cfg, "async_eval_every", 1)))
        horizon = float(getattr(cfg, "async_time_horizon", 0.0))
        budget = int(getattr(cfg, "async_task_budget", 0)
                     or sync_task_budget(cfg))
        w1, w2, w3 = cfg.reward_weights

        x_dev = y_dev = None
        if self.executor == "batched":
            x_dev, y_dev = jnp.asarray(w.x_tr), jnp.asarray(w.y_tr)

        hist = {"acc": [], "acc_mean": [], "energy": [], "round_time": [],
                "alive": [], "participants": [], "model_choices": [],
                "reward": [], "wall_clock": [], "sim_time": [], "idle": [],
                "staleness": [], "task_log": [], "dropouts": 0,
                "idle_time": 0.0, "wait_for_work": 0.0, "hotplug": None,
                "engine": "async"}
        acc_prev = float(np.mean(
            fl_server.evaluate(global_params, w.x_val, w.y_val,
                               family=w.family)))

        state = dict(now=0.0, version=0, seq=0, vround=0,
                     tasks_started=0, completions=0, inflight=0,
                     n_cohorts=0, next_commit=0, last_event=0.0,
                     hotplug_done=not cfg.hotplug_n, acc_prev=acc_prev,
                     window_t0=0.0, window_wall0=time.time(),
                     window_reward=0.0, window_idle=0.0)
        heap: list = []
        cohorts: Dict[int, dict] = {}   # one per selector.select call
        last_done: Dict[int, float] = {}
        window_devices: List[int] = []
        window_models: List[int] = []
        # authoritative virtual clocks, host-side float64: the jax-backend
        # FleetState stores busy_until in float32 (x64 is disabled), whose
        # ~8ms resolution at ~6.5e4 sim-seconds could mark a mid-task
        # device idle; fleet.busy_until is kept as an observability mirror
        # jaxlint: allow(host-sync-in-hot-path) -- one-time setup pull of the host clock mirror
        busy64 = np.asarray(fleet.busy_until, np.float64).copy()
        # alive mirror, maintained from values the loop pulls anyway (charge
        # outcomes, hotplug) so the per-event idle check costs no device sync
        # jaxlint: allow(host-sync-in-hot-path) -- one-time setup pull of the host alive mirror
        alive_host = np.asarray(fleet.alive, bool).copy()

        def n_connected():
            return cfg.n_devices + (cfg.hotplug_n if state["hotplug_done"]
                                    else 0)

        def top_k():
            return max(1, int(round(cfg.participation * n_connected())))

        def credit(cid, amount):
            cohorts[cid]["reward"] += amount
            state["window_reward"] += amount

        def commit_ready():
            # flush cohort rewards to the selector IN DISPATCH ORDER so the
            # MARL episode trace stays (obs_t, action_t, reward_t)-aligned
            # even when later dispatches complete first
            while (state["next_commit"] < state["n_cohorts"]
                   and cohorts[state["next_commit"]]["pending"] == 0):
                c = cohorts.pop(state["next_commit"])
                selector.observe_reward(c["reward"], sim_time=state["now"])
                state["next_commit"] += 1

        def maybe_hotplug(force: bool = False):
            nonlocal fleet
            if state["hotplug_done"] or (not force
                                         and state["vround"]
                                         < cfg.hotplug_round):
                return
            now = state["now"]
            k_before = top_k()
            fleet = fleet_connect(fleet, cfg.n_devices, cfg.energy_scale,
                                  now=now)
            busy64[cfg.n_devices:] = now
            alive_host[cfg.n_devices:] = True    # fleet_connect: joins live
            state["hotplug_done"] = True
            hist["hotplug"] = {
                "sim_time": now, "vround": state["vround"],
                "version": state["version"], "k_before": k_before,
                "k_after": top_k(),
                # jaxlint: allow(host-sync-in-hot-path) -- hotplug happens once per run; telemetry pull
                "join_remaining": [float(r) for r in np.asarray(
                    fleet.remaining)[cfg.n_devices:]],
            }

        def try_dispatch(n_sel) -> int:
            nonlocal fleet, alive_host
            now = state["now"]
            idle = alive_host & (busy64 <= now + 1e-9)
            if not idle.any():
                return 0
            cid = state["n_cohorts"]
            state["n_cohorts"] += 1
            cohorts[cid] = {"pending": 0, "reward": 0.0}
            alive_mask = (jnp.asarray(idle) if fleet_is_jax(fleet) else idle)
            sel = selector.select(fleet.replace(alive=alive_mask),
                                  state["vround"], n_sel, w.sizes,
                                  w.fractions, cfg.local_epochs,
                                  cfg.batch_size)
            _check_selection(sel, w.n_total)
            choice = np.asarray(sel.model_choice, np.int64)
            active = choice >= 0
            if active.any():
                m_idx = np.clip(choice, 0, w.n_models - 1)
                t_tra, t_com, e_tra, e_com = fleet_cost_matrix_jit(
                    fleet, w.sizes, w.fractions, cfg.local_epochs,
                    cfg.batch_size)
                m_col = jnp.asarray(m_idx)[:, None]
                need_d = jnp.take_along_axis(e_tra + e_com, m_col,
                                             1)[:, 0]
                # jaxlint: allow(host-sync-in-hot-path) -- first of the two batched pulls per dispatch tick: per-task times for the event heap
                t_cost = jax.device_get(
                    jnp.take_along_axis(t_tra + t_com, m_col, 1)[:, 0])
                if horizon > 0:
                    # only send work that can land inside the time budget
                    active &= (now + t_cost) <= horizon + 1e-9
                allow = budget - state["tasks_started"]
                kept = [i for i in sel.participants if active[i]][:allow]
                active = np.zeros(w.n_total, bool)
                active[kept] = True
            if not active.any():
                return 0
            e_before_d = fleet.remaining.sum()
            fleet, ok_d = fleet_charge_jit(fleet, need_d,
                                           jnp.asarray(active))
            # jaxlint: allow(host-sync-in-hot-path) -- second batched pull per dispatch tick: charge outcome + energy reward terms
            ok, e_before_a, e_after_a = jax.device_get(
                (ok_d, e_before_d, fleet.remaining.sum()))
            e_before, e_after = float(e_before_a), float(e_after_a)
            # fleet_charge kills attempted-but-unaffordable devices; fold
            # the same deaths into the host mirror
            alive_host &= ~(active & ~ok)
            hist["dropouts"] += int((active & ~ok).sum())
            # energy term at SEND time (includes batteries wasted by deaths)
            credit(cid, -w2 * (e_before - e_after))
            started = [i for i in sel.participants if active[i] and ok[i]]
            if not started:
                return 0
            busy64[np.asarray(started)] = now + t_cost[np.asarray(started)]
            fleet = fleet_set_busy(fleet, started,
                                   now + t_cost[np.asarray(started)])
            # micro-bucket: tasks sharing this dispatch tick train against
            # the SAME pulled snapshot, so the bucketed executor runs them
            # as <= n_buckets jit programs NOW and the completion events
            # just consume the precomputed deltas (semantically identical —
            # a client's delta depends only on dispatch-time state).  Each
            # task stores its (shared) BucketResult + row, not a sliced
            # per-client tree — one slice happens at aggregation time.
            rows_by_dev: Dict[int, Any] = {}
            if self.executor == "batched":
                with_data = [i for i in started if len(w.parts[i])]
                if with_data:
                    res = _run_batched_cohort(
                        cfg, w, global_params, with_data,
                        [int(choice[i]) for i in with_data],
                        [fl_client.client_update_seed(cfg.seed, cid, i)
                         for i in with_data], x_dev, y_dev)
                    for b in res.buckets:
                        for r, dev in enumerate(b.participants):
                            rows_by_dev[dev] = (b, r)
            for i in started:
                if i in last_done:            # wait-for-work since last task
                    hist["wait_for_work"] += now - last_done[i]
                task = {
                    "device": i, "m": int(choice[i]),
                    "version": state["version"],
                    "cohort": cid, "dispatch": cid, "t0": now,
                    "t_cost": float(t_cost[i]),
                }
                if self.executor == "batched":
                    task["delta_row"] = rows_by_dev.get(i)
                else:
                    # per-client path trains lazily at the completion event
                    task["params"] = global_params
                heapq.heappush(heap, (now + float(t_cost[i]), state["seq"],
                                      task))
                state["seq"] += 1
            cohorts[cid]["pending"] = len(started)
            state["tasks_started"] += len(started)
            state["inflight"] += len(started)
            return len(started)

        def refill():
            while (state["tasks_started"] < budget
                   and state["inflight"] < top_k()):
                if horizon > 0 and state["now"] >= horizon:
                    break
                n_sel = min(top_k() - state["inflight"],
                            budget - state["tasks_started"])
                if try_dispatch(n_sel) == 0:
                    break

        def emit_row():
            now = state["now"]
            accs = fl_server.evaluate(global_params, w.x_val, w.y_val,
                                      family=w.family)
            acc = float(np.mean(accs))
            # re-baseline the accuracy term here so eval_every > 1 doesn't
            # leak un-credited progress into later event rewards
            state["window_reward"] += w1 * (acc - state["acc_prev"])
            state["acc_prev"] = acc
            # jaxlint: allow(host-sync-in-hot-path) -- one batched telemetry pull per virtual round
            e_now_a, alive_a = jax.device_get((fleet.remaining.sum(),
                                               fleet.alive))
            e_now, alive_now = float(e_now_a), int(alive_a.sum())
            hist["acc"].append(np.asarray(accs))
            hist["acc_mean"].append(acc)
            hist["energy"].append(e_now)
            hist["round_time"].append(now - state["window_t0"])
            hist["alive"].append(alive_now)
            hist["participants"].append(list(window_devices))
            hist["model_choices"].append(list(window_models))
            hist["reward"].append(state["window_reward"])
            hist["wall_clock"].append(time.time() - state["window_wall0"])
            hist["sim_time"].append(now)
            hist["idle"].append(state["window_idle"])
            if self.verbose:
                print(f"  vround {state['vround']:3d}: acc={acc:.3f}"
                      f" alive={alive_now} energy={e_now:,.0f}J"
                      f" t={now:.1f}s r={state['window_reward']:+.2f}")
            window_devices.clear()
            window_models.clear()
            state["window_t0"] = now
            state["window_wall0"] = time.time()
            state["window_reward"] = 0.0
            state["window_idle"] = 0.0
            state["vround"] += 1

        def process_completion(task):
            nonlocal global_params
            now = state["now"]
            i = task["device"]
            state["inflight"] -= 1
            last_done[i] = now
            staleness = state["version"] - task["version"]
            cid = task["cohort"]
            cohorts[cid]["pending"] -= 1
            # time term pays the VIRTUAL TIME ADVANCED by this event (the
            # gap since the previous one), not the task's own duration:
            # gaps telescope to the window duration, so a virtual round's
            # total time penalty matches sync's t_round / FLEnv's event
            # gaps rather than k-fold overcharging overlapped tasks
            credit(cid, -w3 * ((now - state["last_event"]) / 60.0))
            state["last_event"] = now
            # straggler wait: the update is aggregated at this very event,
            # so it waits (now - finish_time) = 0 — computed, not assumed
            agg_wait = now - (task["t0"] + task["t_cost"])
            hist["idle_time"] += agg_wait
            state["window_idle"] += agg_wait
            n_i = len(w.parts[i])
            aggregated = False
            if n_i:
                batched = "delta_row" in task
                if batched:
                    # bucketed executor: delta precomputed at the dispatch
                    # tick against the snapshot pulled there; slice this
                    # client's row out of the shared bucket result now
                    bucket, row = task["delta_row"]
                else:
                    # clients train on the model snapshot they PULLED at
                    # dispatch; the server reconciles drift via staleness
                    seed = fl_client.client_update_seed(cfg.seed,
                                                        task["dispatch"], i)
                    delta, _ = _client_update(cfg, w.family, task["params"],
                                              task["m"],
                                              w.x_tr[w.parts[i]],
                                              w.y_tr[w.parts[i]], seed)
                if cfg.method == "drfl":
                    if batched:
                        delta_1 = jax.tree.map(
                            lambda a: a[row:row + 1], bucket.stacked_delta)
                        global_params = fl_server.aggregate_drfl_stacked(
                            global_params,
                            [(task["m"], delta_1, [float(n_i)],
                              [staleness])],
                            server_lr=cfg.server_lr, staleness_decay=decay,
                            family=w.family)
                    else:
                        global_params = fl_server.aggregate_drfl(
                            global_params, [delta], [task["m"]],
                            [float(n_i)], server_lr=cfg.server_lr,
                            staleness=[staleness], staleness_decay=decay,
                            family=w.family)
                else:
                    if batched:
                        delta = jax.tree.map(lambda a: a[row],
                                             bucket.stacked_delta)
                    a = fl_server.staleness_scale(staleness, decay)
                    if a != 1.0:
                        delta = jax.tree.map(
                            lambda u: (u * a).astype(u.dtype), delta)
                    global_params = fl_server.aggregate_sliced(
                        global_params, [delta], [float(n_i)])
                state["version"] += 1
                aggregated = True
            hist["staleness"].append(staleness)
            hist["task_log"].append({
                "device": i, "dispatch": task["dispatch"],
                "version": task["version"], "staleness": staleness,
                "m": task["m"], "t_dispatch": task["t0"], "t_done": now,
            })
            # per-aggregation accuracy evals exist to feed event-time
            # rewards; for non-learning selectors observe_reward is a
            # no-op, so only the virtual-round boundary evaluates
            if marl and aggregated and state["version"] % eval_every == 0:
                accs = fl_server.evaluate(global_params, w.x_val, w.y_val,
                                          family=w.family)
                acc = float(np.mean(accs))
                credit(cid, w1 * (acc - state["acc_prev"]))
                state["acc_prev"] = acc
            window_devices.append(i)
            window_models.append(task["m"])
            state["completions"] += 1
            if len(window_devices) >= top_k():
                emit_row()
                maybe_hotplug()

        # --- timeline -------------------------------------------------
        maybe_hotplug()      # hotplug_round == 0 joins before first dispatch
        refill()
        commit_ready()
        while True:
            if not heap:
                if not state["hotplug_done"] \
                        and state["tasks_started"] < budget:
                    # no event can ever advance the virtual-round counter
                    # to the join boundary (e.g. the whole initial fleet is
                    # too drained to take a task), but sync mode reaches it
                    # by ticking empty rounds — connect the joiners now so
                    # the two modes agree on the hot-plug story
                    maybe_hotplug(force=True)
                    refill()
                    commit_ready()
                    if heap:
                        continue
                break
            t_done, _, task = heapq.heappop(heap)
            state["now"] = t_done
            process_completion(task)
            refill()
            commit_ready()

        if window_devices:
            emit_row()
        # flush cohorts whose tasks were cut by the horizon/budget
        for c in cohorts.values():
            c["pending"] = 0
        commit_ready()

        if marl and buffer is not None and marl.ep_rewards:
            # event-driven runs have no natural mid-run barrier to train at
            # (the episode trace only fully commits once in-flight cohorts
            # land), so the learner trains at episode end with the same
            # total update count a sync run would have used
            n_updates = cfg.marl_updates_per_round * max(
                1, state["vround"] // max(1, cfg.marl_train_every))
            _marl_train(marl, buffer, hist, fleet, state["vround"],
                        n_updates)

        hist["n_tasks"] = state["tasks_started"]
        hist["n_aggregations"] = state["version"]
        hist["sim_time_total"] = state["now"]
        hist["k_final"] = top_k()
        return self._finalize(hist, global_params)

    def _finalize(self, hist, global_params) -> Dict:
        hist["final_acc"] = hist["acc"][-1] if hist["acc"] else np.zeros(4)
        hist["best_acc"] = (np.max(np.stack(hist["acc"]), axis=0)
                            if hist["acc"] else np.zeros(4))
        hist["params"] = global_params
        return hist
