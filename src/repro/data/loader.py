"""Minimal batching utilities (host numpy -> device arrays at the jit edge)."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def epoch_batches(x: np.ndarray, y: np.ndarray, batch: int, rng: np.random.Generator
                  ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """One shuffled epoch; last partial batch dropped (shape-stable jit)."""
    idx = rng.permutation(len(x))
    for i in range(0, len(idx) - batch + 1, batch):
        j = idx[i:i + batch]
        yield x[j], y[j]
    if len(idx) < batch:   # tiny client: one padded batch (wrap-around)
        j = np.resize(idx, batch)
        yield x[j], y[j]


def batch_iterator(x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0
                   ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    while True:
        yield from epoch_batches(x, y, batch, rng)
