"""Pluggable ModelFamily + SimulationSpec API (ISSUE 4).

Contracts:
* CNN parity — ``CnnFamily`` reproduces the pre-refactor ``cnn_*`` helper
  behavior bit-for-bit: update masks, stack template group layout, and
  per-method client updates against an inline copy of the legacy jitted
  SGD step.
* second family — the registered ``"mlp"`` family (early-exit MLP from
  repro.models.layers) completes ``run_simulation`` sync + async, the
  bucketed executor, and ``aggregate_drfl_stacked`` end-to-end.
* SimulationSpec — typed round-trip with the flat ``FLConfig`` is exact;
  misspelled knobs (``selector="mral"``, ``engine_mode="asynch"``) raise
  up front, including through ``run_simulation`` on flat configs.
* decoupling — no ``repro.models.cnn`` import inside ``repro/fl`` or
  ``repro.core.aggregation`` (the acceptance criterion of the redesign).
"""
import functools
import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import (FLConfig, EngineSpec, MarlSpec, ModelSpec,
                      SimulationSpec, run_simulation)
from repro.fl import batch as fl_batch
from repro.fl import client as fl_client
from repro.fl import server as fl_server
from repro.fl.environment import FLEnvConfig
from repro.core.selection import Selection
from repro.models import cnn, mlp
from repro.models.family import get_family, known_families, resolve_family


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_default_and_lookup():
    assert "cnn" in known_families() and "mlp" in known_families()
    fam = get_family()
    assert fam.name == "cnn"
    assert resolve_family(None) is fam
    assert resolve_family("mlp") is get_family("mlp")
    assert resolve_family(fam) is fam
    with pytest.raises(ValueError, match="unknown model family"):
        get_family("resnet9000")


def test_family_supported_methods():
    assert get_family("cnn").supports("heterofl")
    assert get_family("cnn").supports("scalefl")
    assert not get_family("mlp").supports("heterofl")
    with pytest.raises(ValueError, match="does not support"):
        get_family("mlp").client_update(
            "heterofl", {}, 0, np.zeros((4, 8, 8, 3)), np.zeros(4))


# ---------------------------------------------------------------------------
# CNN parity vs the pre-refactor cnn_* helpers
# ---------------------------------------------------------------------------


def _cnn_params(width=0.06):
    return cnn.init(jax.random.PRNGKey(0), 10, width_mult=width)


def _legacy_cnn_mask(global_params, model_idx, scale=1.0):
    """Inline copy of the pre-refactor fl_server.cnn_update_mask build."""
    def const(tree, v):
        return jax.tree.map(lambda _: jnp.asarray(v, jnp.float32), tree)

    return {
        "stem": const(global_params["stem"], scale),
        "stages": [const(s, scale if i <= model_idx else 0.0)
                   for i, s in enumerate(global_params["stages"])],
        "exits": [const(e, scale if i <= model_idx else 0.0)
                  for i, e in enumerate(global_params["exits"])],
    }


@pytest.mark.parametrize("m,scale", [(0, 1.0), (2, 1.0), (3, 1.0),
                                     (1, 0.37)])
def test_cnn_parity_update_mask(m, scale):
    params = _cnn_params()
    fam = get_family("cnn")
    got = fam.update_mask(params, m, scale=scale)
    want = _legacy_cnn_mask(params, m, scale)
    assert jax.tree.structure(got) == jax.tree.structure(want)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # mask cache: same structure + (m, scale) returns the same object
    assert fam.update_mask(params, m, scale=scale) is got


def test_cnn_parity_stack_template_and_groups():
    params = _cnn_params()
    fam = get_family("cnn")
    groups = fam.stack_groups(params)
    # pre-refactor _cnn_groups: [stem] + stages + exits
    legacy = [params["stem"]] + list(params["stages"]) + list(params["exits"])
    assert len(groups) == len(legacy) == 9
    for g, l in zip(groups, legacy):
        assert jax.tree.structure(g) == jax.tree.structure(l)
    template = fam.stack_template(params)
    sizes = tuple(sum(l.size for l in jax.tree.leaves(g)) for g in legacy)
    assert template.group_sizes == sizes
    # pre-refactor _held_groups: [True] + held + held
    assert fam.held_groups(params, 1) == [True, True, True, False, False,
                                          True, True, False, False]
    # template cache hit on identical shapes
    assert fam.stack_template(params) is template
    # unstack_groups inverts stack_groups
    rebuilt = fam.unstack_groups(params, groups)
    for a, b in zip(jax.tree.leaves(rebuilt), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _legacy_ce(logits, y):
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32),
                              axis=-1)[:, 0]
    return jnp.mean(lse - tgt)


@functools.partial(jax.jit, static_argnums=(3,))
def _legacy_drfl_step(params, x, y, model_idx, lr=0.05):
    """Inline copy of the pre-refactor fl_client._drfl_sgd_step."""
    def loss_fn(p):
        sub = {"stem": p["stem"], "stages": p["stages"][:model_idx + 1],
               "exits": p["exits"][:model_idx + 1]}
        outs = cnn.apply_all_exits(sub, x)
        loss = _legacy_ce(outs[-1], y)
        for o in outs[:-1]:
            loss = loss + 0.3 * _legacy_ce(o, y)
        return loss / (1.0 + 0.3 * (len(outs) - 1))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    return new, loss


def test_cnn_parity_drfl_client_update_bitexact():
    """family.client_update("drfl") == the legacy per-client SGD loop,
    bit-for-bit (identical jaxpr -> identical executable)."""
    from repro.data.loader import epoch_batches
    rng_data = np.random.default_rng(0)
    x = rng_data.normal(size=(70, 8, 8, 3)).astype(np.float32)
    y = rng_data.integers(0, 10, 70)
    params = _cnn_params()
    seed = fl_client.client_update_seed(0, 2, 5)
    m = 1
    got, got_loss = get_family("cnn").client_update(
        "drfl", params, m, x, y, epochs=2, batch=32, lr=0.05, seed=seed)

    rng = np.random.default_rng(seed)
    ref, losses = params, []
    for _ in range(2):
        for xb, yb in epoch_batches(x, y, 32, rng):
            ref, l = _legacy_drfl_step(ref, jnp.asarray(xb),
                                       jnp.asarray(yb), m, 0.05)
            losses.append(l)
    ref_delta = jax.tree.map(lambda a, b: a - b, ref, params)
    ref_loss = float(jnp.mean(jnp.stack(losses)))

    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref_delta)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert got_loss == ref_loss
    # the flat fl_client API routes through the same family program
    again, again_loss = fl_client.drfl_client_update(
        params, m, x, y, epochs=2, batch=32, lr=0.05, seed=seed)
    for a, b in zip(jax.tree.leaves(again), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert again_loss == got_loss


@pytest.mark.parametrize("method", ["heterofl", "scalefl"])
def test_cnn_parity_baseline_submodels(method):
    """Sliced submodel trees come from the same core.baselines slicers."""
    from repro.core.baselines import (WIDTH_LEVELS, scalefl_submodel,
                                      width_slice_cnn)
    params = _cnn_params()
    fam = get_family("cnn")
    for m in range(4):
        got = fam.submodel_params(method, params, m)
        want = (width_slice_cnn(params, WIDTH_LEVELS[m])
                if method == "heterofl" else scalefl_submodel(params, m))
        assert jax.tree.structure(got) == jax.tree.structure(want)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cnn_cost_model_matches_paper_scale_reference():
    """family.cost_model == the pre-refactor build_world calibration."""
    fam = get_family("cnn")
    sizes, fractions = fam.cost_model(10)
    ref_params = jax.eval_shape(
        lambda k: cnn.init(k, 10, width_mult=1.0), jax.random.PRNGKey(0))
    want_sizes = tuple(
        sum(x.size * x.dtype.itemsize
            for x in jax.tree.leaves(cnn.submodel_param_tree(ref_params, m)))
        for m in range(4))
    full = cnn.flops_per_sample(3, 32, 1.0)
    want_frac = tuple(cnn.flops_per_sample(m, 32, 1.0) / full
                      for m in range(4))
    assert sizes == want_sizes
    assert fractions == want_frac


# ---------------------------------------------------------------------------
# second family: early-exit MLP end-to-end
# ---------------------------------------------------------------------------


def _mlp_cfg(**kw):
    base = dict(n_devices=6, n_rounds=2, participation=0.5, n_train=400,
                local_epochs=1, method="drfl", selector="greedy", seed=1,
                model_family="mlp", hw=8)
    base.update(kw)
    return FLConfig(**base)


def test_mlp_model_shapes():
    params = mlp.init(jax.random.PRNGKey(0), 10, width_mult=0.5, hw=8)
    assert mlp.num_submodels() == 4
    x = jnp.zeros((3, 8, 8, 3))
    outs = mlp.apply_all_exits(params, x)
    assert len(outs) == 4
    assert all(o.shape == (3, 10) for o in outs)
    # truncated tree -> truncated exits (the drfl submodel contract)
    sub = get_family("mlp").submodel_tree(params, 1)
    assert len(mlp.apply_all_exits(sub, x)) == 2
    assert mlp.apply(params, x, 2).shape == (3, 10)
    # deeper submodels cost more
    fl = [mlp.flops_per_sample(m) for m in range(4)]
    assert fl == sorted(fl) and fl[0] < fl[-1]


def test_mlp_run_simulation_sync_and_async():
    h = run_simulation(_mlp_cfg())
    assert len(h["acc_mean"]) == 2 and np.isfinite(h["acc_mean"]).all()
    assert h["engine"] == "sync"
    h_async = run_simulation(_mlp_cfg(engine_mode="async", n_rounds=3))
    assert h_async["engine"] == "async"
    assert h_async["n_tasks"] > 0
    assert np.isfinite(h_async["acc_mean"]).all()


def test_mlp_sync_engine_matches_reference():
    """The frozen reference loop is family-routed too: sync-engine parity
    (the CNN contract of tests/test_engine.py) holds bit-for-bit for the
    second family as well."""
    from repro.fl.simulation import _run_once_reference
    cfg = _mlp_cfg(n_rounds=3)
    h_engine = run_simulation(cfg)
    h_ref, _, _ = _run_once_reference(cfg)
    for key in ("acc_mean", "energy", "round_time", "alive", "participants",
                "model_choices", "reward", "dropouts"):
        assert h_engine[key] == h_ref[key], key
    for a, b in zip(h_engine["acc"], h_ref["acc"]):
        np.testing.assert_array_equal(a, b)


def test_mlp_batched_executor_parity():
    """Bucketed-vmap executor + stacked Pallas-path aggregation run the
    MLP family end-to-end and agree with the per-client path."""
    h_pc = run_simulation(_mlp_cfg(client_executor="perclient"))
    h_b = run_simulation(_mlp_cfg(client_executor="batched"))
    assert h_b["participants"] == h_pc["participants"]
    assert h_b["energy"] == h_pc["energy"]
    np.testing.assert_allclose(h_b["acc_mean"], h_pc["acc_mean"], atol=0.06)


def test_mlp_stacked_aggregation_matches_list_reference():
    params = mlp.init(jax.random.PRNGKey(0), 10, width_mult=0.1, hw=8)
    key = jax.random.PRNGKey(1)
    deltas = [jax.tree.map(
        lambda a, j=j: jax.random.normal(jax.random.fold_in(key, j),
                                         a.shape) * 0.01, params)
        for j in range(5)]
    idxs = [j % 4 for j in range(5)]
    w = [float(3 + j) for j in range(5)]
    ref = fl_server.aggregate_drfl(params, deltas, idxs, w, server_lr=0.7,
                                   family="mlp")
    got = fl_server.aggregate_drfl_from_list(params, deltas, idxs, w,
                                             server_lr=0.7, family="mlp")
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   rtol=0)
    # untouched groups stay bit-identical (no client trained past exit 3's
    # needs here, but exit-0-only coverage leaves stage 3 untouched)
    only0 = fl_server.aggregate_drfl_from_list(params, deltas[:1], [0],
                                               [1.0], family="mlp")
    for a, b in zip(jax.tree.leaves(params["stages"][3]),
                    jax.tree.leaves(only0["stages"][3])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mlp_bucket_executor_matches_per_client():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(200, 8, 8, 3)).astype(np.float32)
    y = rng.integers(0, 10, 200)
    params = mlp.init(jax.random.PRNGKey(0), 10, width_mult=0.25, hw=8)
    parts = [np.arange(0, 40), np.arange(40, 100), np.arange(100, 140)]
    ids, ms = [0, 1, 2], [0, 1, 3]
    seeds = [fl_client.client_update_seed(0, 0, i) for i in ids]
    res = fl_batch.run_cohort("drfl", params, x, y, parts, ids, ms, seeds,
                              epochs=1, batch=32, lr=0.05, family="mlp")
    fam = get_family("mlp")
    for dev, m, delta, w, loss in res.unstacked():
        d_ref, l_ref = fam.client_update(
            "drfl", params, m, x[parts[dev]], y[parts[dev]], epochs=1,
            batch=32, lr=0.05, seed=seeds[dev])
        d_ref = fam.submodel_tree(d_ref, m)
        for a, b in zip(jax.tree.leaves(delta), jax.tree.leaves(d_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-4, rtol=0)
        assert loss == pytest.approx(l_ref, abs=1e-3)


def test_env_config_for_family():
    env_cfg = FLEnvConfig.for_family("mlp", n_devices=4, seed=3)
    fam = get_family("mlp")
    sizes, fractions = fam.cost_model(10)
    assert env_cfg.n_models == fam.num_submodels()
    assert env_cfg.model_bytes == tuple(float(s) for s in sizes)
    assert env_cfg.model_fractions == tuple(float(f) for f in fractions)
    assert env_cfg.n_devices == 4


# ---------------------------------------------------------------------------
# SimulationSpec: round-trip + validation
# ---------------------------------------------------------------------------


def test_spec_roundtrip_defaults_and_modified():
    flat = FLConfig()
    assert SimulationSpec.from_flat(flat).to_flat() == flat
    flat2 = FLConfig(n_devices=17, participation=0.3, method="scalefl",
                     selector="random", engine_mode="async",
                     async_task_budget=12, hotplug_round=2, hotplug_n=3,
                     width_mult=0.06, hw=8, batch_size=8, lr=0.01,
                     energy_scale=0.2, staleness_decay=0.8, seed=9,
                     reward_weights=(1.0, 2.0, 3.0), marl_episodes=2)
    assert SimulationSpec.from_flat(flat2).to_flat() == flat2
    spec = SimulationSpec(model=ModelSpec(family="mlp"),
                          marl=MarlSpec(selector="greedy"))
    assert SimulationSpec.from_flat(spec.to_flat()) == spec


def test_spec_run_simulation_equals_flat():
    flat = FLConfig(n_devices=5, n_rounds=2, participation=0.6, n_train=400,
                    local_epochs=1, method="drfl", selector="greedy", seed=0)
    h_flat = run_simulation(flat)
    h_spec = run_simulation(SimulationSpec.from_flat(flat))
    assert h_flat["participants"] == h_spec["participants"]
    assert h_flat["acc_mean"] == h_spec["acc_mean"]
    assert h_flat["energy"] == h_spec["energy"]


@pytest.mark.parametrize("bad", [
    lambda: SimulationSpec(marl=MarlSpec(selector="mral")),
    lambda: SimulationSpec(engine=EngineSpec(mode="asynch")),
    lambda: SimulationSpec(engine=EngineSpec(client_executor="vmap")),
    lambda: SimulationSpec(model=ModelSpec(family="resnet9000")),
    lambda: SimulationSpec(model=ModelSpec(batch_size=0)),
    lambda: SimulationSpec(method="fedavg"),
    lambda: SimulationSpec(participation=0.0),
    lambda: SimulationSpec(participation=1.5),
    lambda: SimulationSpec(n_val_fraction=1.0),
    lambda: SimulationSpec(method="heterofl", model=ModelSpec(family="mlp")),
])
def test_spec_validation_errors(bad):
    with pytest.raises(ValueError):
        bad()


def test_flat_config_validated_by_run_simulation():
    for bad in (dict(selector="mral"), dict(engine_mode="asynch"),
                dict(model_family="nope"), dict(client_executor="vamp"),
                dict(method="heterofl", model_family="mlp")):
        with pytest.raises(ValueError):
            run_simulation(FLConfig(n_devices=2, n_rounds=1, **bad))
    with pytest.raises(TypeError):
        run_simulation({"n_devices": 2})


def test_selection_rejects_out_of_range_participants():
    with pytest.raises(ValueError, match="out of range"):
        Selection(participants=[5], model_choice=[-1, -1, -1])
    Selection(participants=[0, 2], model_choice=[1, -1, 0])   # fine


# ---------------------------------------------------------------------------
# decoupling guard: the FL layer never imports the concrete CNN
# ---------------------------------------------------------------------------


def test_no_cnn_import_in_fl_or_aggregation():
    import repro.core.aggregation as agg
    import repro.fl as fl
    files = list(pathlib.Path(fl.__file__).parent.glob("*.py"))
    files.append(pathlib.Path(agg.__file__))
    pat = re.compile(
        r"^\s*(from\s+repro\.models\s+import\b.*\bcnn\b"
        r"|from\s+repro\.models\.cnn\s+import"
        r"|import\s+repro\.models\.cnn)", re.M)
    for f in files:
        assert not pat.search(f.read_text()), \
            f"{f} imports repro.models.cnn — FL must route through " \
            "repro.models.family"
