from repro.data.synthetic import (synthetic_image_dataset,
                                  synthetic_lm_dataset,
                                  synthetic_token_dataset)  # noqa: F401
from repro.data.partition import dirichlet_partition  # noqa: F401
from repro.data.loader import batch_iterator, epoch_batches  # noqa: F401
