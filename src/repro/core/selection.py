"""Dual-selection strategies (paper §4.3): choose, per round, (a) which
layer-wise model each device trains and (b) which devices participate.

``MarlSelector`` is the paper's method: per-agent argmax-Q picks the model
action (action M = do not participate), then Top-K over the chosen Q values
picks the participants.  Baseline selectors implement the comparison arms
used in §5 (greedy energy-aware, random, static-by-tier).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import DeviceState, round_cost
from repro.core.marl.qmix import QmixConfig, QmixLearner, epsilon


@dataclasses.dataclass
class Selection:
    participants: List[int]          # device indices
    model_choice: List[int]          # per-device submodel index (-1 = none)
    q_values: Optional[np.ndarray] = None


class SelectorBase:
    name = "base"

    def select(self, devices: Sequence[DeviceState], round_idx: int,
               k: int, model_sizes: Sequence[float],
               model_fractions: Sequence[float]) -> Selection:
        raise NotImplementedError

    def observe_reward(self, reward: float):
        pass


def obs_vector(dev: DeviceState, round_idx: int, n_rounds: int) -> np.ndarray:
    """Paper Eq. 9: s_t^n = [L_n, C_n, E_n, t] (+ last-round latencies,
    §4.3.2), normalised to O(1) ranges."""
    return np.array([
        dev.data_size / 1000.0,
        dev.effective_compute(1.0) / 500.0,
        dev.remaining / dev.profile.battery,
        round_idx / max(n_rounds, 1),
        1.0 if dev.alive else 0.0,
    ], np.float32)


OBS_DIM = 5


class MarlSelector(SelectorBase):
    """The paper's MARL-based dual-selection (QMIX, Fig. 3)."""

    name = "marl"

    def __init__(self, n_devices: int, n_models: int, n_rounds: int,
                 seed: int = 0):
        self.n_models = n_models
        self.n_rounds = n_rounds
        cfg = QmixConfig(
            n_agents=n_devices, obs_dim=OBS_DIM, num_actions=n_models + 1,
            state_dim=n_devices * OBS_DIM,
            eps_decay_rounds=max(10, n_rounds // 2))
        self.learner = QmixLearner(cfg, jax.random.PRNGKey(seed))
        self.key = jax.random.PRNGKey(seed + 1)
        self.hidden = self.learner.init_hidden()
        self.total_rounds = 0   # epsilon decays on TOTAL experience (across
                                # pre-training episodes), not per-episode
        # episode trace for the replay buffer
        self.ep_obs: List[np.ndarray] = []
        self.ep_state: List[np.ndarray] = []
        self.ep_actions: List[np.ndarray] = []
        self.ep_rewards: List[float] = []

    def reset_episode(self):
        self.hidden = self.learner.init_hidden()
        self.ep_obs, self.ep_state = [], []
        self.ep_actions, self.ep_rewards = [], []

    def select(self, devices, round_idx, k, model_sizes, model_fractions):
        obs = np.stack([obs_vector(d, round_idx, self.n_rounds) for d in devices])
        state = obs.reshape(-1)
        self.key, sub = jax.random.split(self.key)
        eps = epsilon(self.learner.cfg, self.total_rounds)
        self.total_rounds += 1
        # affordability action mask ("prevent selected devices from dropping
        # out of the FL process due to energy limitations", paper §4.2 Step 3)
        avail = np.zeros((len(devices), self.n_models + 1), bool)
        avail[:, self.n_models] = True      # not participating: always legal
        for i, d in enumerate(devices):
            if not d.alive:
                continue
            for m in range(self.n_models):
                _, _, e_tra, e_com = round_cost(d, model_sizes[m],
                                                model_fractions[m])
                avail[i, m] = (e_tra + e_com) < d.remaining
        actions, qv, self.hidden = self.learner.act(
            jnp.asarray(obs), self.hidden, sub, eps, jnp.asarray(avail))
        actions = np.array(actions)   # writable copies
        qv = np.array(qv)
        # dead devices never participate
        for i, d in enumerate(devices):
            if not d.alive:
                actions[i] = self.n_models
        willing = [i for i in range(len(devices)) if actions[i] < self.n_models]
        # Top-K over Q values among willing agents (paper §4.3.3)
        willing.sort(key=lambda i: -qv[i])
        chosen = willing[:k]
        model_choice = [int(actions[i]) if i in chosen else -1
                        for i in range(len(devices))]
        self.ep_obs.append(obs)
        self.ep_state.append(state)
        self.ep_actions.append(actions.copy())
        return Selection(participants=chosen, model_choice=model_choice,
                         q_values=qv)

    def observe_reward(self, reward: float):
        self.ep_rewards.append(float(reward))

    def episode_arrays(self, final_devices, round_idx):
        obs = np.stack(self.ep_obs + [np.stack(
            [obs_vector(d, round_idx, self.n_rounds) for d in final_devices])])
        state = obs.reshape(obs.shape[0], -1)
        return (obs, state, np.stack(self.ep_actions),
                np.asarray(self.ep_rewards, np.float32))


class GreedySelector(SelectorBase):
    """Energy-aware greedy (the paper's baseline adaptation): each device
    picks the LARGEST submodel it can afford this round; Top-K by remaining
    energy."""

    name = "greedy"

    def select(self, devices, round_idx, k, model_sizes, model_fractions):
        choice = {}
        for i, d in enumerate(devices):
            if not d.alive:
                continue
            best = -1
            for m in reversed(range(len(model_sizes))):
                t_tra, t_com, e_tra, e_com = round_cost(
                    d, model_sizes[m], model_fractions[m])
                if e_tra + e_com < d.remaining:
                    best = m
                    break
            if best >= 0:
                choice[i] = best
        order = sorted(choice, key=lambda i: -devices[i].remaining)
        chosen = order[:k]
        model_choice = [choice.get(i, -1) if i in chosen else -1
                        for i in range(len(devices))]
        return Selection(participants=chosen, model_choice=model_choice)


class RandomSelector(SelectorBase):
    """Vanilla-FL-style: uniform random K clients, random affordable model."""

    name = "random"

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def select(self, devices, round_idx, k, model_sizes, model_fractions):
        alive = [i for i, d in enumerate(devices) if d.alive]
        self.rng.shuffle(alive)
        chosen = alive[:k]
        model_choice = [-1] * len(devices)
        for i in chosen:
            model_choice[i] = int(self.rng.integers(0, len(model_sizes)))
        return Selection(participants=chosen, model_choice=model_choice)


class StaticTierSelector(SelectorBase):
    """HeteroFL-style static assignment: submodel fixed by device tier."""

    name = "static"
    TIER_MODEL = {"small": 0, "medium": 1, "large": 3}

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def select(self, devices, round_idx, k, model_sizes, model_fractions):
        alive = [i for i, d in enumerate(devices) if d.alive]
        self.rng.shuffle(alive)
        chosen = alive[:k]
        model_choice = [-1] * len(devices)
        for i in chosen:
            m = self.TIER_MODEL[devices[i].profile.tier]
            model_choice[i] = min(m, len(model_sizes) - 1)
        return Selection(participants=chosen, model_choice=model_choice)
