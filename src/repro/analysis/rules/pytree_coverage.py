"""Rule ``pytree-field-coverage``.

The repo's sharded/checkpointed runs depend on four hand-maintained
views of ``FleetState`` staying field-aligned:

* the ``_ARRAY_FIELDS`` tuple that drives ``tree_flatten`` /
  ``tree_unflatten``;
* the ``sharding/fleet.py`` name→PartitionSpec rule table (every array
  field must match some rule pattern);
* ``fleet_summary``'s input set — every array field is either read by
  the summary or named in ``SUMMARY_EXCLUDED_FIELDS`` with intent;
* the checkpoint field tuple in ``checkpoint/io.py``.

"Added a field, forgot one site" breaks sharded or restored runs
silently (the new field silently replicates, or silently drops from
checkpoints).  This rule makes the drift a lint failure.

Generically (works on fixture mini-repos too): for every class
registered with ``jax.tree_util.register_pytree_node_class``, each
dataclass field must appear in the class's ``tree_flatten`` method body
or in the aux-data expression.  The repo-specific cross-file checks
activate only when the configured modules exist in the index.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional, Sequence, Set, Tuple

from ..core import Finding, Module, RepoIndex

RULE = "pytree-field-coverage"


def _dataclass_fields(cls: ast.ClassDef) -> List[Tuple[str, int]]:
    out = []
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                          ast.Name):
            out.append((node.target.id, node.lineno))
    return out


def _names_in(node: ast.AST) -> Set[str]:
    """Every Name id, attribute name, and string constant under node."""
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
        elif isinstance(n, ast.Constant) and isinstance(n.value, str):
            out.add(n.value)
    return out


def _registered_pytree_classes(mod: Module) -> List[ast.ClassDef]:
    out = []
    for node in mod.tree.body:
        if not isinstance(node, ast.ClassDef):
            continue
        for deco in node.decorator_list:
            expr = deco.func if isinstance(deco, ast.Call) else deco
            names = _names_in(expr)
            if "register_pytree_node_class" in names:
                out.append(node)
    return out


def _module_assign(mod: Module, name: str) -> Optional[ast.expr]:
    """RHS of a module-level ``NAME = ...`` / ``NAME: T = ...``."""
    for node in mod.tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == name):
            return node.value
        if (isinstance(node, ast.AnnAssign)
                and isinstance(node.target, ast.Name)
                and node.target.id == name and node.value is not None):
            return node.value
    return None


def _module_tuple_const(mod: Module, name: str) -> Optional[List[str]]:
    """Value of a module-level ``NAME = ("a", "b", ...)`` assignment."""
    value = _module_assign(mod, name)
    if not isinstance(value, (ast.Tuple, ast.List)):
        return None
    vals = []
    for el in value.elts:
        if isinstance(el, ast.Constant) and isinstance(el.value, str):
            vals.append(el.value)
        else:
            return None
    return vals


def _flatten_coverage(index: RepoIndex) -> List[Finding]:
    findings: List[Finding] = []
    for mod in index.modules.values():
        for cls in _registered_pytree_classes(mod):
            flatten = unflatten = None
            for node in cls.body:
                if isinstance(node, ast.FunctionDef):
                    if node.name == "tree_flatten":
                        flatten = node
                    elif node.name == "tree_unflatten":
                        unflatten = node
            if flatten is None or unflatten is None:
                findings.append(Finding(
                    rule=RULE, file=mod.relpath, line=cls.lineno,
                    message=f"pytree class {cls.name} missing "
                            "tree_flatten/tree_unflatten"))
                continue
            # names mentioned anywhere in flatten/unflatten, including the
            # module-level field tuples they reference
            covered = _names_in(flatten) | _names_in(unflatten)
            for ref in list(covered):
                tup = _module_tuple_const(mod, ref)
                if tup:
                    covered.update(tup)
            for field, lineno in _dataclass_fields(cls):
                if field not in covered:
                    findings.append(Finding(
                        rule=RULE, file=mod.relpath, line=lineno,
                        message=f"{cls.name}.{field} not covered by "
                                "tree_flatten/tree_unflatten — sharding and "
                                "jit will silently drop it"))
    return findings


def _fleet_cross_checks(index: RepoIndex, config) -> List[Finding]:
    findings: List[Finding] = []
    fleet_mod = index.modules.get(config.fleet_module)
    if fleet_mod is None:
        return findings
    fields = _module_tuple_const(fleet_mod, config.fleet_fields_name)
    if fields is None:
        findings.append(Finding(
            rule=RULE, file=fleet_mod.relpath, line=1,
            message=f"{config.fleet_fields_name} tuple not found in "
                    f"{config.fleet_module}"))
        return findings

    # (a) sharding rule table: every array field must match some pattern
    shard_mod = index.modules.get(config.sharding_module)
    if shard_mod is not None:
        patterns = _rule_table_patterns(shard_mod, config.sharding_rules_name)
        if patterns is None:
            findings.append(Finding(
                rule=RULE, file=shard_mod.relpath, line=1,
                message=f"{config.sharding_rules_name} not found or not "
                        "statically readable"))
        else:
            for field in fields:
                if not any(re.fullmatch(p, field) for p in patterns):
                    findings.append(Finding(
                        rule=RULE, file=shard_mod.relpath, line=1,
                        message=f"field '{field}' matches no pattern in "
                                f"{config.sharding_rules_name} — it would "
                                "shard as unspecified"))

    # (b) fleet_summary reads every field or excludes it explicitly
    modname, _, fname = config.summary_func.partition(":")
    summary = index.functions.get(f"{modname}:{fname}")
    if summary is not None:
        read = _names_in(summary.node)
        summary_mod = index.modules[summary.module]
        excluded = _module_tuple_const(summary_mod,
                                       config.summary_exclusions_name)
        if excluded is None:
            findings.append(Finding(
                rule=RULE, file=summary_mod.relpath,
                line=summary.node.lineno,
                message=f"{config.summary_exclusions_name} tuple missing — "
                        "bless intentionally-unsummarised fields explicitly"))
            excluded = []
        for field in fields:
            if field not in read and field not in excluded:
                findings.append(Finding(
                    rule=RULE, file=summary_mod.relpath,
                    line=summary.node.lineno,
                    message=f"field '{field}' neither read by {fname} nor "
                            f"listed in {config.summary_exclusions_name}"))
        for field in excluded:
            if field not in fields:
                findings.append(Finding(
                    rule=RULE, file=summary_mod.relpath,
                    line=summary.node.lineno,
                    message=f"{config.summary_exclusions_name} names "
                            f"unknown field '{field}'"))

    # (c) checkpoint field tuple equals the pytree field tuple
    ckpt_mod = index.modules.get(config.checkpoint_module)
    if ckpt_mod is not None:
        ckpt_fields = _module_tuple_const(ckpt_mod,
                                          config.checkpoint_fields_name)
        if ckpt_fields is None:
            findings.append(Finding(
                rule=RULE, file=ckpt_mod.relpath, line=1,
                message=f"{config.checkpoint_fields_name} tuple missing "
                        f"from {config.checkpoint_module}"))
        elif set(ckpt_fields) != set(fields):
            missing = sorted(set(fields) - set(ckpt_fields))
            extra = sorted(set(ckpt_fields) - set(fields))
            findings.append(Finding(
                rule=RULE, file=ckpt_mod.relpath, line=1,
                message=f"{config.checkpoint_fields_name} out of sync with "
                        f"{config.fleet_fields_name}: missing={missing} "
                        f"extra={extra}"))
    return findings


def _rule_table_patterns(mod: Module,
                         name: str) -> Optional[List[str]]:
    """Regex patterns from ``NAME = ((r"pat", spec), ...)``."""
    value = _module_assign(mod, name)
    if not isinstance(value, (ast.Tuple, ast.List)):
        return None
    pats = []
    for el in value.elts:
        if (isinstance(el, (ast.Tuple, ast.List)) and el.elts
                and isinstance(el.elts[0], ast.Constant)
                and isinstance(el.elts[0].value, str)):
            pats.append(el.elts[0].value)
        else:
            return None
    return pats


def check(index: RepoIndex, config) -> List[Finding]:
    return _flatten_coverage(index) + _fleet_cross_checks(index, config)
