"""Fleet-engine microbench (the ISSUE-1 ≥10x claim): one DR-FL round's
selection + energy step — price every (device, model) pair, build the
affordability mask, charge the fleet — as the per-device Python loop over
DeviceState (reference semantics) vs the vectorized FleetState kernels.

Both FleetState backends are measured: numpy (float64, zero dispatch
overhead — the CPU winner at n=256: ~25x) and jax/jit (wins as n grows and
on accelerators; at small n the per-call dispatch dominates).

All paths are pure (no fleet mutation), so iterations are comparable.
Emits `fleet/<path>/n<N>` timings plus `fleet/speedup*/n<N>`."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, emit
from repro.core.energy import make_fleet, round_cost
from repro.core.fleet import (FleetState, fleet_affordability,
                              fleet_affordability_jit, fleet_charge,
                              fleet_charge_jit, fleet_round_cost)

SIZES_B = (2.8e6, 8.4e6, 22.5e6, 44.8e6)
FRACS = (0.11, 0.3, 0.72, 1.0)
NS = (256,) if FAST else (256, 1024, 4096)


def _ref_step(devs):
    """Scalar path: affordability mask + model-0 charge outcome, loop."""
    n, M = len(devs), len(SIZES_B)
    avail = np.zeros((n, M + 1), bool)
    avail[:, M] = True
    rem = np.empty(n)
    alive = np.empty(n, bool)
    for i, d in enumerate(devs):
        if not d.alive:
            rem[i], alive[i] = d.remaining, False
            continue
        need0 = 0.0
        for m in range(M):
            _, _, e_tra, e_com = round_cost(d, SIZES_B[m], FRACS[m])
            avail[i, m] = (e_tra + e_com) < d.remaining
            if m == 0:
                need0 = e_tra + e_com
        if d.remaining <= need0:
            rem[i], alive[i] = 0.0, False
        else:
            rem[i], alive[i] = d.remaining - need0, True
    return avail, rem, alive


def _vec_step_jax(fleet, need_model0, active):
    avail = fleet_affordability_jit(fleet, SIZES_B, FRACS, 5, 32)
    new_fleet, ok = fleet_charge_jit(fleet, need_model0, active)
    return avail, new_fleet, ok


def _vec_step_numpy(fleet, need_model0, active):
    avail = fleet_affordability(fleet, SIZES_B, FRACS, 5, 32)
    new_fleet, ok = fleet_charge(fleet, need_model0, active)
    return avail, new_fleet, ok


def _time(fn, iters):
    fn()  # warmup / compile
    t0 = time.time()
    for _ in range(iters):
        out = fn()
        jax.tree.map(lambda x: jax.block_until_ready(x)
                     if isinstance(x, jax.Array) else x, out)
    return (time.time() - t0) / iters * 1e6


def main():
    for n in NS:
        devs = make_fleet(n, seed=0)
        f_np = FleetState.from_devices(devs, backend="numpy")
        f_jx = FleetState.from_devices(devs, backend="jax")
        _, _, e_tra, e_com = fleet_round_cost(f_np, SIZES_B[0], FRACS[0])
        need_np = e_tra + e_com
        need_jx = jnp.asarray(need_np, jnp.float32)
        act_np = np.ones(n, bool)
        act_jx = jnp.ones(n, bool)
        iters = 3 if n > 1000 else 20
        us_ref = _time(lambda: _ref_step(devs), iters)
        us_np = _time(lambda: _vec_step_numpy(f_np, need_np, act_np),
                      iters * 10)
        us_jx = _time(lambda: _vec_step_jax(f_jx, need_jx, act_jx), iters)
        emit(f"fleet/loop_ref/n{n}", us_ref, f"devices={n};models=4")
        emit(f"fleet/vectorized_numpy/n{n}", us_np, f"devices={n};models=4")
        emit(f"fleet/vectorized_jax/n{n}", us_jx, f"devices={n};models=4")
        emit(f"fleet/speedup_numpy/n{n}", 0.0,
             f"x{us_ref / max(us_np, 1e-9):.1f}")
        emit(f"fleet/speedup_jax/n{n}", 0.0,
             f"x{us_ref / max(us_jx, 1e-9):.1f}")


if __name__ == "__main__":
    main()
