"""Bucketed-vmap client executor (repro.fl.batch) + stacked Pallas
aggregation (repro.fl.server.aggregate_drfl_stacked) vs the per-client /
list-based references.

Parity contracts:
* the executor's padded schedules replay data.loader.epoch_batches exactly
  (same host RNG, same sample order, wrap-around padding included);
* bucketed-vmap deltas match the per-client reference — vmap/scan fusion
  reorders float reductions, so single-step runs agree to ~1e-5 and
  multi-step runs to ~2e-3 (ULP differences amplified through SGD), never
  bit-exact by construction;
* stacked aggregation matches list-based ``aggregate_drfl``: ~1e-6 fresh
  (kernel reduction order differs at the ULP level), allclose under
  staleness decay, and s=0 is BIT-EXACT vs fresh (same compiled branch);
* a sync round at n=256 issues <= 4 client-update program executions (one
  per populated submodel bucket) and <= 4 program compilations.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.runtime import compile_guard
from repro.data.loader import epoch_batches
from repro.fl import FLConfig, resolve_client_executor, run_simulation
from repro.fl import batch as fl_batch
from repro.fl import client as fl_client
from repro.fl import server as fl_server
from repro.models import cnn


def _data(n=300, hw=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, hw, hw, 3)).astype(np.float32)
    y = rng.integers(0, 10, n)
    return x, y


def _params(width=0.06):
    return cnn.init(jax.random.PRNGKey(0), 10, width_mult=width)


# ---------------------------------------------------------------------------
# schedule parity with the per-client loader
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_i,batch,epochs", [(70, 32, 2), (20, 32, 3),
                                              (64, 16, 1), (5, 8, 2)])
def test_schedule_matches_epoch_batches(n_i, batch, epochs):
    part = np.arange(100, 100 + n_i)
    x = np.arange(1000)
    seed = fl_client.client_update_seed(0, 3, 7)
    sched = fl_batch.client_schedule(part, seed, epochs, batch)
    ref = []
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        for xb, _ in epoch_batches(x[part], x[part], batch, rng):
            ref.append(xb)
    assert len(sched) == len(ref)
    for row, xb in zip(sched, ref):
        np.testing.assert_array_equal(x[row], xb)


# ---------------------------------------------------------------------------
# bucketed-vmap deltas vs the per-client reference
# ---------------------------------------------------------------------------


def _cohort_parity(method, epochs, atol):
    x, y = _data()
    params = _params()
    # mixed sizes (incl. tiny wrap-around client) and mixed model indices
    parts = [np.arange(0, 40), np.arange(40, 52), np.arange(52, 120),
             np.arange(120, 140)]
    ids = [0, 1, 2, 3]
    ms = [0, 1, 1, 3]
    seeds = [fl_client.client_update_seed(0, 0, i) for i in ids]
    res = fl_batch.run_cohort(method, params, x, y, parts, ids, ms, seeds,
                              epochs=epochs, batch=32, lr=0.05)
    fn = getattr(fl_client, f"{method}_client_update")
    for dev, m, delta, w, loss in res.unstacked():
        d_ref, l_ref = fn(params, m, x[parts[dev]], y[parts[dev]],
                          epochs=epochs, batch=32, lr=0.05, seed=seeds[dev])
        assert w == float(len(parts[dev]))
        if method == "drfl":
            # reference deltas are full-structure with exact zeros outside
            # the submodel; the executor returns the submodel prefix
            assert all(bool(jnp.all(l == 0)) for l in jax.tree.leaves(
                {"stages": d_ref["stages"][m + 1:],
                 "exits": d_ref["exits"][m + 1:]}))
            d_ref = {"stem": d_ref["stem"], "stages": d_ref["stages"][:m + 1],
                     "exits": d_ref["exits"][:m + 1]}
        for a, b in zip(jax.tree.leaves(delta), jax.tree.leaves(d_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=atol, rtol=0)
        assert loss == pytest.approx(l_ref, abs=1e-3)


def test_bucketed_deltas_match_per_client_single_epoch():
    # one epoch, tiny shards -> few steps: reductions barely reorder
    _cohort_parity("drfl", epochs=1, atol=2e-4)


def test_bucketed_deltas_match_per_client_multi_epoch():
    # the executor's patches-conv (batched-GEMM) formulation reorders conv
    # reductions (~1e-6/step vs lax.conv); SGD amplifies that chaotically
    # over multi-epoch runs — documented tolerance on ~1e-2-scale deltas
    _cohort_parity("drfl", epochs=2, atol=6e-3)


@pytest.mark.parametrize("method", ["heterofl", "scalefl"])
def test_bucketed_deltas_match_baselines(method):
    _cohort_parity(method, epochs=1, atol=5e-4)


def test_bucket_padding_is_inert():
    """Pad rows (pow2 participant padding) carry weight 0.0 and the real
    rows are unchanged by their presence."""
    x, y = _data()
    params = _params()
    parts = [np.arange(0, 30), np.arange(30, 60), np.arange(60, 90)]
    ids, ms = [0, 1, 2], [2, 2, 2]
    seeds = [fl_client.client_update_seed(0, 0, i) for i in ids]
    res = fl_batch.run_cohort("drfl", params, x, y, parts, ids, ms, seeds,
                              epochs=1, batch=32, lr=0.05)
    (b,) = res.buckets
    leaves = jax.tree.leaves(b.stacked_delta)
    assert all(l.shape[0] == 4 for l in leaves)          # pow2(3) = 4
    assert b.weights == [30.0, 30.0, 30.0, 0.0]
    assert len(b.participants) == 3


# ---------------------------------------------------------------------------
# stacked aggregation vs the list-based reference
# ---------------------------------------------------------------------------


def _deltas(params, n, seed=1):
    key = jax.random.PRNGKey(seed)
    deltas = [jax.tree.map(
        lambda a, j=j: jax.random.normal(jax.random.fold_in(key, j),
                                         a.shape) * 0.01, params)
        for j in range(n)]
    idxs = [j % 4 for j in range(n)]
    weights = [float(5 + j) for j in range(n)]
    return deltas, idxs, weights


def test_stacked_aggregate_matches_list_reference():
    params = _params()
    deltas, idxs, w = _deltas(params, 7)
    ref = fl_server.aggregate_drfl(params, deltas, idxs, w, server_lr=0.7)
    got = fl_server.aggregate_drfl_from_list(params, deltas, idxs, w,
                                             server_lr=0.7)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   rtol=0)


def test_stacked_aggregate_staleness_matches_list_reference():
    params = _params()
    deltas, idxs, w = _deltas(params, 7)
    stal = [0, 2, 0, 5, 1, 0, 3]
    ref = fl_server.aggregate_drfl(params, deltas, idxs, w, server_lr=0.7,
                                   staleness=stal, staleness_decay=0.5)
    got = fl_server.aggregate_drfl_from_list(params, deltas, idxs, w,
                                             server_lr=0.7, staleness=stal,
                                             staleness_decay=0.5)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   rtol=0)


def test_stacked_aggregate_zero_staleness_bitexact_vs_fresh():
    params = _params()
    deltas, idxs, w = _deltas(params, 5)
    fresh = fl_server.aggregate_drfl_from_list(params, deltas, idxs, w)
    s0 = fl_server.aggregate_drfl_from_list(params, deltas, idxs, w,
                                            staleness=[0] * 5)
    for a, b in zip(jax.tree.leaves(fresh), jax.tree.leaves(s0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stacked_aggregate_untrained_layers_unchanged():
    params = _params()
    deltas, _, _ = _deltas(params, 1)
    out = fl_server.aggregate_drfl_from_list(params, deltas, [0], [1.0])
    for a, b in zip(jax.tree.leaves(params["stages"][3]),
                    jax.tree.leaves(out["stages"][3])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(params["stem"]),
                    jax.tree.leaves(out["stem"])):
        assert not np.allclose(np.asarray(a), np.asarray(b))


def test_stacked_aggregate_pallas_kernel_interpret():
    """The Pallas layer_agg kernel (interpret mode) plugs into the same
    stacked path and agrees with the einsum fallback and the list path."""
    params = cnn.init(jax.random.PRNGKey(0), 10, width_mult=0.02)
    deltas, idxs, w = _deltas(params, 5)
    ref = fl_server.aggregate_drfl(params, deltas, idxs, w, server_lr=0.7)
    got = fl_server.aggregate_drfl_from_list(
        params, deltas, idxs, w, server_lr=0.7, use_kernel=True,
        interpret=True)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-5)


def test_stacked_aggregate_staleness_with_kernel_interpret():
    params = cnn.init(jax.random.PRNGKey(0), 10, width_mult=0.02)
    deltas, idxs, w = _deltas(params, 4)
    stal = [1, 0, 4, 2]
    ref = fl_server.aggregate_drfl(params, deltas, idxs, w, staleness=stal)
    got = fl_server.aggregate_drfl_from_list(
        params, deltas, idxs, w, staleness=stal, use_kernel=True,
        interpret=True)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# engine wiring: batched executor through sync + async
# ---------------------------------------------------------------------------


def _small_cfg(**kw):
    base = dict(n_devices=6, n_rounds=3, participation=0.5, n_train=500,
                local_epochs=1, method="drfl", selector="greedy", seed=1)
    base.update(kw)
    return FLConfig(**base)


def test_sync_engine_batched_executor_parity():
    """Selection, energy and scheduling are executor-independent (exact);
    accuracies agree to vmap-reduction tolerance."""
    h_pc = run_simulation(_small_cfg(client_executor="perclient"))
    h_b = run_simulation(_small_cfg(client_executor="batched"))
    assert h_b["participants"] == h_pc["participants"]
    assert h_b["model_choices"] == h_pc["model_choices"]
    assert h_b["energy"] == h_pc["energy"]
    assert h_b["round_time"] == h_pc["round_time"]
    np.testing.assert_allclose(h_b["acc_mean"], h_pc["acc_mean"], atol=0.06)


@pytest.mark.parametrize("method", ["heterofl", "scalefl"])
def test_sync_engine_batched_baselines(method):
    h_pc = run_simulation(_small_cfg(method=method,
                                     client_executor="perclient"))
    h_b = run_simulation(_small_cfg(method=method,
                                    client_executor="batched"))
    assert h_b["participants"] == h_pc["participants"]
    np.testing.assert_allclose(h_b["acc_mean"], h_pc["acc_mean"], atol=0.06)


def test_async_engine_batched_executor():
    """Micro-bucketed dispatch-tick training: deltas precomputed at send
    time, consumed at completion events, staleness decay still applied."""
    cfg = _small_cfg(n_devices=8, n_rounds=4, engine_mode="async",
                     client_executor="batched")
    h = run_simulation(cfg)
    h_pc = run_simulation(dataclasses.replace(cfg,
                                              client_executor="perclient"))
    assert h["n_tasks"] == h_pc["n_tasks"]
    assert h["n_aggregations"] == len(h["staleness"])
    assert np.isfinite(h["acc_mean"]).all()
    np.testing.assert_allclose(h["acc_mean"], h_pc["acc_mean"], atol=0.06)


def test_resolve_client_executor_auto_rules():
    assert resolve_client_executor(_small_cfg()) == "perclient"
    big_small_model = _small_cfg(n_devices=128, hw=8, width_mult=0.06,
                                 batch_size=8)
    big_paper_model = _small_cfg(n_devices=128, hw=16, width_mult=0.25,
                                 batch_size=32)
    if jax.default_backend() == "cpu":
        assert resolve_client_executor(big_small_model) == "batched"
        # paper-width steps are BLAS-bound on CPU: batching cannot win
        assert resolve_client_executor(big_paper_model) == "perclient"
    assert resolve_client_executor(
        _small_cfg(client_executor="batched")) == "batched"
    with pytest.raises(ValueError):
        resolve_client_executor(_small_cfg(client_executor="nope"))


# ---------------------------------------------------------------------------
# dispatch-count regression guard (ISSUE 3 acceptance)
# ---------------------------------------------------------------------------


def test_dispatch_count_sync_round_n256():
    """A sync round at n=256 issues <= 4 client-update program executions
    (one per populated submodel bucket) and <= 4 compilations."""
    cfg = FLConfig(n_devices=256, n_rounds=1, participation=0.1,
                   n_train=1536, local_epochs=1, method="drfl",
                   selector="greedy", seed=0, energy_scale=0.05,
                   hw=8, width_mult=0.06, batch_size=8,
                   client_executor="batched")
    fl_batch.reset_counters()
    h = run_simulation(cfg)
    assert len(h["acc_mean"]) == 1
    assert 0 < fl_batch.COUNTERS["executions"] <= 4
    assert fl_batch.COUNTERS["compiles"] <= 4
    # and the auto rule picks the batched path for this CPU-budget config
    if jax.default_backend() == "cpu":
        assert resolve_client_executor(
            dataclasses.replace(cfg, client_executor="auto")) == "batched"


def test_repeat_cohort_reuses_compiled_programs():
    x, y = _data()
    params = _params()
    parts = [np.arange(0, 40), np.arange(40, 80)]
    ids, ms = [0, 1], [1, 3]
    seeds = [fl_client.client_update_seed(0, 0, i) for i in ids]
    kw = dict(epochs=1, batch=32, lr=0.05)
    fl_batch.reset_counters()
    fl_batch.run_cohort("drfl", params, x, y, parts, ids, ms, seeds, **kw)
    first = fl_batch.COUNTERS["compiles"]
    # the reusable runtime guard consumes the same COUNTERS dict: a repeat
    # cohort of identical shapes may not compile anything new
    with compile_guard(counters=fl_batch.COUNTERS, max_new=0):
        fl_batch.run_cohort("drfl", params, x, y, parts, ids, ms, seeds,
                            **kw)
    assert fl_batch.COUNTERS["executions"] == 2 * first
