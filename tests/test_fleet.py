"""FleetState vectorized engine vs the DeviceState scalar reference.

Parity contract: the numpy (float64) backend must match the scalar path
BIT-FOR-BIT — costs, affordability masks, charge outcomes, observations —
on a seeded heterogeneous fleet with dead/drained/mode-tuned devices.
The jax backend must agree to float32 tolerance with identical boolean
decisions.  Plus: selector equivalence across input types, the cost-model
bugfixes (configured epochs priced into the mask, k tracking the connected
fleet), and a 256-device run_simulation smoke."""
import copy

import numpy as np
import pytest

from repro.core import energy
from repro.core.energy import DeviceProfile, DeviceState, make_fleet
from repro.core.fleet import (FleetState, as_fleet_state, fleet_affordability,
                              fleet_charge, fleet_charge_jit,
                              fleet_connect, fleet_cost_matrix,
                              fleet_cost_matrix_jit, fleet_disconnect,
                              fleet_idle, fleet_round_cost, fleet_set_busy,
                              fleet_total_remaining, make_fleet_state)
from repro.core.selection import (GreedySelector, MarlSelector,
                                  StaticTierSelector, fleet_obs, obs_vector)

SIZES = (2.8e6, 8.4e6, 22.5e6, 44.8e6)
FRACS = (0.11, 0.3, 0.72, 1.0)


def _seeded_devices(n=33, seed=7):
    devs = make_fleet(n, seed=seed)
    devs[3].alive = False                 # dead
    devs[5].remaining = 10.0              # nearly drained
    devs[8].mode = "turbo"                # mode-tuned
    if n > 13:
        devs[11].mode = "eco"
        devs[13].remaining = 0.0          # drained but still alive
    return devs


# ---------------------------------------------------------------------------
# bit-for-bit parity (numpy float64 backend)
# ---------------------------------------------------------------------------


def test_cost_matrix_parity_bitexact():
    devs = _seeded_devices()
    fleet = FleetState.from_devices(devs, backend="numpy")
    t_tra, t_com, e_tra, e_com = fleet_cost_matrix(fleet, SIZES, FRACS,
                                                   local_epochs=5)
    for i, d in enumerate(devs):
        for m in range(len(SIZES)):
            ref = energy.round_cost(d, SIZES[m], FRACS[m], local_epochs=5)
            assert (t_tra[i, m], t_com[i, m], e_tra[i, m], e_com[i, m]) \
                == ref, (i, m)


def test_round_cost_single_model_parity_bitexact():
    devs = _seeded_devices()
    fleet = FleetState.from_devices(devs, backend="numpy")
    t_tra, t_com, e_tra, e_com = fleet_round_cost(fleet, SIZES[2], FRACS[2],
                                                  local_epochs=3)
    for i, d in enumerate(devs):
        assert (t_tra[i], t_com[i], e_tra[i], e_com[i]) \
            == energy.round_cost(d, SIZES[2], FRACS[2], local_epochs=3), i


def test_affordability_parity_bitexact():
    devs = _seeded_devices()
    fleet = FleetState.from_devices(devs, backend="numpy")
    got = fleet_affordability(fleet, SIZES, FRACS, local_epochs=5)
    M = len(SIZES)
    ref = np.zeros((len(devs), M + 1), bool)
    ref[:, M] = True                      # abstain always legal
    for i, d in enumerate(devs):
        if not d.alive:
            continue
        for m in range(M):
            _, _, e_tra, e_com = energy.round_cost(d, SIZES[m], FRACS[m],
                                                   local_epochs=5)
            ref[i, m] = (e_tra + e_com) < d.remaining
    np.testing.assert_array_equal(np.asarray(got), ref)


def test_charge_parity_bitexact():
    devs = _seeded_devices()
    fleet = FleetState.from_devices(devs, backend="numpy")
    # price model 1 for everyone; activate a mixed subset incl. the dead and
    # the drained devices
    _, _, e_tra, e_com = fleet_round_cost(fleet, SIZES[1], FRACS[1])
    need = np.asarray(e_tra + e_com)
    active = np.arange(len(devs)) % 3 != 1
    ref_devs = copy.deepcopy(devs)
    ref_ok = np.zeros(len(devs), bool)
    for i, d in enumerate(ref_devs):
        if active[i]:
            ref_ok[i] = energy.charge(d, float(e_tra[i]), float(e_com[i]))
    new_fleet, ok = fleet_charge(fleet, need, active)
    np.testing.assert_array_equal(np.asarray(ok), ref_ok)
    np.testing.assert_array_equal(
        np.asarray(new_fleet.remaining),
        np.array([d.remaining for d in ref_devs]))
    np.testing.assert_array_equal(
        np.asarray(new_fleet.alive), np.array([d.alive for d in ref_devs]))
    # input fleet untouched (functional kernel)
    assert float(fleet.remaining[0]) == devs[0].remaining
    assert fleet_total_remaining(new_fleet) == pytest.approx(
        energy.total_remaining(ref_devs))


def test_obs_parity_bitexact():
    devs = _seeded_devices()
    fleet = FleetState.from_devices(devs, backend="numpy")
    got = fleet_obs(fleet, 4, 20)
    ref = np.stack([obs_vector(d, 4, 20) for d in devs])
    np.testing.assert_array_equal(got, ref)


def test_device_roundtrip_preserves_state():
    devs = _seeded_devices()
    back = FleetState.from_devices(devs, backend="numpy").to_devices()
    for a, b in zip(devs, back):
        assert (a.profile, a.remaining, a.data_size, a.mode, a.alive) \
            == (b.profile, b.remaining, b.data_size, b.mode, b.alive)


# ---------------------------------------------------------------------------
# jax backend: float32-close values, identical decisions
# ---------------------------------------------------------------------------


def test_jax_backend_matches_numpy_reference():
    devs = _seeded_devices()
    f_np = FleetState.from_devices(devs, backend="numpy")
    f_jx = FleetState.from_devices(devs, backend="jax")
    ref = fleet_cost_matrix(f_np, SIZES, FRACS)
    got = fleet_cost_matrix_jit(f_jx, SIZES, FRACS, 5, 32)
    for r, g in zip(ref, got):
        np.testing.assert_allclose(np.asarray(g), r, rtol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(fleet_affordability(f_jx, SIZES, FRACS)),
        np.asarray(fleet_affordability(f_np, SIZES, FRACS)))
    _, _, e_tra, e_com = fleet_round_cost(f_np, SIZES[0], FRACS[0])
    need = np.asarray(e_tra + e_com)
    active = np.ones(len(devs), bool)
    ref_fleet, ref_ok = fleet_charge(f_np, need, active)
    jx_fleet, jx_ok = fleet_charge_jit(f_jx, need.astype(np.float32), active)
    np.testing.assert_array_equal(np.asarray(jx_ok), np.asarray(ref_ok))
    np.testing.assert_array_equal(np.asarray(jx_fleet.alive),
                                  np.asarray(ref_fleet.alive))
    np.testing.assert_allclose(np.asarray(jx_fleet.remaining),
                               np.asarray(ref_fleet.remaining), rtol=1e-5)


def test_busy_until_virtual_clocks():
    """Per-device virtual clocks for the async engine: fresh fleets are
    idle, fleet_set_busy marks tasks in flight, hot-plug joins idle at the
    join time."""
    fleet = make_fleet_state(6, seed=0, backend="numpy")
    np.testing.assert_array_equal(np.asarray(fleet.busy_until), np.zeros(6))
    assert fleet_idle(fleet, 0.0).all()
    busy = fleet_set_busy(fleet, [1, 4], [10.0, 3.5])
    # functional: the input fleet is untouched
    assert float(fleet.busy_until[1]) == 0.0
    np.testing.assert_array_equal(fleet_idle(busy, 5.0),
                                  [True, False, True, True, True, True])
    assert fleet_idle(busy, 10.0).all()
    # dead devices are never idle/dispatchable
    dead = busy.replace(alive=np.array([False] + [True] * 5))
    assert not fleet_idle(dead, 20.0)[0]
    # hot-plug: joiners come back idle as of the join event's sim time
    off = fleet_disconnect(fleet_set_busy(fleet, [4, 5], [99.0, 99.0]), 4)
    on = fleet_connect(off, 4, energy_scale=1.0, now=7.0)
    np.testing.assert_array_equal(np.asarray(on.busy_until)[4:], [7.0, 7.0])
    assert not fleet_idle(on, 6.0)[4]
    assert fleet_idle(on, 7.0)[4]
    # jax backend: busy_until flows through the pytree/jit kernels
    fj = make_fleet_state(6, seed=0, backend="jax")
    fj2, _ = fleet_charge_jit(fj, np.zeros(6, np.float32), np.ones(6, bool))
    assert np.shape(np.asarray(fj2.busy_until)) == (6,)


def test_connect_disconnect():
    fleet = make_fleet_state(8, seed=0, backend="numpy")
    fleet = fleet_disconnect(fleet, 5)
    assert list(np.asarray(fleet.alive)) == [True] * 5 + [False] * 3
    assert np.asarray(fleet.remaining)[5:].sum() == 0.0
    fleet = fleet_connect(fleet, 5, energy_scale=0.5)
    assert bool(np.asarray(fleet.alive).all())
    np.testing.assert_array_equal(np.asarray(fleet.remaining)[5:],
                                  np.asarray(fleet.battery)[5:] * 0.5)


# ---------------------------------------------------------------------------
# selectors: DeviceState sequence and FleetState inputs are interchangeable
# ---------------------------------------------------------------------------


def test_greedy_selector_same_on_devices_and_fleet():
    devs = _seeded_devices()
    fleet = FleetState.from_devices(devs, backend="numpy")
    a = GreedySelector().select(devs, 0, 5, list(SIZES), list(FRACS))
    b = GreedySelector().select(fleet, 0, 5, list(SIZES), list(FRACS))
    assert a.participants == b.participants
    assert a.model_choice == b.model_choice
    # greedy invariants: picks only alive+affordable, largest model wins
    for i in a.participants:
        assert devs[i].alive
        _, _, e_tra, e_com = energy.round_cost(
            devs[i], SIZES[a.model_choice[i]], FRACS[a.model_choice[i]])
        assert e_tra + e_com < devs[i].remaining


def test_marl_selector_same_on_devices_and_fleet():
    devs = _seeded_devices(n=10, seed=1)
    fleet = FleetState.from_devices(devs, backend="numpy")
    sa = MarlSelector(10, 4, n_rounds=20, seed=0)
    sb = MarlSelector(10, 4, n_rounds=20, seed=0)
    a = sa.select(devs, 0, 3, list(SIZES), list(FRACS))
    b = sb.select(fleet, 0, 3, list(SIZES), list(FRACS))
    assert a.participants == b.participants
    assert a.model_choice == b.model_choice
    np.testing.assert_array_equal(a.q_values, b.q_values)


def test_static_selector_uses_fleet_tiers():
    devs = _seeded_devices(n=12, seed=2)
    fleet = FleetState.from_devices(devs, backend="numpy")
    sel = StaticTierSelector(seed=0).select(fleet, 0, 6, list(SIZES),
                                            list(FRACS))
    for i in sel.participants:
        expect = min(StaticTierSelector.TIER_MODEL[devs[i].profile.tier], 3)
        assert sel.model_choice[i] == expect


# ---------------------------------------------------------------------------
# cost-model bugfixes
# ---------------------------------------------------------------------------


def test_affordability_prices_configured_epochs():
    """The action mask must reflect the energy the round will actually
    deduct: a device that can afford 1 local epoch but not 50 is selectable
    only under the former."""
    prof = DeviceProfile.from_tier("medium")
    dev = DeviceState(profile=prof, remaining=200.0, data_size=1000)
    g = GreedySelector()
    cheap = g.select([dev], 0, 1, [1e5], [1.0], local_epochs=1)
    dear = g.select([dev], 0, 1, [1e5], [1.0], local_epochs=50)
    assert cheap.participants == [0]
    assert dear.participants == []
    fleet = as_fleet_state([dev])
    assert bool(fleet_affordability(fleet, [1e5], [1.0], local_epochs=1)[0, 0])
    assert not bool(
        fleet_affordability(fleet, [1e5], [1.0], local_epochs=50)[0, 0])


def test_simulation_k_tracks_connected_fleet():
    """Participation fraction applies to the connected fleet: after hot-plug
    the Top-K budget must grow with it (it was previously pinned to
    cfg.n_devices)."""
    from repro.fl import FLConfig, run_simulation
    cfg = FLConfig(n_devices=4, n_rounds=3, participation=1.0, n_train=600,
                   local_epochs=1, method="drfl", selector="greedy", seed=0,
                   hotplug_round=1, hotplug_n=4)
    h = run_simulation(cfg)
    assert len(h["participants"][0]) <= 4
    assert max(len(p) for p in h["participants"][1:]) == 8


# ---------------------------------------------------------------------------
# scale smoke
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_run_simulation_256_devices_smoke():
    from repro.fl import FLConfig, run_simulation
    cfg = FLConfig(n_devices=256, n_rounds=2, participation=0.02,
                   n_train=2000, local_epochs=1, method="drfl",
                   selector="greedy", seed=0, energy_scale=0.05)
    h = run_simulation(cfg)
    assert len(h["acc_mean"]) == 2
    assert np.isfinite(h["acc_mean"]).all()
    assert 0 < h["alive"][-1] <= 256
    assert all(len(p) <= max(1, round(0.02 * 256)) for p in h["participants"])
