"""QMIX machinery: mixer monotonicity (the QMIX invariant), learner update,
replay buffer, epsilon schedule, selection semantics."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.marl.buffer import ReplayBuffer
from repro.core.marl.networks import (agent_hidden_init, agent_init,
                                      agent_step, mixer_apply, mixer_init)
from repro.core.marl.qmix import QmixConfig, QmixLearner, epsilon
from repro.core.energy import make_fleet
from repro.core.selection import MarlSelector, OBS_DIM, obs_vector


@hypothesis.given(seed=st.integers(0, 1000))
@hypothesis.settings(max_examples=15, deadline=None)
def test_mixer_monotonic_in_agent_qs(seed):
    """QMIX invariant: dQ_tot/dq_i >= 0 for every agent i and any state."""
    key = jax.random.PRNGKey(seed)
    n, sdim, e = 5, 11, 16
    params = mixer_init(key, n, sdim, e)
    qs = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    state = jax.random.normal(jax.random.fold_in(key, 2), (sdim,))
    g = jax.grad(lambda q: mixer_apply(params, q, state, n, e))(qs)
    assert bool(jnp.all(g >= -1e-6)), g


def test_agent_shared_weights_vary_by_obs():
    key = jax.random.PRNGKey(0)
    params = agent_init(key, OBS_DIM, 5)
    h = agent_hidden_init(3)
    obs = jnp.stack([jnp.zeros(OBS_DIM), jnp.ones(OBS_DIM), -jnp.ones(OBS_DIM)])
    q, h2 = agent_step(params, obs, h)
    assert q.shape == (3, 5) and h2.shape == h.shape
    assert not np.allclose(np.asarray(q[0]), np.asarray(q[1]))


def test_qmix_update_reduces_td_loss():
    cfg = QmixConfig(n_agents=4, obs_dim=OBS_DIM, num_actions=5,
                     state_dim=4 * OBS_DIM, lr=3e-3, target_update_every=1000)
    learner = QmixLearner(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    T = 6
    batch = {
        "obs": rng.normal(size=(8, T + 1, 4, OBS_DIM)).astype(np.float32),
        "state": rng.normal(size=(8, T + 1, 4 * OBS_DIM)).astype(np.float32),
        "actions": rng.integers(0, 5, size=(8, T, 4)),
        "rewards": rng.normal(size=(8, T)).astype(np.float32),
        "mask": np.ones((8, T), np.float32),
    }
    losses = [learner.update(batch)["td_loss"] for _ in range(30)]
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_replay_buffer_roundtrip():
    buf = ReplayBuffer(4, episode_len=5, n_agents=3, obs_dim=OBS_DIM,
                       state_dim=3 * OBS_DIM)
    obs = np.arange((3 + 1) * 3 * OBS_DIM, dtype=np.float32).reshape(4, 3, OBS_DIM)
    state = obs.reshape(4, -1)
    buf.add_episode(obs, state, np.ones((3, 3), np.int64),
                    np.array([1.0, 2.0, 3.0], np.float32))
    assert len(buf) == 1
    s = buf.sample(2)
    assert s["obs"].shape[1:] == (6, 3, OBS_DIM)
    np.testing.assert_allclose(s["mask"][0, :3], 1.0)
    np.testing.assert_allclose(s["mask"][0, 3:], 0.0)


def test_epsilon_schedule():
    cfg = QmixConfig(n_agents=2, obs_dim=3, num_actions=2, state_dim=6,
                     eps_decay_rounds=10)
    assert epsilon(cfg, 0) == pytest.approx(1.0)
    assert epsilon(cfg, 10) == pytest.approx(0.05)
    assert epsilon(cfg, 100) == pytest.approx(0.05)


def test_marl_selector_respects_topk_and_death():
    fleet = make_fleet(6, seed=0)
    fleet[2].alive = False
    sel = MarlSelector(6, 4, n_rounds=20, seed=0)
    s = sel.select(fleet, 0, k=2, model_sizes=[1e5] * 4,
                   model_fractions=[0.25, 0.5, 0.75, 1.0])
    assert len(s.participants) <= 2
    assert 2 not in s.participants
    for i, m in enumerate(s.model_choice):
        if i in s.participants:
            assert 0 <= m < 4
        else:
            assert m == -1
