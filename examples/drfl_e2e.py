"""End-to-end driver (the paper's kind: federated training).

Reproduces the paper's core experiment end-to-end: DR-FL vs HeteroFL vs
ScaleFL on a non-IID synthetic dataset under a binding energy budget, a few
hundred rounds at full scale.

    PYTHONPATH=src python examples/drfl_e2e.py                 # CPU-budget
    PYTHONPATH=src python examples/drfl_e2e.py --full          # paper-scale
    PYTHONPATH=src python examples/drfl_e2e.py --alpha 0.1 --rounds 50

Writes per-arm histories (drfl_e2e_results.json) and a checkpoint of the
final DR-FL global model into the ``--out`` directory (default ``tmp/``,
created on demand) so runs never litter the working tree.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.checkpoint import save_pytree
from repro.fl import FLConfig, run_simulation


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 40 devices, 200 rounds (slow on CPU)")
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="tmp",
                    help="output directory for results + model checkpoint")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    if args.full:
        base = dict(n_devices=40, n_rounds=200, n_train=8000, local_epochs=5,
                    participation=0.1)
    else:
        base = dict(n_devices=10, n_rounds=20, n_train=1500, local_epochs=2,
                    participation=0.3)
    if args.rounds:
        base["n_rounds"] = args.rounds
    if args.devices:
        base["n_devices"] = args.devices

    results = {}
    for method, sel in (("drfl", "marl"), ("heterofl", "greedy"),
                        ("scalefl", "greedy")):
        print(f"\n=== {method} ({sel}) ===")
        cfg = FLConfig(method=method, selector=sel, alpha=args.alpha,
                       seed=args.seed, energy_scale=0.05, **base)
        h = run_simulation(cfg, verbose=True)
        results[method] = {
            "acc_mean": h["acc_mean"],
            "best_acc": np.asarray(h["best_acc"]).tolist(),
            "energy": h["energy"],
            "alive": h["alive"],
            "round_time": h["round_time"],
            "dropouts": h["dropouts"],
        }
        if method == "drfl":
            ckpt = os.path.join(args.out, "drfl_global_model.ckpt")
            save_pytree(ckpt, h["params"])
            print(f"saved DR-FL global model -> {ckpt}")

    out_json = os.path.join(args.out, "drfl_e2e_results.json")
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"\nwrote {out_json}")
    print("\nfinal best-exit accuracies:")
    for m, r in results.items():
        print(f"  {m:10s} best_acc={np.round(r['best_acc'], 3)} "
              f"alive={r['alive'][-1]} dropouts={r['dropouts']}")


if __name__ == "__main__":
    main()
