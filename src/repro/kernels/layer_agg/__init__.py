from repro.kernels.layer_agg.ops import (aggregate_stacked_leaf,  # noqa: F401
                                         layer_agg_op)
from repro.kernels.layer_agg.ref import layer_agg_ref  # noqa: F401
