"""Paper-faithful backbone: ResNet-18-style CNN with 4 early exits.

DR-FL (§5.1.1): "The ResNet-18 model serves as the backbone. Each block of
the ResNet-18 model is accompanied by a bottleneck and classifier, resulting
in the creation of four distinct layer-wise models" (Models 1–4).

Model_m = stem + stages[0..m] + exit[m]  (depth-prefix submodel).
Exit head = 1x1 bottleneck conv + global-avg-pool + linear classifier.

Parameters are a dict with per-stage subtrees so the DR-FL layer-wise
aggregation can mask whole stages; exits are aggregated only across clients
training the same exit.
"""
from __future__ import annotations

import contextlib
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

STAGE_CHANNELS = (64, 128, 256, 512)
BLOCKS_PER_STAGE = 2


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return (jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)
            * math.sqrt(2.0 / fan_in))


# Under vmap with per-client kernels (the bucketed client-update executor),
# lax.conv lowers to a grouped convolution, which XLA CPU executes on a
# naive non-Eigen path — up to ~10x slower per FLOP at paper widths.  The
# patches formulation below turns the same conv into static slices + an
# einsum; vmapped, that is a batched GEMM, which XLA CPU runs at BLAS
# speed.  Trace-time flag: only the bucket program flips it (and only on
# CPU); everything else keeps the cuDNN/Eigen/MXU-friendly lax.conv.
_CONV_VIA_PATCHES = False


@contextlib.contextmanager
def conv_via_patches():
    global _CONV_VIA_PATCHES
    prev = _CONV_VIA_PATCHES
    _CONV_VIA_PATCHES = True
    try:
        yield
    finally:
        _CONV_VIA_PATCHES = prev


def _conv_patches(x, w, stride=1):
    """SAME conv as shifted slices + einsum (identical math to lax.conv up
    to float reduction order)."""
    B, H, W, _ = x.shape
    kh, kw, _, _ = w.shape
    ho = -(-H // stride)
    wo = -(-W // stride)
    ph = max((ho - 1) * stride + kh - H, 0)
    pw = max((wo - 1) * stride + kw - W, 0)
    xp = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                     (pw // 2, pw - pw // 2), (0, 0)))
    rows = []
    for i in range(kh):
        cols = []
        for j in range(kw):
            cols.append(xp[:, i:i + stride * (ho - 1) + 1:stride,
                           j:j + stride * (wo - 1) + 1:stride, :])
        rows.append(jnp.stack(cols, axis=-2))
    patches = jnp.stack(rows, axis=-3)            # [B, ho, wo, kh, kw, C]
    return jnp.einsum("bhwijc,ijco->bhwo", patches, w)


def _conv(x, w, stride=1):
    if _CONV_VIA_PATCHES:
        return _conv_patches(x, w, stride)
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _groupnorm(p, x, groups=8):
    # GroupNorm instead of BatchNorm: batch-size independent (FL clients train
    # with small local batches; avoids running-stat aggregation headaches).
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:        # width-sliced channel counts need not divide 8
        g -= 1
    xg = x.reshape(B, H, W, g, C // g)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + 1e-5)
    return xg.reshape(B, H, W, C) * p["scale"] + p["bias"]


def _basic_block_init(key, cin, cout, stride):
    ks = jax.random.split(key, 3)
    p = {
        "conv1": _conv_init(ks[0], 3, 3, cin, cout), "gn1": _gn_init(cout),
        "conv2": _conv_init(ks[1], 3, 3, cout, cout), "gn2": _gn_init(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[2], 1, 1, cin, cout)
    return p


def _basic_block(p, x, stride):
    h = jax.nn.relu(_groupnorm(p["gn1"], _conv(x, p["conv1"], stride)))
    h = _groupnorm(p["gn2"], _conv(h, p["conv2"]))
    sc = _conv(x, p["proj"], stride) if "proj" in p else x
    return jax.nn.relu(h + sc)


def init(key, num_classes: int = 10, in_channels: int = 3,
         width_mult: float = 1.0):
    """width_mult < 1 slims every stage (CPU-budget benchmark runs keep the
    4-stage / 4-exit ResNet-18 topology but shrink channels)."""
    chans = [max(8, int(c * width_mult)) for c in STAGE_CHANNELS]
    ks = jax.random.split(key, 2 + len(chans) * (BLOCKS_PER_STAGE + 1))
    it = iter(ks)
    c0 = chans[0]
    params = {
        "stem": {"conv": _conv_init(next(it), 3, 3, in_channels, c0),
                 "gn": _gn_init(c0)},
        "stages": [],
        "exits": [],
    }
    cin = c0
    for si, cout in enumerate(chans):
        blocks = []
        for bi in range(BLOCKS_PER_STAGE):
            stride = 2 if (bi == 0 and si > 0) else 1
            blocks.append(_basic_block_init(next(it), cin, cout, stride))
            cin = cout
        params["stages"].append(blocks)
        kb = next(it)
        k1, k2 = jax.random.split(kb)
        bott = max(16, cout // 2)
        params["exits"].append({
            "bottleneck": _conv_init(k1, 1, 1, cout, bott),
            "gn": _gn_init(bott),
            "w": jax.random.normal(k2, (bott, num_classes)) / math.sqrt(bott),
            "b": jnp.zeros((num_classes,)),
        })
    return params


def num_submodels() -> int:
    return len(STAGE_CHANNELS)


def _exit_head(p, x):
    h = jax.nn.relu(_groupnorm(p["gn"], _conv(x, p["bottleneck"])))
    h = h.mean(axis=(1, 2))
    return h @ p["w"] + p["b"]


def apply(params, x, model_idx: int):
    """x: [B,32,32,3] -> logits at exit ``model_idx`` (0..3).

    ``model_idx`` selects the depth-prefix submodel (Model_{idx+1}).
    Static python int — each submodel is its own (tiny) jitted program.
    """
    h = jax.nn.relu(_groupnorm(params["stem"]["gn"], _conv(x, params["stem"]["conv"])))
    for si in range(model_idx + 1):
        for bi, bp in enumerate(params["stages"][si]):
            stride = 2 if (bi == 0 and si > 0) else 1
            h = _basic_block(bp, h, stride)
    return _exit_head(params["exits"][model_idx], h)


def apply_all_exits(params, x):
    """Returns logits from every exit held by ``params`` (supports truncated
    / width-sliced submodel trees as well as the full global model)."""
    h = jax.nn.relu(_groupnorm(params["stem"]["gn"], _conv(x, params["stem"]["conv"])))
    outs = []
    for si in range(len(params["stages"])):
        for bi, bp in enumerate(params["stages"][si]):
            stride = 2 if (bi == 0 and si > 0) else 1
            h = _basic_block(bp, h, stride)
        outs.append(_exit_head(params["exits"][si], h))
    return outs


def submodel_param_tree(params, model_idx: int):
    """The pytree a Model_{idx+1} client actually holds/trains."""
    return {
        "stem": params["stem"],
        "stages": params["stages"][:model_idx + 1],
        "exits": [params["exits"][model_idx]],
    }


def submodel_size_bytes(params, model_idx: int) -> int:
    tree = submodel_param_tree(params, model_idx)
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def flops_per_sample(model_idx: int, image_hw: int = 32,
                     width_mult: float = 1.0) -> float:
    """Rough analytic forward FLOPs for Model_{idx+1} (energy model input)."""
    chans = [max(8, int(c * width_mult)) for c in STAGE_CHANNELS]
    total, hw, cin = 0.0, image_hw, 3
    total += 2 * 9 * cin * chans[0] * hw * hw
    cin = chans[0]
    for si in range(model_idx + 1):
        cout = chans[si]
        stride = 2 if si > 0 else 1
        hw = hw // stride
        for bi in range(BLOCKS_PER_STAGE):
            total += 2 * 9 * cin * cout * hw * hw
            total += 2 * 9 * cout * cout * hw * hw
            cin = cout
    total += 2 * cin * max(16, cin // 2) * hw * hw
    return total


# ---------------------------------------------------------------------------
# ModelFamily adapter: the registered default family ("cnn")
# ---------------------------------------------------------------------------


from repro.models.family import LayerwiseFamily, register_family  # noqa: E402


class CnnFamily(LayerwiseFamily):
    """The paper's multi-exit ResNet-18 as a pluggable :class:`ModelFamily`.

    The only family that supports all three FL methods: HeteroFL /
    ScaleFL submodels are structural channel-prefix slices of the conv
    tree (:mod:`repro.core.baselines`)."""

    name = "cnn"
    supported_methods = ("drfl", "heterofl", "scalefl")

    def init(self, key, num_classes: int = 10, width_mult: float = 1.0,
             hw: int = 32):
        # parameters are image-size independent; ``hw`` only matters for
        # the analytic FLOP model
        return init(key, num_classes, width_mult=width_mult)

    def num_submodels(self) -> int:
        return num_submodels()

    def apply_all_exits(self, params, x):
        return apply_all_exits(params, x)

    def flops_per_sample(self, model_idx: int, image_hw: int = 32,
                         width_mult: float = 1.0) -> float:
        return flops_per_sample(model_idx, image_hw, width_mult)

    def submodel_params(self, method: str, global_params, model_idx: int):
        from repro.core.baselines import (WIDTH_LEVELS, scalefl_submodel,
                                          width_slice_cnn)
        if method == "heterofl":
            return width_slice_cnn(global_params, WIDTH_LEVELS[model_idx])
        if method == "scalefl":
            return scalefl_submodel(global_params, model_idx)
        return super().submodel_params(method, global_params, model_idx)

    def bucket_trace_context(self):
        # vmapped lax.conv with per-client kernels = grouped conv, which
        # XLA CPU runs ~10x off BLAS speed at paper widths; trace the
        # batched convs as patches+einsum (batched GEMMs) instead
        if jax.default_backend() == "cpu":
            return conv_via_patches()
        import contextlib
        return contextlib.nullcontext()


register_family(CnnFamily())
