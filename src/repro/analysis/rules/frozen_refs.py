"""Rule ``frozen-reference-integrity``.

Two artifacts in this repo are *frozen*: the synchronous reference loop
``simulation._run_once_reference`` (the bit-for-bit ground truth the
engine parity test compares against) and the pre-factoring selector copy
in ``tests/test_factored_state.py`` (the ground truth for the factored
QMIX state refactor).  Editing either one silently moves the goalposts:
the parity tests would then assert "engine == whatever the reference
became", not "engine == the blessed behaviour".

This rule pins each artifact's content hash (sha256 over its source
span, decorators included, trailing whitespace stripped per line) in
``src/repro/analysis/frozen_refs.json``.  Any edit fails the lint with
instructions; when a change is *intended*, re-bless with::

    python scripts/jaxlint.py --bless-frozen

and say why in the commit message.
"""
from __future__ import annotations

import ast
import hashlib
import json
import os
from typing import Dict, List, Optional, Tuple

from ..core import Finding, RepoIndex

RULE = "frozen-reference-integrity"


def _find_span(path: str, name: str, kind: str) \
        -> Optional[Tuple[int, int]]:
    """Line span (1-based, inclusive, decorators included) of a top-level
    function or class ``name`` in ``path``."""
    try:
        with open(path, encoding="utf-8") as fh:
            tree = ast.parse(fh.read(), filename=path)
    except (OSError, SyntaxError):
        return None
    want = (ast.ClassDef,) if kind == "class" else (ast.FunctionDef,
                                                    ast.AsyncFunctionDef)
    for node in tree.body:
        if isinstance(node, want) and node.name == name:
            first = min([node.lineno]
                        + [d.lineno for d in node.decorator_list])
            return first, node.end_lineno or node.lineno
    return None


def hash_target(repo_root: str, relpath: str, name: str,
                kind: str) -> Optional[str]:
    path = os.path.join(repo_root, relpath)
    span = _find_span(path, name, kind)
    if span is None:
        return None
    with open(path, encoding="utf-8") as fh:
        lines = fh.read().splitlines()
    chunk = "\n".join(l.rstrip() for l in lines[span[0] - 1:span[1]])
    return hashlib.sha256(chunk.encode("utf-8")).hexdigest()


def _ledger_path(config) -> str:
    return os.path.join(config.repo_root, config.frozen_ledger_rel)


def load_ledger(config) -> Optional[Dict[str, str]]:
    try:
        with open(_ledger_path(config), encoding="utf-8") as fh:
            data = json.load(fh)
        return dict(data.get("hashes", {}))
    except (OSError, ValueError):
        return None


def bless(config) -> Dict[str, str]:
    """Recompute every target hash and write the ledger."""
    hashes: Dict[str, str] = {}
    for tid, relpath, name, kind in config.frozen_targets:
        h = hash_target(config.repo_root, relpath, name, kind)
        if h is not None:
            hashes[tid] = h
    with open(_ledger_path(config), "w", encoding="utf-8") as fh:
        json.dump({"version": 1, "hashes": hashes}, fh, indent=2,
                  sort_keys=True)
        fh.write("\n")
    return hashes


def check(index: RepoIndex, config) -> List[Finding]:
    findings: List[Finding] = []
    if not config.frozen_targets:
        return findings
    ledger = load_ledger(config)
    ledger_rel = config.frozen_ledger_rel
    if ledger is None:
        findings.append(Finding(
            rule=RULE, file=ledger_rel, line=1,
            message="frozen-reference ledger missing — run "
                    "'python scripts/jaxlint.py --bless-frozen' to create "
                    "it"))
        return findings
    for tid, relpath, name, kind in config.frozen_targets:
        current = hash_target(config.repo_root, relpath, name, kind)
        if current is None:
            findings.append(Finding(
                rule=RULE, file=relpath, line=1,
                message=f"frozen {kind} '{name}' ({tid}) not found — it is "
                        "a blessed parity artifact; restore it or re-bless "
                        "with --bless-frozen"))
            continue
        expected = ledger.get(tid)
        if expected is None:
            findings.append(Finding(
                rule=RULE, file=ledger_rel, line=1,
                message=f"ledger has no hash for '{tid}' — re-bless with "
                        "--bless-frozen"))
        elif current != expected:
            findings.append(Finding(
                rule=RULE, file=relpath, line=1,
                message=f"frozen {kind} '{name}' ({tid}) was edited — "
                        "parity references must not drift silently.  If "
                        "the change is intended, run 'python "
                        "scripts/jaxlint.py --bless-frozen' and explain "
                        "why in the commit message"))
    return findings
