"""Pure-jnp oracle for the layer-aggregation kernel."""
from __future__ import annotations

import jax.numpy as jnp


def layer_agg_ref(updates, masks, weights):
    """updates: [N,L,D]; masks: [N,L]; weights: [N] -> [L,D] float32."""
    wm = weights[:, None].astype(jnp.float32) * masks.astype(jnp.float32)  # [N,L]
    num = jnp.einsum("nl,nld->ld", wm, updates.astype(jnp.float32))
    den = wm.sum(axis=0)[:, None]
    return jnp.where(den > 0, num / jnp.maximum(den, 1e-12), 0.0)
