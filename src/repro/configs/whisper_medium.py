"""Whisper-medium — enc-dec audio backbone; conv/mel frontend is a stub
(precomputed frame embeddings) [arXiv:2212.04356].  num_layers counts the
DECODER stack; the encoder has the same depth."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    head_dim=64,
    attn_bias=True, mlp_bias=True,
    encoder_layers=24, num_audio_frames=1500,
    exit_points=(6, 12, 18, 24),
    source="arXiv:2212.04356",
)
