"""Early-exit decoder-only transformer — the third registered
:class:`~repro.models.family.ModelFamily` (``model_family="transformer"``).

The on-device-LLM variant of the paper's §4.2 dual-selection story: depth
is the submodel axis.  The global model is a stack of ``N_BLOCKS``
pre-norm decoder blocks with one next-token exit head per block; submodel
m = embedding stem + blocks[:m+1] + exit heads <= m, exactly the DR-FL
depth-prefix contract, so the whole FL stack (bucketed-vmap executor,
stacked Pallas aggregation, Eq. 5/7 cost model, sync/async engine,
checkpoint/resume, energy scenarios) runs it through the generic
:class:`~repro.models.family.LayerwiseFamily` machinery.

Kernel routing — the block's normalisation and attention go through the
repo's Pallas ops/ref parity contract (``repro.kernels.rmsnorm``,
``repro.kernels.flash_attention``):

* on TPU the compiled Pallas kernels run on the traced path;
* elsewhere the pure-jnp oracles (``rmsnorm_ref`` / ``attention_ref``)
  run DIRECTLY — identical math to the kernels (that is the parity
  contract ``tests/test_kernels.py`` enforces in interpret mode), without
  paying the Pallas interpreter in the hot path;
* tests force either side via :func:`kernel_mode` and assert the two
  forwards agree (interpret-mode Pallas vs ref on CPU).

No-retrace heterogeneous depth: unlike the cnn/mlp step (one jitted
program per static ``model_idx``), this family's DR-FL step is a SINGLE
jitted program taking a *traced* ``model_idx``.  The forward always runs
full depth; a per-exit weight vector (1.0 at the held depth, 0.3 for
shallower exits, exactly 0.0 deeper — the same BranchyNet weighting and
normalisation as ``LayerwiseFamily._drfl_loss``) masks the joint CE, so
gradients past the held prefix are exactly zero and the returned delta is
zero-filled for layer-aligned aggregation, while every submodel reuses
one compiled program (``tests/test_family_contract.py`` pins the
single-compilation property).

Data: :meth:`TransformerFamily.make_dataset` serves the synthetic
next-token corpus (:func:`repro.data.synthetic.synthetic_token_dataset`),
framing next-token prediction as classification over ``num_classes``
(= vocab) so ``run_simulation`` works offline with the stack's CE loss,
per-exit accuracy evaluation and label-Dirichlet sharding unchanged;
``cfg.hw`` doubles as the sequence length.
"""
from __future__ import annotations

import contextlib
import math
from typing import List

import jax
import jax.numpy as jnp

from repro.models.family import (LayerwiseFamily, cross_entropy,
                                 register_family)
from repro.models.layers import (apply_rope, dense_apply, dense_bias_init,
                                 dense_init, embed_init, gelu_mlp_apply,
                                 gelu_mlp_init, rmsnorm_init)

N_BLOCKS = 4              # one exit head per block = 4 submodels (paper M)
BASE_WIDTH = 128          # d_model at width_mult=1.0
N_HEADS = 4
MLP_RATIO = 4             # hidden = MLP_RATIO * d
ROPE_THETA = 10000.0


def _width(width_mult: float) -> int:
    """d_model: multiple of 2*N_HEADS so every head splits evenly for
    RoPE's half-dim rotation."""
    step = 2 * N_HEADS
    d = max(32, int(BASE_WIDTH * width_mult))
    return ((d + step - 1) // step) * step


# ---------------------------------------------------------------------------
# kernel dispatch (Pallas ops on TPU, identical-math oracles elsewhere)
# ---------------------------------------------------------------------------

_KERNEL_MODE = None       # None = auto; "pallas" | "ref" force one side


@contextlib.contextmanager
def kernel_mode(mode):
    """Force the block's kernel dispatch while tracing: ``"pallas"`` runs
    the Pallas ops (interpret mode off-TPU), ``"ref"`` the pure-jnp
    oracles.  Test-only: the choice is baked in at TRACE time, so only
    fresh traces (eager calls / new jits) see the override — the family's
    cached step/eval programs keep whatever the engine traced with."""
    global _KERNEL_MODE
    if mode not in ("pallas", "ref"):
        raise ValueError(f"kernel_mode must be 'pallas' or 'ref', "
                         f"got {mode!r}")
    prev = _KERNEL_MODE
    _KERNEL_MODE = mode
    try:
        yield
    finally:
        _KERNEL_MODE = prev


def _use_pallas() -> bool:
    if _KERNEL_MODE is not None:
        return _KERNEL_MODE == "pallas"
    return jax.default_backend() == "tpu"


def _largest_pow2_leq(n: int, cap: int) -> int:
    b = 1
    while b * 2 <= min(n, cap):
        b *= 2
    return b


def _rmsnorm(p, h):
    """rmsnorm over the trailing dim: Pallas op on TPU, oracle elsewhere."""
    if _use_pallas():
        from repro.kernels.rmsnorm import rmsnorm_op
        return rmsnorm_op(h, p["scale"])
    from repro.kernels.rmsnorm import rmsnorm_ref
    return rmsnorm_ref(h.reshape(-1, h.shape[-1]),
                       p["scale"]).reshape(h.shape)


def _attend(q, k, v):
    """Causal self-attention, model layout [B, S, H, D]."""
    if _use_pallas():
        from repro.kernels.flash_attention import flash_attention
        blk = _largest_pow2_leq(q.shape[1], 128)
        return flash_attention(q, k, v, causal=True, block_q=blk,
                               block_k=blk)
    from repro.kernels.flash_attention import attention_ref
    B, S, H, D = q.shape
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kb = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vb = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    o = attention_ref(qb, kb, vb, causal=True)
    return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# model (canonical {"stem", "stages", "exits"} layer-wise tree)
# ---------------------------------------------------------------------------


def init(key, num_classes: int = 10, width_mult: float = 1.0, hw: int = 32):
    """Canonical layer-wise tree: stem (token embedding over a
    ``num_classes``-sized vocab), N_BLOCKS pre-norm decoder blocks as
    stages, one rmsnorm + linear next-token head per stage.  ``hw`` (the
    sequence length) is positional-encoding-free at init — positions are
    rotary, applied at trace time."""
    d = _width(width_mult)
    f = MLP_RATIO * d
    ks = jax.random.split(key, 1 + 2 * N_BLOCKS)
    it = iter(ks)
    params = {
        "stem": {"embed": embed_init(next(it), num_classes, d, jnp.float32)},
        "stages": [],
        "exits": [],
    }
    for _ in range(N_BLOCKS):
        bk = jax.random.split(next(it), 5)
        params["stages"].append({
            "attn_norm": rmsnorm_init(d, jnp.float32),
            "attn": {
                "wq": dense_init(bk[0], d, d, jnp.float32),
                "wk": dense_init(bk[1], d, d, jnp.float32),
                "wv": dense_init(bk[2], d, d, jnp.float32),
                "wo": dense_init(bk[3], d, d, jnp.float32,
                                 scale=1.0 / math.sqrt(d)),
            },
            "mlp_norm": rmsnorm_init(d, jnp.float32),
            "mlp": gelu_mlp_init(bk[4], d, f, jnp.float32),
        })
        params["exits"].append({
            "norm": rmsnorm_init(d, jnp.float32),
            "head": dense_bias_init(next(it), d, num_classes, jnp.float32,
                                    scale=1.0 / math.sqrt(d)),
        })
    return params


def num_submodels() -> int:
    return N_BLOCKS


def _attention(bp, h):
    B, S, d = h.shape
    hd = d // N_HEADS
    q = dense_apply(bp["wq"], h).reshape(B, S, N_HEADS, hd)
    k = dense_apply(bp["wk"], h).reshape(B, S, N_HEADS, hd)
    v = dense_apply(bp["wv"], h).reshape(B, S, N_HEADS, hd)
    pos = jnp.arange(S)
    q = apply_rope(q, pos, ROPE_THETA)
    k = apply_rope(k, pos, ROPE_THETA)
    o = _attend(q, k, v)
    return dense_apply(bp["wo"], o.reshape(B, S, d))


def _block(bp, h):
    h = h + _attention(bp["attn"], _rmsnorm(bp["attn_norm"], h))
    return h + gelu_mlp_apply(bp["mlp"], _rmsnorm(bp["mlp_norm"], h))


def _exit_head(ep, h):
    """Next-token logits at the LAST position (the window's label slot)."""
    return dense_apply(ep["head"], _rmsnorm(ep["norm"], h[:, -1, :]))


def apply(params, x, model_idx: int):
    """x: [B, S] int32 tokens -> logits at exit ``model_idx``."""
    h = jnp.take(params["stem"]["embed"]["emb"], x, axis=0)
    for si in range(model_idx + 1):
        h = _block(params["stages"][si], h)
    return _exit_head(params["exits"][model_idx], h)


def apply_all_exits(params, x) -> List[jnp.ndarray]:
    """Logits from every exit held by ``params`` (truncated trees ok)."""
    h = jnp.take(params["stem"]["embed"]["emb"], x, axis=0)
    outs = []
    for si in range(len(params["stages"])):
        h = _block(params["stages"][si], h)
        outs.append(_exit_head(params["exits"][si], h))
    return outs


def flops_per_sample(model_idx: int, image_hw: int = 32,
                     width_mult: float = 1.0, num_classes: int = 10) -> float:
    """Analytic forward FLOPs for Model_{idx+1}; ``image_hw`` is the
    sequence length (the FL stack's one spatial knob)."""
    d = _width(width_mult)
    f = MLP_RATIO * d
    S = image_hw
    per_block = (4 * 2.0 * S * d * d        # q/k/v/o projections
                 + 2 * 2.0 * S * S * d      # scores + weighted values
                 + 2.0 * S * (d * f + f * d))  # GELU MLP in + out
    return (model_idx + 1) * per_block + 2.0 * d * num_classes


# ---------------------------------------------------------------------------
# the family
# ---------------------------------------------------------------------------


class TransformerFamily(LayerwiseFamily):
    """Early-exit decoder as a pluggable family
    (``model_family="transformer"``).

    DR-FL (depth-prefix) only, like the MLP: width-slicing attention heads
    is a different baseline design, so
    :class:`repro.fl.spec.SimulationSpec` rejects HeteroFL/ScaleFL with
    this family up front."""

    name = "transformer"
    supported_methods = ("drfl",)
    ref_hw = 32          # paper-scale sequence length (cost calibration)

    def init(self, key, num_classes: int = 10, width_mult: float = 1.0,
             hw: int = 32):
        return init(key, num_classes, width_mult=width_mult, hw=hw)

    def num_submodels(self) -> int:
        return num_submodels()

    def apply_all_exits(self, params, x):
        return apply_all_exits(params, x)

    def flops_per_sample(self, model_idx: int, image_hw: int = 32,
                         width_mult: float = 1.0) -> float:
        return flops_per_sample(model_idx, image_hw, width_mult)

    def make_dataset(self, n: int, num_classes: int = 10, hw: int = 32,
                     noise: float = 1.0, seed: int = 0):
        from repro.data.synthetic import synthetic_token_dataset
        return synthetic_token_dataset(n, num_classes, seq_len=hw,
                                       noise=noise, seed=seed)

    # -- no-retrace heterogeneous depth -----------------------------------
    def _masked_drfl_loss(self, params, x, y, model_idx):
        """Full-depth forward, per-exit weights from the TRACED held depth:
        1.0 at ``model_idx``, 0.3 shallower, exactly 0.0 deeper — the same
        joint-CE weighting/normalisation as ``_drfl_loss`` on a truncated
        tree, but with zero-weight (hence exactly-zero-gradient) deep
        exits instead of absent ones."""
        outs = self.apply_all_exits(params, x)
        ces = jnp.stack([cross_entropy(o, y) for o in outs])
        idx = jnp.arange(len(outs))
        w = jnp.where(idx == model_idx, 1.0,
                      jnp.where(idx < model_idx, 0.3, 0.0))
        return jnp.sum(w * ces) / (1.0 + 0.3 * model_idx)

    def _step_fn(self, method: str):
        if method != "drfl":
            return super()._step_fn(method)
        key = ("step", method)
        fn = self._jit_cache.get(key)
        if fn is not None:
            return fn

        # jaxlint: allow(retrace-hazard) -- memoised in self._jit_cache keyed by (step, method); model_idx is TRACED so all submodels share one compilation
        @jax.jit
        def fn(params, x, y, model_idx, lr: float = 0.05):
            loss, grads = jax.value_and_grad(
                lambda p: self._masked_drfl_loss(p, x, y, model_idx))(params)
            new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
            return new, loss

        self._jit_cache[key] = fn
        return fn


register_family(TransformerFamily())
