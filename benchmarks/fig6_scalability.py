"""Paper Fig. 6: learning curves / final accuracy for different fleet sizes
(RQ3 scalability).  Directional claim: DR-FL's advantage does not degrade —
and typically grows — with more heterogeneous devices.

Fleet sizes are overridable for large-scale runs (the vectorized FleetState
engine handles 256+ devices):

    REPRO_FIG6_SIZES=64,256 python -m benchmarks.fig6_scalability
    python -m benchmarks.fig6_scalability 64 256

At 64+ devices the runs use the event-driven async engine (no round
barrier, staleness-aware aggregation) and the training-set size scales
with the fleet so per-device data stays roughly constant — a fixed FAST
n_train starves 256-device Dirichlet splits.

At 1024+ devices the MARL selector runs with the FACTORED QMIX state
(``FLConfig.state_mode="auto"`` resolves to the fixed-width fleet summary
above 256 agents — the flat ``n * OBS_DIM`` state used to OOM-scale the
mixer and replay buffer here) and the row runs a bounded smoke profile:
capped training set, one pre-training episode, a small async task budget
(env-tunable via REPRO_FIG6_MAX_TRAIN / REPRO_FIG6_EPISODES /
REPRO_FIG6_BUDGET).  Those rows validate the factored selector and the
data-parallel fleet kernels at scale; the DIRECTIONAL accuracy claim is
carried by the <= 256-device rows.
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks.common import FAST, bench_params, emit, family_supports
from repro.fl import FLConfig, run_simulation

SIZES = (8, 14) if FAST else (10, 20, 40)


def _env_sizes():
    raw = os.environ.get("REPRO_FIG6_SIZES", "")
    if not raw:
        return None
    try:
        return tuple(int(s) for s in raw.replace(",", " ").split())
    except ValueError as e:
        raise SystemExit(
            f"REPRO_FIG6_SIZES must be comma/space-separated ints, "
            f"got {raw!r}") from e


def main(seed=0, verbose=False, sizes=None):
    sizes = tuple(sizes) if sizes else (_env_sizes() or SIZES)
    p = bench_params()
    results = {}
    for n in sizes:
        for method, sel in (("drfl", "marl"), ("heterofl", "greedy")):
            if not family_supports(p, method):
                emit(f"fig6/{method}/n{n}", 0.0,
                     f"skipped=unsupported_by_{p['model_family']}")
                continue
            t0 = time.time()
            # at large fleets keep the paper's 10% participation so k (and
            # the per-round training cost) stays proportionate
            overrides = {"n_devices": n}
            # data budget scales with the fleet: a fixed n_train starves
            # 256-device Dirichlet splits (most devices get ~0 samples and
            # the directional gap disappears); hold per-device data roughly
            # constant relative to the base config instead
            overrides["n_train"] = max(
                p["n_train"], int(round(p["n_train"] * n / p["n_devices"])))
            if n >= 64:
                overrides["participation"] = min(p.get("participation", 0.1),
                                                 0.1)
                # scalability runs use the event-driven engine: no round
                # barrier, staleness-aware aggregation (ISSUE 2 default);
                # reward evals once per ~k aggregations, not per arrival —
                # per-event evals would dominate wall-clock at 256 devices
                overrides["engine_mode"] = "async"
                overrides["async_eval_every"] = max(1, int(round(0.1 * n)))
            episodes = 3
            if n >= 1024:
                # bounded smoke profile (see module docstring): the factored
                # selector + data-parallel kernels at fleet scale, not the
                # directional accuracy claim
                overrides["participation"] = min(
                    overrides.get("participation", 0.1), 0.02)
                k = max(1, int(round(overrides["participation"] * n)))
                overrides["n_train"] = min(
                    overrides["n_train"],
                    int(os.environ.get("REPRO_FIG6_MAX_TRAIN", 60000)))
                overrides["async_task_budget"] = int(
                    os.environ.get("REPRO_FIG6_BUDGET", 2 * k))
                overrides["async_eval_every"] = k
                # thousands of per-client jits would compile one program per
                # distinct Dirichlet shard size; the bucketed executor's
                # pow2-padded programs are the only sane path at this scale
                overrides["client_executor"] = "batched"
                episodes = int(os.environ.get("REPRO_FIG6_EPISODES", 1))
            cfg = FLConfig(**{**p, **overrides}, method=method,
                           selector=sel, seed=seed, marl_episodes=episodes)
            h = run_simulation(cfg, verbose=verbose)
            acc = float(np.mean(h["best_acc"]))
            results[(n, method)] = acc
            emit(f"fig6/{method}/n{n}", (time.time() - t0) * 1e6,
                 f"best_acc_mean={acc:.3f}")
    for n in sizes:
        if (n, "drfl") in results and (n, "heterofl") in results:
            emit(f"fig6/gap/n{n}", 0.0,
                 f"drfl_minus_heterofl="
                 f"{results[(n, 'drfl')] - results[(n, 'heterofl')]:.3f}")
    return results


if __name__ == "__main__":
    cli_sizes = tuple(int(a) for a in sys.argv[1:]) or None
    main(verbose=True, sizes=cli_sizes)
