"""Model-substrate correctness: decode==prefill, masks, chunkwise==recurrent."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.core.layerwise import layer_mask
from repro.models import build, extra_inputs

DECODE_TOL = 2e-4


def _mk(arch, **over):
    cfg = get_smoke_config(arch)
    if cfg.num_experts:
        over.setdefault("moe_capacity_factor", 100.0)  # no drops: exact match
    return dataclasses.replace(cfg, **over) if over else cfg


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_prefill(arch):
    cfg = _mk(arch)
    m = build(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    B, S = 2, 12
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extras = {k: jax.random.normal(key, shp).astype(dt)
              for k, (shp, dt) in extra_inputs(cfg, B, S).items()}
    hidden, _ = m.apply(params, tokens, extras, remat="none")
    ref = m.logits(params, hidden)
    cache = m.decode_init(params, B, S, extras=extras)
    outs = []
    for t in range(S):
        lg, cache = m.decode_step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               atol=DECODE_TOL, rtol=1e-3)


@pytest.mark.parametrize("arch", ["yi-34b", "xlstm-1.3b", "zamba2-1.2b",
                                  "mixtral-8x22b", "whisper-medium"])
def test_layer_mask_prefix_identity(arch):
    """Masked-out layers must be exact identities: full mask == default, and
    a zero mask reduces the stack to embed+final norm."""
    cfg = _mk(arch)
    m = build(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    B, S = 2, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extras = {k: jax.random.normal(key, shp).astype(dt)
              for k, (shp, dt) in extra_inputs(cfg, B, S).items()}
    h_full, _ = m.apply(params, tokens, extras, remat="none")
    ones = jnp.ones((cfg.num_layers,), jnp.float32)
    h_mask, _ = m.apply(params, tokens, extras, layer_mask=ones, remat="none")
    np.testing.assert_allclose(np.asarray(h_full, np.float32),
                               np.asarray(h_mask, np.float32), atol=1e-5)
    # prefix mask changes the output (layers do something)
    half = layer_mask(dataclasses.replace(cfg, exit_points=(1, 2)), 0)
    h_half, _ = m.apply(params, tokens, extras, layer_mask=half, remat="none")
    assert not np.allclose(np.asarray(h_half, np.float32),
                           np.asarray(h_full, np.float32), atol=1e-5)


def test_mlstm_chunkwise_matches_recurrent():
    from repro.models.xlstm import (_mlstm_chunk_scan, mlstm_step)
    key = jax.random.PRNGKey(0)
    B, H, S, P = 2, 3, 32, 16
    ks = jax.random.split(key, 5)
    q, k, v = (jax.random.normal(ks[i], (B, H, S, P)) for i in range(3))
    log_i = jax.random.normal(ks[3], (B, H, S))
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, S)) + 2.0)
    y_chunk, (C, n, m) = _mlstm_chunk_scan(q, k, v, log_i, log_f, chunk=8)
    st = (jnp.zeros((B, H, P, P)), jnp.zeros((B, H, P)),
          jnp.full((B, H), -1e30))
    ys = []
    for t in range(S):
        y, st = mlstm_step(q[:, :, t], k[:, :, t], v[:, :, t],
                           log_i[:, :, t], log_f[:, :, t], st)
        ys.append(y)
    y_rec = jnp.stack(ys, axis=2)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(C), np.asarray(st[0]),
                               atol=1e-4, rtol=1e-3)


def test_ssd_chunk_sizes_agree():
    """Mamba2 SSD: result independent of chunk length (algorithm identity)."""
    from repro.models.ssm import _ssd_chunk_scan
    key = jax.random.PRNGKey(0)
    B, S, H, P, N = 2, 32, 4, 8, 8
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    Bm = jax.random.normal(ks[1], (B, S, N))
    Cm = jax.random.normal(ks[2], (B, S, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    log_a = -dt * 0.5
    D = jnp.ones((H,))
    y1, s1 = _ssd_chunk_scan(xh, Bm, Cm, dt, log_a, D, 4)
    y2, s2 = _ssd_chunk_scan(xh, Bm, Cm, dt, log_a, D, 16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4,
                               rtol=1e-3)


def test_swa_ring_cache_wraps():
    """Sliding-window decode past the cache length must keep matching the
    windowed teacher-forced forward."""
    cfg = _mk("mixtral-8x22b", window=8)
    m = build(cfg)
    key = jax.random.PRNGKey(2)
    params = m.init(key)
    B, S = 1, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    hidden, _ = m.apply(params, tokens, remat="none")
    ref = m.logits(params, hidden)
    cache = m.decode_init(params, B, S)   # cache length = window = 8 < S
    outs = []
    for t in range(S):
        lg, cache = m.decode_step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               atol=2e-4, rtol=1e-3)


def test_pallas_attention_path_matches_xla():
    """use_pallas=True (interpret on CPU) must match the XLA attention path."""
    cfg = _mk("yi-34b")
    m = build(cfg)
    key = jax.random.PRNGKey(3)
    params = m.init(key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    h_xla, _ = m.apply(params, tokens, remat="none", use_pallas=False)
    h_pal, _ = m.apply(params, tokens, remat="none", use_pallas=True)
    np.testing.assert_allclose(np.asarray(h_pal, np.float32),
                               np.asarray(h_xla, np.float32),
                               atol=2e-4, rtol=1e-3)


def test_chunked_attention_matches_naive():
    from repro.models.layers import gqa_attend, gqa_attend_chunked
    key = jax.random.PRNGKey(0)
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    for causal, window in ((True, 0), (True, 24), (False, 0)):
        ref = gqa_attend(q, k, v, causal=causal, window=window)
        out = gqa_attend_chunked(q, k, v, causal=causal, window=window, chunk=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-4)
