"""Event-driven FL round engine: sync (barrier) and async (timeline) modes.

The paper's §4.2 workflow assumes devices come and go on their own clocks,
but a synchronous round loop is a barrier: every round waits ``max(t_cost)``
over its participants, so one slow straggler sets the fleet's wall-clock —
the "wooden barrel effect" DR-FL is supposed to beat.  This module replaces
the monolithic loop with a scheduler over *events* on a simulated timeline:

* ``mode="sync"``  — one DISPATCH + one barrier COMPLETION per round; a
  verbatim port of the legacy loop, bit-for-bit identical to the frozen
  reference (:func:`repro.fl.simulation._run_once_reference`, enforced by
  ``tests/test_engine.py``).
* ``mode="async"`` — dispatch (selection + energy charge at send time) and
  completion (delta arrival + staleness-aware aggregation at finish time)
  are separate events on a heap keyed by per-device virtual clocks
  (``FleetState.busy_until``).  The server keeps ~k tasks in flight: each
  completion aggregates immediately (FedAsync-style, down-weighted by
  :func:`repro.fl.server.staleness_scale`) and back-fills the freed slot,
  so no device ever waits at a barrier.  Hot-plug joins, dropouts, and
  battery depletion are timeline events, not round-boundary hacks.

Async bookkeeping groups completions into *virtual rounds* of k tasks so
histories stay row-comparable with sync runs; rewards are credited at
EVENT time (energy at dispatch, duration and accuracy-delta at arrival)
and committed to the selector in dispatch order, which keeps the MARL
episode trace (obs/action/reward) aligned.

Fairness accounting reported in the history (``benchmarks/async_bench.py``):

* ``idle_time`` — straggler wait: how long each finished client update sat
  before entering the global model.  Sync pays ``t_round - t_cost_i`` per
  surviving participant (the barrier); async aggregates at the completion
  event, so the wait is zero by construction (computed, not assumed, so
  the metric stays honest if scheduling ever batches arrivals).
* ``wait_for_work`` (async only) — time between a device completing a task
  and its NEXT dispatch; spare capacity, the analogue of sync devices
  sitting out a round, reported for scheduling diagnostics.

Public surface (one-line contracts):

* :class:`RoundEngine` — runs one FL episode under ``cfg.engine_mode``;
  ``run()`` returns the history dict (selector/buffer owned by caller).
* :class:`World` — per-episode immutable setup bundle (data shards,
  fleet, global model, family, paper-scale cost calibration).
* :func:`build_world` — build a :class:`World` from a config; shards the
  fleet over the ``"fleet"`` mesh when ``cfg.fleet_mesh`` asks for it.
* :func:`resolve_client_executor` — map ``cfg.client_executor`` ("auto" /
  "perclient" / "batched") to the concrete executor for this backend.
* :func:`sync_task_budget` — total client tasks a sync run dispatches at
  most (the async engine's default work budget).
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.engine import (CheckpointHalt, EngineCheckpointer,
                                     config_fingerprint)
from repro.checkpoint.io import FLEET_CHECKPOINT_FIELDS
from repro.core.fleet import (FleetState, fleet_charge_jit, fleet_connect,
                              fleet_cost_matrix_jit, fleet_disconnect,
                              fleet_is_jax, fleet_kill, fleet_set_alive,
                              fleet_set_busy, fleet_total_remaining,
                              make_fleet_state)
from repro.core.selection import MarlSelector
from repro.data.partition import dirichlet_partition
from repro.energy import EnergyScenario, scenario_from_config
from repro.fl import batch as fl_batch
from repro.fl import client as fl_client
from repro.fl import server as fl_server
from repro.fl.faults import FaultPlan, poison_payload
from repro.models.family import ModelFamily, get_family


class _RestoredBucket(NamedTuple):
    """Stand-in for a BucketResult restored from a checkpoint: the task's
    own row was sliced to ``[1, ...]`` at save time, so row index 0 of this
    bucket reproduces the original ``bucket.stacked_delta[row:row+1]``
    slice bit-for-bit."""
    stacked_delta: Any


# ---------------------------------------------------------------------------
# shared episode setup (data shards, fleet, global model, cost calibration)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class World:
    """Everything one simulation episode needs, built once per episode."""
    x_tr: np.ndarray
    y_tr: np.ndarray
    x_val: np.ndarray
    y_val: np.ndarray
    parts: List[np.ndarray]
    fleet: FleetState
    global_params: Any
    n_models: int
    sizes: tuple
    fractions: tuple
    n_total: int
    family: ModelFamily = None
    scenario: EnergyScenario = None


def _validate_energy_feasibility(cfg, fleet, sizes, fractions) -> None:
    """Fail fast on budgets no fresh device can survive.

    ``fleet_charge`` uses a strict ``remaining > need`` survival check: a
    device whose FULL battery (``battery * energy_scale``) cannot cover even
    its cheapest submodel dies the first time any selector picks it — at
    small scales that silently wipes the whole fleet in round 0.  Surface
    the misconfiguration at build time instead, naming the offending
    devices and their cheapest submodel."""
    from repro.core.fleet import fleet_cost_matrix
    _, _, e_tra, e_com = fleet_cost_matrix(fleet, sizes, fractions,
                                           cfg.local_epochs, cfg.batch_size)
    # jaxlint: allow(host-sync-in-hot-path) -- one-time build_world validation pull, before any round runs
    need, battery = jax.device_get((e_tra + e_com, fleet.battery))
    need = np.asarray(need, np.float64)
    fresh = np.asarray(battery, np.float64) * float(cfg.energy_scale)
    min_need = need.min(axis=1)
    bad = np.flatnonzero(min_need >= fresh)
    if bad.size:
        cheapest = need.argmin(axis=1)
        detail = "; ".join(
            f"device {int(i)}: cheapest submodel {int(cheapest[i])} needs "
            f"{min_need[i]:.1f}J >= fresh battery {fresh[i]:.1f}J"
            for i in bad[:5])
        more = f" (+{bad.size - 5} more)" if bad.size > 5 else ""
        raise ValueError(
            f"energy.scale={cfg.energy_scale} leaves {bad.size}/{len(fresh)}"
            " device(s) unable to afford even their cheapest submodel on a "
            "FULL battery — they die the first round any selector picks "
            f"them (fleet_charge survival is strict '>'): {detail}{more}. "
            "Raise energy.scale, or lower local_epochs/model cost.")


def build_world(cfg) -> World:
    """Exact port of the legacy ``_run_once`` setup (shared by the engine
    and the frozen reference loop, so parity starts from identical state)."""
    key = jax.random.PRNGKey(cfg.seed)
    family = get_family(getattr(cfg, "model_family", None))
    # family-routed corpus: image families keep the exact legacy
    # synthetic_image_dataset call (bit-for-bit), token families serve
    # [n, seq] context windows through the same (x, y) row contract
    x, y = family.make_dataset(cfg.n_train, cfg.num_classes, hw=cfg.hw,
                               noise=cfg.noise, seed=cfg.seed)
    n_val = max(64, int(cfg.n_val_fraction * cfg.n_train))
    x_val, y_val = x[:n_val], y[:n_val]          # server-side validation set
    x_tr, y_tr = x[n_val:], y[n_val:]
    parts = dirichlet_partition(y_tr, cfg.n_devices + cfg.hotplug_n,
                                cfg.alpha, cfg.seed)

    n_total = cfg.n_devices + cfg.hotplug_n
    fleet = make_fleet_state(n_total, cfg.seed,
                             data_sizes=[len(p) for p in parts],
                             backend="jax")
    fleet = fleet.replace(remaining=fleet.battery * cfg.energy_scale)
    scenario = scenario_from_config(cfg)
    if not scenario.is_trivial:
        # profile parameter arrays (harvest amplitude, timezone phase) are
        # drawn for the FULL fleet — hotplug joiners included — from a
        # dedicated RNG stream, so the default scenario keeps the fleet
        # bit-for-bit untouched
        fleet = scenario.init_fleet(fleet, cfg.seed)
    if cfg.hotplug_n:                   # hot-plug devices: not yet connected
        fleet = fleet_disconnect(fleet, cfg.n_devices)
    if getattr(cfg, "fleet_mesh", 0) not in (0, 1):
        # opt-in data-parallel placement: [n] arrays row-sharded over the
        # "fleet" mesh so the per-round kernels run SPMD (no-op when the
        # runtime has a single device)
        from repro.sharding.fleet import maybe_shard_fleet
        fleet = maybe_shard_fleet(fleet, cfg.fleet_mesh)
    global_params = family.init(key, cfg.num_classes,
                                width_mult=cfg.width_mult, hw=cfg.hw)
    M = family.num_submodels()
    # Energy/time accounting (Eq. 5 & 7) is calibrated to the PAPER-scale
    # backbone (full-width model on 32x32): the slim model is only the
    # CPU-budget compute proxy; batteries must see paper-scale costs for the
    # wooden-barrel dynamics to reproduce.
    sizes, fractions = family.cost_model(cfg.num_classes)
    _validate_energy_feasibility(cfg, fleet, sizes, fractions)
    return World(x_tr=x_tr, y_tr=y_tr, x_val=x_val, y_val=y_val, parts=parts,
                 fleet=fleet, global_params=global_params, n_models=M,
                 sizes=sizes, fractions=fractions, n_total=n_total,
                 family=family, scenario=scenario)


def _check_selection(sel, n_total: int) -> None:
    """The engine indexes ``model_choice`` by raw device id — a selector
    returning fewer entries than the fleet silently mis-indexes."""
    if len(sel.model_choice) != n_total:
        raise ValueError(
            f"selector returned {len(sel.model_choice)} model choices "
            f"for a fleet of {n_total}")


def _client_update(cfg, family, global_params, m, xi, yi, seed):
    return family.client_update(cfg.method, global_params, m, xi, yi,
                                epochs=cfg.local_epochs, batch=cfg.batch_size,
                                lr=cfg.lr, seed=seed)


# Above this per-step work, XLA CPU executes the per-client convs at
# BLAS-bound speed and batching them (vmapped GEMMs) cannot win — measured
# crossover between 1.8e7 (batched 2x faster) and 5.6e8 (batched 0.7x)
# FLOPs per training step on 2-core CPU; see benchmarks/client_bench.py.
_CPU_BATCHED_STEP_FLOPS = 5e7


def resolve_client_executor(cfg) -> str:
    """``cfg.client_executor``: "perclient" | "batched" | "auto".

    "auto" picks the bucketed-vmap executor (repro.fl.batch, <= 1 jit
    dispatch per submodel bucket per round) at 64+ device fleets — where
    per-participant dispatch dominates wall time — and the per-client path
    below that, which keeps small-fleet sync runs bit-for-bit equal to the
    frozen reference loop (vmap/scan fusion reorders float reductions at
    the ULP level, so the batched path is allclose, not bit-exact).  On
    CPU, large per-step models stay per-client: execution there is
    FLOP-bound, so bucketing only wins while per-op overhead dominates
    (small widths/images — exactly the CPU-budget large-fleet configs)."""
    mode = getattr(cfg, "client_executor", "auto")
    if mode == "auto":
        if cfg.n_devices < 64:
            return "perclient"
        if jax.default_backend() == "cpu":
            family = get_family(getattr(cfg, "model_family", None))
            step_flops = (family.flops_per_sample(
                family.num_submodels() - 1, cfg.hw, cfg.width_mult)
                * cfg.batch_size)
            return ("batched" if step_flops <= _CPU_BATCHED_STEP_FLOPS
                    else "perclient")
        return "batched"
    if mode in ("perclient", "batched"):
        return mode
    raise ValueError(f"unknown client_executor {mode!r} "
                     "(expected 'auto', 'perclient' or 'batched')")


def _run_batched_cohort(cfg, world, global_params, device_ids, model_idxs,
                        seeds, x_dev, y_dev) -> fl_batch.CohortResult:
    """One bucketed-vmap executor pass for ``device_ids`` (all must have
    local data).  Weights default to shard sizes inside run_cohort."""
    return fl_batch.run_cohort(
        cfg.method, global_params, x_dev, y_dev,
        [world.parts[i] for i in device_ids], device_ids, model_idxs, seeds,
        epochs=cfg.local_epochs, batch=cfg.batch_size, lr=cfg.lr,
        family=world.family)


def sync_task_budget(cfg) -> int:
    """Total client-task budget a sync run of ``cfg`` dispatches at most
    (sum over rounds of the connected-fleet Top-K k) — the async engine's
    default work budget, so both modes do the same amount of training."""
    k_pre = max(1, int(round(cfg.participation * cfg.n_devices)))
    if not cfg.hotplug_n:
        return cfg.n_rounds * k_pre
    hr = min(max(int(cfg.hotplug_round), 0), cfg.n_rounds)
    k_post = max(1, int(round(
        cfg.participation * (cfg.n_devices + cfg.hotplug_n))))
    return hr * k_pre + (cfg.n_rounds - hr) * k_post


def _marl_train(marl, buffer, hist, fleet, round_idx, n_updates):
    """Flush the episode trace into replay, run QMIX updates, and record
    effective-replay telemetry under ``hist["qmix"]`` (the resolved buffer
    capacity — possibly degraded by ``_make_buffer``'s obs budget — plus
    mixer mode, stored-agent width, update count and per-update TD loss),
    so fig5/table1 runs can report the replay the learner actually saw.

    Call order (episode_arrays → add_episode → sample/update loop) is
    byte-identical to the legacy inline blocks — the buffer RNG consumes
    the same draws, keeping sync parity with the frozen reference."""
    obs, state, actions, rewards = marl.episode_arrays(fleet, round_idx)
    buffer.add_episode(obs, state, actions, rewards)
    losses = []
    for _ in range(n_updates):
        batch = buffer.sample(marl.learner.cfg.batch_size)
        if batch:
            losses.append(marl.learner.update(batch)["td_loss"])
    q = hist.setdefault("qmix", {
        "mixer_mode": marl.mixer_mode,
        "replay_capacity": buffer.capacity,
        "replay_episode_len": buffer.T,
        "replay_agents": buffer.N,
        "replay_episodes": 0,
        "updates": 0,
        "td_loss": [],
    })
    q["replay_episodes"] = len(buffer)
    q["updates"] = marl.learner.updates
    q["td_loss"].extend(losses)


class RoundEngine:
    """Scheduler layer: runs one FL episode under ``cfg.engine_mode``.

    ``selector`` and (for MARL) ``buffer`` are owned by the caller —
    :func:`repro.fl.simulation.run_simulation` persists them across
    pre-training episodes exactly as the legacy loop did.

    Crash safety (opt-in, off by default so clean runs stay bit-for-bit):

    * ``cfg.checkpoint_dir`` + ``cfg.checkpoint_every`` — snapshot the FULL
      run state (fleet arrays, params, history, event heap, selector +
      replay buffer, partitions) every N rounds / virtual rounds via
      :class:`repro.checkpoint.engine.EngineCheckpointer`; pass the decoded
      state back as ``resume_state`` and the run continues byte-identically
      to one that was never interrupted.
    * ``fault_plan`` (or the ``cfg.fault_*`` counts) — seeded churn events
      injected into the async timeline; see :mod:`repro.fl.faults`.
    * ``halt_counter`` — ``{"remaining": N}`` shared dict: raise
      :class:`CheckpointHalt` right after the N-th checkpoint save (the
      test/bench hook that simulates a crash at a known point).
    """

    def __init__(self, cfg, selector, buffer=None, verbose: bool = False, *,
                 fault_plan: Optional[FaultPlan] = None, episode: int = 0,
                 resume_state: Optional[dict] = None,
                 halt_counter: Optional[dict] = None):
        self.cfg = cfg
        self.selector = selector
        self.buffer = buffer
        self.verbose = verbose
        self.mode = getattr(cfg, "engine_mode", "sync")
        self.executor = resolve_client_executor(cfg)
        self.episode = int(episode)
        self.faults = (fault_plan if fault_plan is not None
                       else FaultPlan.from_config(cfg))
        if self.faults is not None and not len(self.faults):
            self.faults = None
        if self.faults is not None and self.mode == "sync":
            raise ValueError("fault injection needs the event timeline: "
                             "set engine_mode='async'")
        self.ckpt = None
        if getattr(cfg, "checkpoint_dir", ""):
            self.ckpt = EngineCheckpointer(
                cfg.checkpoint_dir, keep=int(getattr(cfg, "checkpoint_keep",
                                                     3)))
        self.ckpt_every = int(getattr(cfg, "checkpoint_every", 0))
        self._halt = halt_counter
        self._resume = resume_state
        self._qpend: List[Any] = []   # (info, device validity array) pairs

    def run(self) -> Dict:
        self.world = build_world(self.cfg)
        rs = self._resume
        if rs is not None:
            if rs.get("mode") != self.mode:
                raise ValueError(
                    f"checkpoint was taken in engine_mode={rs.get('mode')!r}"
                    f" but this engine runs {self.mode!r}")
            # partitions/selector/buffer are mode-independent run state
            self.world.parts = [np.asarray(p) for p in rs["parts"]]  # jaxlint: allow(host-sync-in-hot-path) -- restored checkpoint leaves are host numpy
            self.selector.load_state_dict(rs["selector"])
            if rs.get("buffer") is not None:
                if self.buffer is None:
                    raise ValueError("checkpoint carries replay-buffer state"
                                     " but the engine has no buffer")
                self.buffer.load_state_dict(rs["buffer"])
        if self.mode == "sync":
            return self._run_sync()
        if self.mode == "async":
            return self._run_async()
        raise ValueError(f"unknown engine_mode {self.mode!r} "
                         "(expected 'sync' or 'async')")

    # ------------------------------------------------------------------
    # checkpoint plumbing (shared by both modes)
    # ------------------------------------------------------------------

    def _ckpt_meta(self, step: int) -> dict:
        return {"episode": self.episode, "step": int(step),
                "engine_mode": self.mode,
                "fingerprint": config_fingerprint(self.cfg)}

    # jaxlint: allow(host-sync-in-hot-path) -- checkpoint encode runs at
    # save cadence, off the per-event loop; the save IS the barrier
    def _base_snapshot(self, fleet, global_params, hist) -> dict:
        """Mode-independent slice of the run state (fleet arrays keyed by
        the lint-enforced ``FLEET_CHECKPOINT_FIELDS``, so a new FleetState
        array field fails loudly here rather than silently not resuming)."""
        return {
            "mode": self.mode,
            "fleet": {f: getattr(fleet, f)
                      for f in FLEET_CHECKPOINT_FIELDS},
            "global_params": global_params,
            "hist": hist,
            "parts": [np.asarray(p) for p in self.world.parts],
            "selector": self.selector.state_dict(),
            "buffer": (self.buffer.state_dict()
                       if self.buffer is not None else None),
        }

    def _restore_fleet(self, fleet, arrays: dict):
        fleet = fleet.replace(**arrays)
        if getattr(self.cfg, "fleet_mesh", 0) not in (0, 1):
            from repro.sharding.fleet import maybe_shard_fleet
            fleet = maybe_shard_fleet(fleet, self.cfg.fleet_mesh)
        return fleet

    @staticmethod
    # jaxlint: allow(host-sync-in-hot-path) -- task fields are python
    # scalars; runs only at checkpoint save
    def _encode_task(task: dict, params_table: dict) -> dict:
        """Serializable form of an async task.  A batched task's shared
        ``(BucketResult, row)`` reference becomes its own ``[1, ...]`` row
        slice (the exact tree the completion event would have sliced); a
        perclient task's dispatch-time params snapshot is deduped into
        ``params_table`` by model version (tasks from one dispatch tick
        share one snapshot)."""
        enc = {k: v for k, v in task.items()
               if k not in ("delta_row", "params")}
        if "delta_row" in task:
            dr = task["delta_row"]
            enc["has_delta_row"] = True
            enc["delta1"] = (None if dr is None else jax.tree.map(
                lambda a: a[dr[1]:dr[1] + 1], dr[0].stacked_delta))
        elif "params" in task:
            v = int(task["version"])
            params_table[v] = task["params"]
            enc["params_version"] = v
        return enc

    @staticmethod
    # jaxlint: allow(host-sync-in-hot-path) -- restore-only inverse of
    # _encode_task; manifest values are host state
    def _decode_task(enc: dict, params_table: dict) -> dict:
        task = {k: v for k, v in enc.items()
                if k not in ("delta1", "has_delta_row", "params_version")}
        if enc.get("has_delta_row"):
            d1 = enc["delta1"]
            # row 0 of the restored one-row bucket IS the original slice,
            # so the completion-event jit program (and its output bits)
            # match the uninterrupted run
            task["delta_row"] = (None if d1 is None
                                 else (_RestoredBucket(d1), 0))
        elif "params_version" in enc:
            task["params"] = params_table[int(enc["params_version"])]
        return task

    def _after_save(self):
        if self._halt is None:
            return
        self._halt["remaining"] -= 1
        if self._halt["remaining"] <= 0:
            raise CheckpointHalt(
                "simulated crash: halted after checkpoint save")

    def _flush_quarantine(self, hist) -> None:
        """Drain pending validity verdicts into ``hist["faults"]``.

        Aggregation calls record (context, device-bool-array) pairs; the
        arrays stay on device until a natural barrier (finalize or a
        checkpoint save) flushes them in ONE batched pull.  Entries append
        in aggregation order regardless of when flushes happen, so the
        final ``quarantined`` list is identical across checkpoint cadences
        — which is what makes resumed histories byte-comparable."""
        if not self._qpend:
            return
        f = hist.get("faults")
        if f is None:
            f = hist["faults"] = {"events": [], "quarantined": [],
                                  "n_reaped": 0, "n_quarantined": 0}
        # jaxlint: allow(host-sync-in-hot-path) -- one batched validity pull at a barrier (finalize / checkpoint save), not per aggregation
        vals = jax.device_get([v for _, v in self._qpend])
        for (info, _), v in zip(self._qpend, vals):
            flat = np.atleast_1d(np.asarray(v))
            for j, dev in enumerate(info["devices"]):
                if dev is None or j >= len(flat) or bool(flat[j]):
                    continue
                rec = {k: info[k] for k in info
                       if k not in ("devices", "models")}
                rec["device"] = int(dev)
                rec["m"] = int(info["models"][j])
                f["quarantined"].append(rec)
                f["n_quarantined"] += 1
        self._qpend.clear()

    # ------------------------------------------------------------------
    # sync mode — barrier rounds, bit-for-bit the legacy loop
    # ------------------------------------------------------------------

    def _run_sync(self) -> Dict:
        cfg, w = self.cfg, self.world
        fleet = w.fleet
        global_params = w.global_params
        M = w.n_models
        selector, buffer = self.selector, self.buffer
        marl = selector if isinstance(selector, MarlSelector) else None

        x_dev = y_dev = None
        if self.executor == "batched":
            # training set stays device-resident: the bucketed executor
            # gathers mini-batches on device instead of per-step host copies
            x_dev, y_dev = jnp.asarray(w.x_tr), jnp.asarray(w.y_tr)

        # energy scenario (repro.energy): every hook below is gated on the
        # python-level trivial_* flags, so the default config runs the exact
        # pre-scenario program — same traces, same pulls, same bits
        scenario = w.scenario
        gate_avail = not scenario.trivial_availability
        recharge = not scenario.trivial_charge
        budget_active = scenario.budget_active
        tz_host = alive_host = None
        if gate_avail:
            # jaxlint: allow(host-sync-in-hot-path) -- availability-scenario one-time setup pull of the host phase/alive mirrors
            tz_a, alive_a0 = jax.device_get((fleet.tz_phase, fleet.alive))
            tz_host = np.asarray(tz_a, np.float64)
            alive_host = np.asarray(alive_a0, bool).copy()

        w1, w2, w3 = cfg.reward_weights
        rs = self._resume
        if rs is None:
            hist = {"acc": [], "acc_mean": [], "energy": [], "round_time": [],
                    "alive": [], "participants": [], "model_choices": [],
                    "reward": [], "wall_clock": [], "sim_time": [], "idle": [],
                    "dropouts": 0, "idle_time": 0.0, "engine": "sync",
                    "faults": {"events": [], "quarantined": [],
                               "n_reaped": 0, "n_quarantined": 0}}
            prev_acc = float(np.mean(
                fl_server.evaluate(global_params, w.x_val, w.y_val,
                                   family=w.family)))
            e_prev = fleet_total_remaining(fleet)
            sim_time = 0.0
            n_agg = 0
            hotplug_done = False
            t_start = 0
            budget_spent = 0.0
        else:
            fleet = self._restore_fleet(fleet, rs["fleet"])
            global_params = rs["global_params"]
            hist = rs["hist"]
            prev_acc = float(rs["prev_acc"])  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
            e_prev = float(rs["e_prev"])  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
            sim_time = float(rs["sim_time"])  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
            n_agg = int(rs["n_agg"])  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
            hotplug_done = bool(rs["hotplug_done"])
            t_start = int(rs["next_round"])  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
            budget_spent = float(rs.get("budget_spent", 0.0))  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
        if budget_active and "budget" not in hist:
            hist["budget"] = {"limit": float(cfg.global_budget_j),
                              "spent": 0.0, "overrun": 0.0, "trimmed": 0}
        fleet_dead = False
        budget_exhausted = False

        for t in range(t_start, cfg.n_rounds):
            t0 = time.time()
            if (cfg.hotplug_n and not hotplug_done
                    and t >= cfg.hotplug_round):
                # paper Step 1 hot-plug: new devices connect, receive the
                # global model (implicit — clients always pull W_t), start
                # with full batteries
                fleet = fleet_connect(fleet, cfg.n_devices, cfg.energy_scale)
                hotplug_done = True
                if alive_host is not None:
                    alive_host[cfg.n_devices:] = True
            # Top-K budget tracks the CONNECTED fleet (see ISSUE 1 fix).
            n_connected = cfg.n_devices + (cfg.hotplug_n if hotplug_done
                                           else 0)
            k = max(1, int(round(cfg.participation * n_connected)))
            sel_fleet = fleet
            if gate_avail:
                # diurnal/carbon gate: offline devices look dead to the
                # selector this round (they auto-abstain, PR 2 semantics)
                av_host = scenario.available_host(tz_host, sim_time)
                if alive_host.any() and not (av_host & alive_host).any():
                    # whole surviving fleet is offline — fast-forward the
                    # clock to the next opening instead of burning rounds
                    sim_time = scenario.next_available_host(
                        tz_host[alive_host], sim_time)
                av_d = scenario.available(fleet, sim_time)
                sel_fleet = fleet.replace(alive=fleet.alive & av_d)
            sel_kw = {}
            budget_left = overrun = 0.0
            if budget_active:
                # per-pick hard cap: selectors refuse actions whose cost
                # alone no longer fits the remaining fleet-wide budget
                budget_left = float(cfg.global_budget_j) - budget_spent
                sel_kw["budget_left"] = budget_left
            sel = selector.select(sel_fleet, t, k, w.sizes, w.fractions,
                                  cfg.local_epochs, cfg.batch_size, **sel_kw)
            _check_selection(sel, w.n_total)

            choice = np.asarray(sel.model_choice, np.int64)
            active = choice >= 0
            m_idx = np.clip(choice, 0, M - 1)
            t_tra_m, t_com_m, e_tra_m, e_com_m = fleet_cost_matrix_jit(
                fleet, w.sizes, w.fractions, cfg.local_epochs, cfg.batch_size)
            # gather each device's chosen-model column on device, charge,
            # then pull everything the round head needs in ONE sync
            m_col = jnp.asarray(m_idx)[:, None]
            t_cost_d = jnp.take_along_axis(t_tra_m + t_com_m, m_col, 1)[:, 0]
            need_d = jnp.take_along_axis(e_tra_m + e_com_m, m_col, 1)[:, 0]
            had_picks = bool(active.any())
            budget_starved = False
            if budget_active and not had_picks:
                # no picks at all: decide whether the per-pick budget gate
                # (not drained batteries) closed the round — if some alive
                # device could fund its cheapest submodel from its OWN
                # battery but not from the remaining global budget, further
                # rounds can never dispatch either
                # jaxlint: allow(host-sync-in-hot-path) -- budget-scenario termination disambiguation; runs only when a round selects nobody
                mn_a, rem_a, al_a = jax.device_get(
                    ((e_tra_m + e_com_m).min(axis=1), fleet.remaining,
                     fleet.alive))
                mn = np.asarray(mn_a, np.float64)
                own_ok = (np.asarray(al_a, bool)
                          & (mn < np.asarray(rem_a, np.float64)))
                if own_ok.any() and mn[own_ok].min() > budget_left:
                    budget_starved = True
            if budget_active:
                # cumulative hard cap: each pick respected the per-pick
                # budget gate, but together they can still overrun — trim
                # in selection order and charge the trimmed cost to the
                # round's reward as an overrun penalty
                # jaxlint: allow(host-sync-in-hot-path) -- budget-scenario-only extra pull: per-pick costs for the cumulative cap
                need_h = np.asarray(jax.device_get(need_d), np.float64)
                left = budget_left
                for i in sel.participants:
                    if not active[i]:
                        continue
                    if need_h[i] <= left + 1e-9:
                        left -= float(need_h[i])
                    else:
                        active[i] = False
                        overrun += float(need_h[i])
                # attempted cost counts as spent (deaths waste no more than
                # their attempt), so the cap can never be overdrawn
                budget_spent += float(need_h[active].sum())
            fleet, ok_d = fleet_charge_jit(fleet, need_d,
                                           jnp.asarray(active))
            # jaxlint: allow(host-sync-in-hot-path) -- the one batched pull per round head: charge outcome + per-device round times
            t_cost, ok = jax.device_get((t_cost_d, ok_d))
            hist["dropouts"] += int((active & ~ok).sum())
            survivors = active & ok
            t_round = float(t_cost[survivors].max()) if survivors.any() else 0.0
            # straggler wait: finished participants idle at the barrier
            idle_round = float((t_round - t_cost[survivors]).sum())
            if recharge and t_round > 0.0:
                # harvesting: alive devices trickle-charge while the round
                # runs (midpoint-rate rectangle over [sim_time, +t_round])
                fleet = scenario.apply_charge(fleet, sim_time,
                                              sim_time + t_round)

            # contributors: survivors with local data (large-fleet Dirichlet
            # splits can leave a device with no samples — it still paid the
            # round's mostly-comm energy but has nothing to contribute)
            cohort = [i for i in sel.participants
                      if survivors[i] and len(w.parts[i])]
            if self.executor == "batched" and cohort:
                # whole cohort in <= n_buckets jit dispatches (one per
                # populated submodel index), stacked deltas straight into
                # the Pallas layer-agg aggregation for DR-FL
                res = _run_batched_cohort(
                    cfg, w, global_params, cohort,
                    [int(choice[i]) for i in cohort],
                    [fl_client.client_update_seed(cfg.seed, t, i)
                     for i in cohort], x_dev, y_dev)
                if cfg.method == "drfl":
                    global_params, valid = fl_server.aggregate_drfl_stacked(
                        global_params,
                        [(b.model_idx, b.stacked_delta, b.weights, None)
                         for b in res.buckets], server_lr=cfg.server_lr,
                        family=w.family, with_stats=True)
                    devs, models = [], []
                    for b in res.buckets:
                        pad = len(b.weights) - len(b.participants)
                        devs += list(b.participants) + [None] * pad
                        models += [b.model_idx] * len(b.weights)
                    if valid is not None:
                        self._qpend.append((
                            {"devices": devs, "models": models, "round": t,
                             "time": sim_time}, valid))
                else:
                    contribs = res.unstacked()
                    global_params, valid = fl_server.aggregate_sliced(
                        global_params, [c[2] for c in contribs],
                        [c[3] for c in contribs], with_stats=True)
                    self._qpend.append((
                        {"devices": [c[0] for c in contribs],
                         "models": [c[1] for c in contribs], "round": t,
                         "time": sim_time}, valid))
                n_agg += 1
            elif cohort:
                deltas, idxs, weights = [], [], []
                for i in cohort:
                    m = int(choice[i])
                    xi = w.x_tr[w.parts[i]]
                    yi = w.y_tr[w.parts[i]]
                    upd_seed = fl_client.client_update_seed(cfg.seed, t, i)
                    d_, _ = _client_update(cfg, w.family, global_params, m,
                                           xi, yi, upd_seed)
                    deltas.append(d_)
                    idxs.append(m)
                    weights.append(float(len(xi)))
                if cfg.method == "drfl":
                    global_params, valid = fl_server.aggregate_drfl(
                        global_params, deltas, idxs, weights,
                        server_lr=cfg.server_lr, family=w.family,
                        with_stats=True)
                else:
                    global_params, valid = fl_server.aggregate_sliced(
                        global_params, deltas, weights, with_stats=True)
                self._qpend.append((
                    {"devices": list(cohort), "models": idxs, "round": t,
                     "time": sim_time}, valid))
                n_agg += 1

            accs = fl_server.evaluate(global_params, w.x_val, w.y_val,
                                      family=w.family)
            acc = float(np.mean(accs))
            # jaxlint: allow(host-sync-in-hot-path) -- one batched pull per round tail: reward energy term + alive telemetry
            e_now_a, alive_a = jax.device_get((fleet.remaining.sum(),
                                               fleet.alive))
            e_now = float(e_now_a)
            reward = (w1 * (acc - prev_acc) - w2 * (e_prev - e_now)
                      - w3 * (t_round / 60.0))
            if budget_active and overrun:
                # budget-overrun penalty: energy the fleet PROPOSED to spend
                # past the global cap, priced like wasted joules
                reward -= w2 * overrun
            sim_time += t_round
            selector.observe_reward(reward, sim_time=sim_time)
            prev_acc, e_prev = acc, e_now

            if marl:
                if (t + 1) % cfg.marl_train_every == 0 and marl.ep_rewards:
                    _marl_train(marl, buffer, hist, fleet, t + 1,
                                cfg.marl_updates_per_round)

            alive_now = int(alive_a.sum())
            hist["acc"].append(np.asarray(accs))
            hist["acc_mean"].append(acc)
            hist["energy"].append(e_now)
            hist["round_time"].append(t_round)
            hist["alive"].append(alive_now)
            hist["participants"].append(list(sel.participants))
            hist["model_choices"].append(
                [sel.model_choice[i] for i in sel.participants])
            hist["reward"].append(reward)
            hist["wall_clock"].append(time.time() - t0)
            hist["sim_time"].append(sim_time)
            hist["idle"].append(idle_round)
            hist["idle_time"] += idle_round
            if alive_host is not None:
                alive_host = np.asarray(alive_a, bool).copy()
            if budget_active:
                hist["budget"]["spent"] = budget_spent
                hist["budget"]["overrun"] += overrun
                if overrun:
                    hist["budget"]["trimmed"] += 1
            if self.verbose:
                print(f"  round {t:3d}: acc={acc:.3f} exits="
                      f"{np.round(np.asarray(accs), 3)} alive={alive_now}"
                      f" energy={e_now:,.0f}J time={t_round:.1f}s"
                      f" r={reward:+.2f}")
            if alive_now == 0:
                fleet_dead = True
                break
            if budget_active and (
                    float(cfg.global_budget_j) - budget_spent <= 1e-9
                    or budget_starved
                    or (had_picks and not active.any())):
                # nothing left to fund (or the whole round's picks were
                # trimmed): stop here rather than ticking unfunded rounds
                budget_exhausted = True
                break
            if (self.ckpt is not None and self.ckpt_every > 0
                    and (t + 1) % self.ckpt_every == 0):
                self._flush_quarantine(hist)
                state = self._base_snapshot(fleet, global_params, hist)
                state.update(next_round=t + 1, prev_acc=prev_acc,
                             e_prev=e_prev, sim_time=sim_time, n_agg=n_agg,
                             hotplug_done=hotplug_done,
                             budget_spent=budget_spent)
                self.ckpt.save(state, self._ckpt_meta(t + 1))
                self._after_save()

        hist["terminated"] = {
            "reason": ("budget_exhausted" if budget_exhausted
                       else "fleet_dead" if fleet_dead else "completed"),
            "rounds": len(hist["acc_mean"]), "n_rounds": cfg.n_rounds,
            "sim_time": sim_time,
        }
        if budget_exhausted:
            hist["terminated"]["budget"] = "energy"
        hist["n_aggregations"] = n_agg
        hist["sim_time_total"] = sim_time
        return self._finalize(hist, global_params)

    # ------------------------------------------------------------------
    # async mode — event heap over per-device virtual clocks
    # ------------------------------------------------------------------

    def _run_async(self) -> Dict:
        cfg, w = self.cfg, self.world
        fleet = w.fleet
        global_params = w.global_params
        selector, buffer = self.selector, self.buffer
        marl = selector if isinstance(selector, MarlSelector) else None
        decay = getattr(cfg, "staleness_decay", 0.5)
        eval_every = max(1, int(getattr(cfg, "async_eval_every", 1)))
        horizon = float(getattr(cfg, "async_time_horizon", 0.0))
        budget = int(getattr(cfg, "async_task_budget", 0)
                     or sync_task_budget(cfg))
        w1, w2, w3 = cfg.reward_weights

        # energy scenario hooks — python-gated like sync, so the default
        # config dispatches the exact pre-scenario event timeline
        scenario = w.scenario
        gate_avail = not scenario.trivial_availability
        recharge = not scenario.trivial_charge
        budget_active = scenario.budget_active
        tz_host = None
        if gate_avail:
            # jaxlint: allow(host-sync-in-hot-path) -- availability-scenario one-time setup pull of the host phase mirror
            tz_host = np.asarray(jax.device_get(fleet.tz_phase), np.float64)

        x_dev = y_dev = None
        if self.executor == "batched":
            x_dev, y_dev = jnp.asarray(w.x_tr), jnp.asarray(w.y_tr)

        deadline_factor = float(getattr(cfg, "task_deadline_factor", 4.0))
        # per-task deadlines (and their reap events) exist only when faults
        # are injected: a reap pop re-runs refill(), which can consume
        # selector RNG, so clean runs must not see ANY reap events if their
        # timelines are to stay bit-for-bit with earlier releases
        reaping = self.faults is not None
        tasks: Dict[int, dict] = {}        # tid -> task (shared with heap)
        task_by_dev: Dict[int, dict] = {}  # device -> its in-flight task
        disconnected: set = set()
        corrupt_pending: Dict[int, list] = {}  # dev -> [(payload, ev_idx)]
        rs = self._resume
        if rs is None:
            hist = {"acc": [], "acc_mean": [], "energy": [], "round_time": [],
                    "alive": [], "participants": [], "model_choices": [],
                    "reward": [], "wall_clock": [], "sim_time": [], "idle": [],
                    "staleness": [], "task_log": [], "lost": [],
                    "dropouts": 0, "idle_time": 0.0, "wait_for_work": 0.0,
                    "hotplug": None, "engine": "async",
                    "faults": {"events": [], "quarantined": [],
                               "n_reaped": 0, "n_quarantined": 0}}
            acc_prev = float(np.mean(
                fl_server.evaluate(global_params, w.x_val, w.y_val,
                                   family=w.family)))

            state = dict(now=0.0, version=0, seq=0, vround=0,
                         tasks_started=0, completions=0, inflight=0,
                         n_cohorts=0, next_commit=0, last_event=0.0,
                         hotplug_done=not cfg.hotplug_n, acc_prev=acc_prev,
                         window_t0=0.0, window_wall0=time.time(),
                         window_reward=0.0, window_idle=0.0,
                         window_lost=0, tid=0,
                         budget_spent=0.0, budget_blocked=False,
                         last_charge_t=0.0)
            heap: list = []
            cohorts: Dict[int, dict] = {}   # one per selector.select call
            last_done: Dict[int, float] = {}
            window_devices: List[int] = []
            window_models: List[int] = []
            # authoritative virtual clocks, host-side float64: the jax-backend
            # FleetState stores busy_until in float32 (x64 is disabled), whose
            # ~8ms resolution at ~6.5e4 sim-seconds could mark a mid-task
            # device idle; fleet.busy_until is kept as an observability mirror
            # jaxlint: allow(host-sync-in-hot-path) -- one-time setup pull of the host clock mirror
            busy64 = np.asarray(fleet.busy_until, np.float64).copy()
            # alive mirror, maintained from values the loop pulls anyway
            # (charge outcomes, hotplug) so the per-event idle check costs
            # no device sync
            # jaxlint: allow(host-sync-in-hot-path) -- one-time setup pull of the host alive mirror
            alive_host = np.asarray(fleet.alive, bool).copy()
            if self.faults is not None:
                # injected churn rides the same heap as completions; seq
                # pre-assignment makes fault-vs-completion ties deterministic
                for ev in self.faults.events:
                    heapq.heappush(
                        heap, (float(ev.time), state["seq"], "fault",  # jaxlint: allow(host-sync-in-hot-path) -- FaultEvent fields are python scalars; startup plan expansion
                               {"kind": ev.kind, "device": int(ev.device),  # jaxlint: allow(host-sync-in-hot-path) -- FaultEvent fields are python scalars
                                "duration": float(ev.duration),  # jaxlint: allow(host-sync-in-hot-path) -- FaultEvent fields are python scalars
                                "payload": ev.payload}))
                    state["seq"] += 1
        else:
            fleet = self._restore_fleet(fleet, rs["fleet"])
            global_params = rs["global_params"]
            hist = rs["hist"]
            state = dict(rs["state"])
            state["window_wall0"] = time.time()
            state.setdefault("budget_spent", 0.0)
            state.setdefault("budget_blocked", False)
            state.setdefault("last_charge_t", float(state["now"]))
            cohorts = {int(k): dict(v) for k, v in rs["cohorts"].items()}  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
            last_done = {int(k): float(v)  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
                         for k, v in rs["last_done"].items()}
            window_devices = [int(i) for i in rs["window_devices"]]  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
            window_models = [int(m) for m in rs["window_models"]]  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
            busy64 = rs["busy64"]
            alive_host = rs["alive_host"]
            disconnected = set(int(i) for i in rs["disconnected"])  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
            corrupt_pending = {int(k): [tuple(x) for x in v]  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
                               for k, v in rs["corrupt_pending"].items()}
            for tid, enc in rs["tasks"].items():
                tasks[int(tid)] = self._decode_task(enc, rs["params_table"])
            # the serialized heap list was already heap-ordered, so
            # restoring it verbatim preserves the invariant; done/reap
            # entries re-share one task object per tid
            heap = [(float(tt), int(sq), kind,  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
                     tasks[int(ref)] if kind in ("done", "reap")  # jaxlint: allow(host-sync-in-hot-path) -- one-time resume, host values
                     else dict(ref) if kind == "fault" else None)
                    for tt, sq, kind, ref in rs["heap"]]
            for task in tasks.values():
                if not task.get("done") and not task.get("reaped"):
                    task_by_dev[task["device"]] = task
        if budget_active and "budget" not in hist:
            hist["budget"] = {"limit": float(cfg.global_budget_j),
                              "spent": 0.0, "overrun": 0.0, "trimmed": 0}

        def n_connected():
            return cfg.n_devices + (cfg.hotplug_n if state["hotplug_done"]
                                    else 0)

        def top_k():
            return max(1, int(round(cfg.participation * n_connected())))

        def credit(cid, amount):
            cohorts[cid]["reward"] += amount
            state["window_reward"] += amount

        def commit_ready():
            # flush cohort rewards to the selector IN DISPATCH ORDER so the
            # MARL episode trace stays (obs_t, action_t, reward_t)-aligned
            # even when later dispatches complete first
            while (state["next_commit"] < state["n_cohorts"]
                   and cohorts[state["next_commit"]]["pending"] == 0):
                c = cohorts.pop(state["next_commit"])
                selector.observe_reward(c["reward"], sim_time=state["now"])
                state["next_commit"] += 1

        def maybe_hotplug(force: bool = False):
            nonlocal fleet
            if state["hotplug_done"] or (not force
                                         and state["vround"]
                                         < cfg.hotplug_round):
                return
            now = state["now"]
            k_before = top_k()
            fleet = fleet_connect(fleet, cfg.n_devices, cfg.energy_scale,
                                  now=now)
            busy64[cfg.n_devices:] = now
            alive_host[cfg.n_devices:] = True    # fleet_connect: joins live
            state["hotplug_done"] = True
            hist["hotplug"] = {
                "sim_time": now, "vround": state["vround"],
                "version": state["version"], "k_before": k_before,
                "k_after": top_k(),
                # jaxlint: allow(host-sync-in-hot-path) -- hotplug happens once per run; telemetry pull
                "join_remaining": [float(r) for r in np.asarray(
                    fleet.remaining)[cfg.n_devices:]],
            }

        def try_dispatch(n_sel) -> int:
            nonlocal fleet, alive_host
            now = state["now"]
            if recharge and now > state["last_charge_t"]:
                # harvest the idle gap since the last dispatch tick BEFORE
                # costing/charging, so e_before reflects the topped-up fleet
                fleet = scenario.apply_charge(fleet, state["last_charge_t"],
                                              now)
                state["last_charge_t"] = now
            idle = alive_host & (busy64 <= now + 1e-9)
            if gate_avail:
                # offline devices (diurnal window / carbon curfew) simply
                # aren't idle candidates; the heap-empty wake event below
                # reopens the timeline when everyone is offline
                idle &= scenario.available_host(tz_host, now)
            if not idle.any():
                return 0
            budget_left = 0.0
            if budget_active:
                budget_left = (float(cfg.global_budget_j)
                               - state["budget_spent"])
                if budget_left <= 1e-9:
                    state["budget_blocked"] = True
                    return 0
            cid = state["n_cohorts"]
            state["n_cohorts"] += 1
            cohorts[cid] = {"pending": 0, "reward": 0.0}
            alive_mask = (jnp.asarray(idle) if fleet_is_jax(fleet) else idle)
            sel_kw = {"budget_left": budget_left} if budget_active else {}
            sel = selector.select(fleet.replace(alive=alive_mask),
                                  state["vround"], n_sel, w.sizes,
                                  w.fractions, cfg.local_epochs,
                                  cfg.batch_size, **sel_kw)
            _check_selection(sel, w.n_total)
            choice = np.asarray(sel.model_choice, np.int64)
            active = choice >= 0
            if active.any():
                m_idx = np.clip(choice, 0, w.n_models - 1)
                t_tra, t_com, e_tra, e_com = fleet_cost_matrix_jit(
                    fleet, w.sizes, w.fractions, cfg.local_epochs,
                    cfg.batch_size)
                m_col = jnp.asarray(m_idx)[:, None]
                need_d = jnp.take_along_axis(e_tra + e_com, m_col,
                                             1)[:, 0]
                t_cost_d = jnp.take_along_axis(t_tra + t_com, m_col, 1)[:, 0]
                need_h = None
                if budget_active:
                    # jaxlint: allow(host-sync-in-hot-path) -- budget-scenario variant of the same first batched pull (extra values, same sync count)
                    t_cost, need_h = jax.device_get((t_cost_d, need_d))
                    need_h = np.asarray(need_h, np.float64)
                else:
                    # jaxlint: allow(host-sync-in-hot-path) -- first of the two batched pulls per dispatch tick: per-task times for the event heap
                    t_cost = jax.device_get(t_cost_d)
                if horizon > 0:
                    # only send work that can land inside the time budget
                    active &= (now + t_cost) <= horizon + 1e-9
                allow = budget - state["tasks_started"]
                kept = [i for i in sel.participants if active[i]][:allow]
                if budget_active:
                    # cumulative cap, trimmed in selection order (sync rule)
                    left, funded, overrun = budget_left, [], 0.0
                    for i in kept:
                        if need_h[i] <= left + 1e-9:
                            left -= float(need_h[i])
                            funded.append(i)
                        else:
                            overrun += float(need_h[i])
                    if overrun:
                        credit(cid, -w2 * overrun)  # overrun penalty
                        hist["budget"]["overrun"] += overrun
                        hist["budget"]["trimmed"] += 1
                    if kept and not funded:
                        state["budget_blocked"] = True
                    kept = funded
                active = np.zeros(w.n_total, bool)
                active[kept] = True
            if not active.any():
                if budget_active and not state["budget_blocked"]:
                    # nothing dispatched: was it the budget's per-pick gate,
                    # or genuinely drained batteries?  Blocked only if some
                    # idle device could afford its cheapest submodel from
                    # its OWN battery but not from the remaining budget.
                    _, _, e_tra, e_com = fleet_cost_matrix_jit(
                        fleet, w.sizes, w.fractions, cfg.local_epochs,
                        cfg.batch_size)
                    # jaxlint: allow(host-sync-in-hot-path) -- budget-scenario termination disambiguation; runs only when a dispatch comes back empty
                    min_need_a, rem_a = jax.device_get(
                        ((e_tra + e_com).min(axis=1), fleet.remaining))
                    min_need = np.asarray(min_need_a, np.float64)
                    own_ok = idle & (min_need < np.asarray(rem_a,
                                                           np.float64))
                    if own_ok.any() and min_need[own_ok].min() > budget_left:
                        state["budget_blocked"] = True
                return 0
            e_before_d = fleet.remaining.sum()
            fleet, ok_d = fleet_charge_jit(fleet, need_d,
                                           jnp.asarray(active))
            # jaxlint: allow(host-sync-in-hot-path) -- second batched pull per dispatch tick: charge outcome + energy reward terms
            ok, e_before_a, e_after_a = jax.device_get(
                (ok_d, e_before_d, fleet.remaining.sum()))
            e_before, e_after = float(e_before_a), float(e_after_a)
            # fleet_charge kills attempted-but-unaffordable devices; fold
            # the same deaths into the host mirror
            alive_host &= ~(active & ~ok)
            hist["dropouts"] += int((active & ~ok).sum())
            # energy term at SEND time (includes batteries wasted by deaths)
            credit(cid, -w2 * (e_before - e_after))
            if budget_active:
                # attempted cost counts as spent (a death wastes at most its
                # attempt), so the global cap can never be overdrawn
                state["budget_spent"] += float(need_h[active].sum())
                state["budget_blocked"] = False
                hist["budget"]["spent"] = state["budget_spent"]
            started = [i for i in sel.participants if active[i] and ok[i]]
            if not started:
                return 0
            busy64[np.asarray(started)] = now + t_cost[np.asarray(started)]
            fleet = fleet_set_busy(fleet, started,
                                   now + t_cost[np.asarray(started)])
            # micro-bucket: tasks sharing this dispatch tick train against
            # the SAME pulled snapshot, so the bucketed executor runs them
            # as <= n_buckets jit programs NOW and the completion events
            # just consume the precomputed deltas (semantically identical —
            # a client's delta depends only on dispatch-time state).  Each
            # task stores its (shared) BucketResult + row, not a sliced
            # per-client tree — one slice happens at aggregation time.
            rows_by_dev: Dict[int, Any] = {}
            if self.executor == "batched":
                with_data = [i for i in started if len(w.parts[i])]
                if with_data:
                    res = _run_batched_cohort(
                        cfg, w, global_params, with_data,
                        [int(choice[i]) for i in with_data],
                        [fl_client.client_update_seed(cfg.seed, cid, i)
                         for i in with_data], x_dev, y_dev)
                    for b in res.buckets:
                        for r, dev in enumerate(b.participants):
                            rows_by_dev[dev] = (b, r)
            for i in started:
                if i in last_done:            # wait-for-work since last task
                    hist["wait_for_work"] += now - last_done[i]
                task = {
                    "tid": state["tid"], "device": i, "m": int(choice[i]),
                    "version": state["version"],
                    "cohort": cid, "dispatch": cid, "t0": now,
                    "t_cost": float(t_cost[i]),
                }
                state["tid"] += 1
                if self.executor == "batched":
                    task["delta_row"] = rows_by_dev.get(i)
                else:
                    # per-client path trains lazily at the completion event
                    task["params"] = global_params
                tasks[task["tid"]] = task
                task_by_dev[i] = task
                heapq.heappush(heap, (now + float(t_cost[i]), state["seq"],
                                      "done", task))
                state["seq"] += 1
                if reaping:
                    # deadline strictly beyond the completion event: a lost
                    # task's slot is reclaimed here, a healthy task's reap
                    # pops as a no-op after its own completion
                    task["deadline"] = now + deadline_factor * float(
                        t_cost[i])
                    heapq.heappush(heap, (task["deadline"], state["seq"],
                                          "reap", task))
                    state["seq"] += 1
            cohorts[cid]["pending"] = len(started)
            state["tasks_started"] += len(started)
            state["inflight"] += len(started)
            return len(started)

        def refill():
            while (state["tasks_started"] < budget
                   and state["inflight"] < top_k()):
                if horizon > 0 and state["now"] >= horizon:
                    break
                n_sel = min(top_k() - state["inflight"],
                            budget - state["tasks_started"])
                if try_dispatch(n_sel) == 0:
                    break

        def emit_row():
            now = state["now"]
            accs = fl_server.evaluate(global_params, w.x_val, w.y_val,
                                      family=w.family)
            acc = float(np.mean(accs))
            # re-baseline the accuracy term here so eval_every > 1 doesn't
            # leak un-credited progress into later event rewards
            state["window_reward"] += w1 * (acc - state["acc_prev"])
            state["acc_prev"] = acc
            # jaxlint: allow(host-sync-in-hot-path) -- one batched telemetry pull per virtual round
            e_now_a, alive_a = jax.device_get((fleet.remaining.sum(),
                                               fleet.alive))
            e_now, alive_now = float(e_now_a), int(alive_a.sum())
            hist["acc"].append(np.asarray(accs))
            hist["acc_mean"].append(acc)
            hist["energy"].append(e_now)
            hist["round_time"].append(now - state["window_t0"])
            hist["alive"].append(alive_now)
            hist["participants"].append(list(window_devices))
            hist["model_choices"].append(list(window_models))
            hist["reward"].append(state["window_reward"])
            hist["wall_clock"].append(time.time() - state["window_wall0"])
            hist["sim_time"].append(now)
            hist["idle"].append(state["window_idle"])
            hist["lost"].append(state["window_lost"])
            if self.verbose:
                print(f"  vround {state['vround']:3d}: acc={acc:.3f}"
                      f" alive={alive_now} energy={e_now:,.0f}J"
                      f" t={now:.1f}s r={state['window_reward']:+.2f}")
            window_devices.clear()
            window_models.clear()
            state["window_t0"] = now
            state["window_wall0"] = time.time()
            state["window_reward"] = 0.0
            state["window_idle"] = 0.0
            state["window_lost"] = 0
            state["vround"] += 1

        def maybe_emit():
            # lost (reaped) tasks count toward the virtual-round quota so
            # heavy churn still advances rounds — a window where every task
            # died emits a zero-participant row instead of stalling
            if len(window_devices) + state["window_lost"] >= top_k():
                emit_row()
                maybe_hotplug()

        def process_completion(task):
            nonlocal global_params
            now = state["now"]
            i = task["device"]
            task["done"] = True
            if task_by_dev.get(i) is task:
                del task_by_dev[i]
            state["inflight"] -= 1
            last_done[i] = now
            staleness = state["version"] - task["version"]
            cid = task["cohort"]
            cohorts[cid]["pending"] -= 1
            # time term pays the VIRTUAL TIME ADVANCED by this event (the
            # gap since the previous one), not the task's own duration:
            # gaps telescope to the window duration, so a virtual round's
            # total time penalty matches sync's t_round / FLEnv's event
            # gaps rather than k-fold overcharging overlapped tasks
            credit(cid, -w3 * ((now - state["last_event"]) / 60.0))
            state["last_event"] = now
            # straggler wait: the update is aggregated at this very event,
            # so it waits (now - finish_time) = 0 — computed, not assumed
            agg_wait = now - (task["t0"] + task["t_cost"])
            hist["idle_time"] += agg_wait
            state["window_idle"] += agg_wait
            n_i = len(w.parts[i])
            aggregated = False
            if n_i:
                poison_val = None
                if corrupt_pending.get(i):
                    # an armed "corrupt" fault fires on this device's next
                    # completed delta; the aggregation-side quarantine must
                    # keep it out of the global params (asserted by tests)
                    payload, ev_idx = corrupt_pending[i].pop(0)
                    poison_val = poison_payload(payload)
                    ev_rec = hist["faults"]["events"][ev_idx]
                    ev_rec["outcome"] = "poisoned"
                    ev_rec["poisoned_version"] = state["version"]
                batched = "delta_row" in task
                if batched:
                    # bucketed executor: delta precomputed at the dispatch
                    # tick against the snapshot pulled there; slice this
                    # client's row out of the shared bucket result now
                    bucket, row = task["delta_row"]
                else:
                    # clients train on the model snapshot they PULLED at
                    # dispatch; the server reconciles drift via staleness
                    seed = fl_client.client_update_seed(cfg.seed,
                                                        task["dispatch"], i)
                    delta, _ = _client_update(cfg, w.family, task["params"],
                                              task["m"],
                                              w.x_tr[w.parts[i]],
                                              w.y_tr[w.parts[i]], seed)
                qinfo = {"devices": [i], "models": [task["m"]],
                         "version": state["version"], "time": now}
                if cfg.method == "drfl":
                    if batched:
                        delta_1 = jax.tree.map(
                            lambda a: a[row:row + 1], bucket.stacked_delta)
                        if poison_val is not None:
                            delta_1 = jax.tree.map(
                                lambda a: jnp.full_like(a, poison_val),
                                delta_1)
                        global_params, valid = (
                            fl_server.aggregate_drfl_stacked(
                                global_params,
                                [(task["m"], delta_1, [float(n_i)],
                                  [staleness])],
                                server_lr=cfg.server_lr,
                                staleness_decay=decay,
                                family=w.family, with_stats=True))
                    else:
                        if poison_val is not None:
                            delta = jax.tree.map(
                                lambda a: jnp.full_like(a, poison_val),
                                delta)
                        global_params, valid = fl_server.aggregate_drfl(
                            global_params, [delta], [task["m"]],
                            [float(n_i)], server_lr=cfg.server_lr,
                            staleness=[staleness], staleness_decay=decay,
                            family=w.family, with_stats=True)
                else:
                    if batched:
                        delta = jax.tree.map(lambda a: a[row],
                                             bucket.stacked_delta)
                    if poison_val is not None:
                        delta = jax.tree.map(
                            lambda a: jnp.full_like(a, poison_val), delta)
                    a = fl_server.staleness_scale(staleness, decay)
                    if a != 1.0:
                        delta = jax.tree.map(
                            lambda u: (u * a).astype(u.dtype), delta)
                    global_params, valid = fl_server.aggregate_sliced(
                        global_params, [delta], [float(n_i)],
                        with_stats=True)
                if valid is not None:
                    self._qpend.append((qinfo, valid))
                state["version"] += 1
                aggregated = True
            hist["staleness"].append(staleness)
            hist["task_log"].append({
                "device": i, "dispatch": task["dispatch"],
                "version": task["version"], "staleness": staleness,
                "m": task["m"], "t_dispatch": task["t0"], "t_done": now,
            })
            # per-aggregation accuracy evals exist to feed event-time
            # rewards; for non-learning selectors observe_reward is a
            # no-op, so only the virtual-round boundary evaluates
            if marl and aggregated and state["version"] % eval_every == 0:
                accs = fl_server.evaluate(global_params, w.x_val, w.y_val,
                                          family=w.family)
                acc = float(np.mean(accs))
                credit(cid, w1 * (acc - state["acc_prev"]))
                state["acc_prev"] = acc
            window_devices.append(i)
            window_models.append(task["m"])
            state["completions"] += 1
            maybe_emit()

        def process_reap(task):
            # a lost task's deadline passed: reclaim its in-flight slot and
            # settle its cohort so commit_ready can flush in dispatch order.
            # Healthy tasks completed before their deadline — their reap
            # pops as a pure no-op.
            nonlocal fleet
            if (task.get("done") or task.get("reaped")
                    or not task.get("lost")):
                return
            task["reaped"] = True
            now = state["now"]
            i = task["device"]
            if task_by_dev.get(i) is task:
                del task_by_dev[i]
            state["inflight"] -= 1
            cohorts[task["cohort"]]["pending"] -= 1
            # the lost task's cohort pays for the virtual time its silence
            # stalled the timeline (same telescoping rule as completions)
            credit(task["cohort"], -w3 * ((now - state["last_event"])
                                          / 60.0))
            state["last_event"] = now
            busy64[i] = min(busy64[i], now)
            fleet = fleet_set_busy(fleet, [i], float(busy64[i]))  # jaxlint: allow(host-sync-in-hot-path) -- busy64 is the float64 host mirror, no device sync
            hist["faults"]["n_reaped"] += 1
            state["window_lost"] += 1
            hist["task_log"].append({
                "device": i, "dispatch": task["dispatch"],
                "version": task["version"], "staleness": None,
                "m": task["m"], "t_dispatch": task["t0"], "t_done": None,
                "lost": True, "reaped_at": now,
            })
            maybe_emit()

        def process_fault(ev):
            nonlocal fleet
            now = state["now"]
            i = int(ev["device"])
            kind = ev["kind"]
            entry = {"time": now, "kind": kind, "device": i,
                     "injected": kind != "rejoin"}
            task = task_by_dev.get(i)
            if kind == "rejoin":
                if i in disconnected:
                    disconnected.discard(i)
                    fleet = fleet_set_alive(fleet, [i], True)
                    alive_host[i] = True
                    busy64[i] = now
                    fleet = fleet_set_busy(fleet, [i], now)
                    entry["outcome"] = "rejoined"
                else:
                    # the device crash-died while disconnected — stays dead
                    entry["outcome"] = "noop"
            elif kind == "crash":
                if not alive_host[i]:
                    entry["outcome"] = "already_dead"
                else:
                    # jaxlint: allow(host-sync-in-hot-path) -- one scalar pull per injected crash event (plan-bounded, not per tick)
                    e_lost = float(jax.device_get(fleet.remaining[i]))
                    fleet = fleet_kill(fleet, [i])
                    alive_host[i] = False
                    entry["e_lost"] = e_lost
                    if task is not None and not task.get("lost"):
                        # mid-task: the cohort that picked this device eats
                        # the wasted battery, so MARL learns flakiness
                        task["lost"] = True
                        credit(task["cohort"], -w2 * e_lost)
                        entry["outcome"] = "crash_mid_task"
                    else:
                        entry["outcome"] = "crash_idle"
            elif kind == "timeout":
                if task is None or task.get("lost"):
                    entry["outcome"] = "no_inflight_task"
                else:
                    # straggler: silent until the deadline reaps the task;
                    # the device itself survives with its battery
                    task["lost"] = True
                    busy64[i] = task["deadline"]
                    fleet = fleet_set_busy(fleet, [i], task["deadline"])
                    entry["outcome"] = "timed_out"
                    entry["reap_at"] = task["deadline"]
            elif kind == "disconnect":
                if not alive_host[i]:
                    entry["outcome"] = "already_dead"
                else:
                    alive_host[i] = False
                    fleet = fleet_set_alive(fleet, [i], False)
                    disconnected.add(i)
                    if task is not None and not task.get("lost"):
                        task["lost"] = True
                        entry["outcome"] = "disconnect_mid_task"
                    else:
                        entry["outcome"] = "disconnected"
                    t_back = now + max(float(ev.get("duration", 0.0)), 1e-6)
                    heapq.heappush(heap, (t_back, state["seq"], "fault",
                                          {"kind": "rejoin", "device": i}))
                    state["seq"] += 1
                    entry["rejoin_at"] = t_back
            elif kind == "corrupt":
                entry["payload"] = ev.get("payload") or "nan"
                entry["outcome"] = "armed"
            hist["faults"]["events"].append(entry)
            if kind == "corrupt":
                corrupt_pending.setdefault(i, []).append(
                    (entry["payload"], len(hist["faults"]["events"]) - 1))

        def save_checkpoint():
            # quarantine verdicts flush first so the serialized hist is
            # self-consistent; heap entries serialize task payloads by tid
            # (done+reap share one object) and perclient param snapshots
            # dedup by version
            self._flush_quarantine(hist)
            params_table: Dict[int, Any] = {}
            tasks_enc: Dict[int, Any] = {}
            heap_enc = []
            for tt, sq, kind, payload in heap:
                if kind == "wake":
                    heap_enc.append((float(tt), int(sq), kind, None))
                elif kind == "fault":
                    heap_enc.append((float(tt), int(sq), kind,
                                     dict(payload)))
                else:
                    tid = payload["tid"]
                    if tid not in tasks_enc:
                        tasks_enc[tid] = self._encode_task(payload,
                                                           params_table)
                    heap_enc.append((float(tt), int(sq), kind, tid))
            snap = self._base_snapshot(fleet, global_params, hist)
            snap.update(
                state=dict(state),
                cohorts={int(k): dict(v) for k, v in cohorts.items()},
                last_done=dict(last_done),
                window_devices=list(window_devices),
                window_models=list(window_models),
                busy64=busy64.copy(),
                alive_host=alive_host.copy(),
                disconnected=sorted(int(x) for x in disconnected),
                corrupt_pending={int(k): [tuple(x) for x in v]
                                 for k, v in corrupt_pending.items()},
                tasks=tasks_enc,
                heap=heap_enc,
                params_table=params_table,
            )
            self.ckpt.save(snap, self._ckpt_meta(state["vround"]))
            self._after_save()

        last_ckpt = {"vround": state["vround"]}

        def maybe_checkpoint():
            if self.ckpt is None or self.ckpt_every <= 0:
                return
            v = state["vround"]
            if v > last_ckpt["vround"] and v % self.ckpt_every == 0:
                last_ckpt["vround"] = v
                save_checkpoint()

        # --- timeline -------------------------------------------------
        if rs is None:
            maybe_hotplug()  # hotplug_round == 0 joins before first dispatch
            refill()
            commit_ready()
        while True:
            if not heap:
                if not state["hotplug_done"] \
                        and state["tasks_started"] < budget:
                    # no event can ever advance the virtual-round counter
                    # to the join boundary (e.g. the whole initial fleet is
                    # too drained to take a task), but sync mode reaches it
                    # by ticking empty rounds — connect the joiners now so
                    # the two modes agree on the hot-plug story
                    maybe_hotplug(force=True)
                    refill()
                    commit_ready()
                    if heap:
                        continue
                if (gate_avail and state["tasks_started"] < budget
                        and not state["budget_blocked"]):
                    # the timeline starved only because every idle device is
                    # offline right now — wake at the next opening (diurnal
                    # dawn / carbon-window reopen) and dispatch again
                    idle_u = alive_host & (busy64 <= state["now"] + 1e-9)
                    if idle_u.any() and not (
                            scenario.available_host(tz_host, state["now"])
                            & idle_u).any():
                        t_wake = scenario.next_available_host(
                            tz_host[idle_u], state["now"])
                        if horizon <= 0 or t_wake < horizon - 1e-9:
                            heapq.heappush(heap, (float(t_wake),
                                                  state["seq"], "wake",
                                                  None))
                            state["seq"] += 1
                            continue
                break
            t_ev, _, kind, payload = heapq.heappop(heap)
            state["now"] = t_ev
            if kind == "done":
                # a task marked lost settles at its reap event instead
                if not payload.get("lost"):
                    process_completion(payload)
                if not reaping:
                    tasks.pop(payload["tid"], None)
            elif kind == "reap":
                # the reap event is always a task's LAST heap entry
                # (deadline > completion time), so release it here
                process_reap(payload)
                tasks.pop(payload["tid"], None)
            elif kind == "wake":
                pass            # availability wake: refill() below dispatches
            else:
                process_fault(payload)
            refill()
            commit_ready()
            maybe_checkpoint()

        if window_devices or state["window_lost"]:
            emit_row()
        # flush cohorts whose tasks were cut by the horizon/budget
        for c in cohorts.values():
            c["pending"] = 0
        commit_ready()

        if marl and buffer is not None and marl.ep_rewards:
            # event-driven runs have no natural mid-run barrier to train at
            # (the episode trace only fully commits once in-flight cohorts
            # land), so the learner trains at episode end with the same
            # total update count a sync run would have used
            n_updates = cfg.marl_updates_per_round * max(
                1, state["vround"] // max(1, cfg.marl_train_every))
            _marl_train(marl, buffer, hist, fleet, state["vround"],
                        n_updates)

        budget_kind = None
        if state["tasks_started"] >= budget:
            reason = "budget_exhausted"
            budget_kind = "tasks"
        elif not bool(alive_host.any()):
            # every device (including all in-flight work) died: nothing can
            # ever be dispatched again — the terminal marker tells callers
            # the run ended early rather than silently under-delivering
            reason = "fleet_dead"
        elif budget_active and state["budget_blocked"]:
            # global energy budget can no longer fund any dispatch
            reason = "budget_exhausted"
            budget_kind = "energy"
        elif horizon > 0:
            reason = "horizon_reached"
        else:
            reason = "starved"
        hist["terminated"] = {
            "reason": reason, "vrounds": state["vround"],
            "tasks_started": state["tasks_started"],
            "completions": state["completions"],
            "lost": hist["faults"]["n_reaped"],
            "sim_time": state["now"],
        }
        if budget_kind is not None:
            hist["terminated"]["budget"] = budget_kind
        hist["n_tasks"] = state["tasks_started"]
        hist["n_aggregations"] = state["version"]
        hist["sim_time_total"] = state["now"]
        hist["k_final"] = top_k()
        return self._finalize(hist, global_params)

    def _finalize(self, hist, global_params) -> Dict:
        self._flush_quarantine(hist)
        hist["final_acc"] = hist["acc"][-1] if hist["acc"] else np.zeros(4)
        hist["best_acc"] = (np.max(np.stack(hist["acc"]), axis=0)
                            if hist["acc"] else np.zeros(4))
        hist["params"] = global_params
        return hist
