"""Checkpoint substrate hardening (crash-safe fleet service).

Covers the io layer — dtype validation, duplicate-leaf-path raise,
corrupt/truncated-file errors, codec cross-loading, ``latest_step`` tmp
hygiene — and the engine-manifest layer: exact skeleton round-trips
(incl. float64 numpy leaves with x64 disabled), keep-last-k rotation,
orphaned arrays files, manifest version gating, RNG snapshots, and the
config fingerprint that blocks resuming a different run.
"""
import json
import os
import tempfile

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.checkpoint import (EngineCheckpointer, config_fingerprint,
                              decode_state, encode_state, latest_step,
                              load_pytree, read_payload, rng_state,
                              save_pytree, set_rng_state)
from repro.checkpoint import io as ckpt_io

try:
    import zstandard  # noqa: F401
    HAVE_ZSTD = True
except ImportError:
    HAVE_ZSTD = False

DTYPES = ("bool", "int32", "int64", "float32", "float64")


def _random_array(dt, seed):
    rng = np.random.default_rng(seed)
    if dt == "bool":
        return rng.integers(0, 2, size=(3, 4)).astype(bool)
    if dt.startswith("int"):
        return rng.integers(-1000, 1000, size=(3, 4)).astype(dt)
    return rng.standard_normal((3, 4)).astype(dt)


# ----------------------------------------------------------------------
# io layer
# ----------------------------------------------------------------------

@given(dt=st.sampled_from(DTYPES), seed=st.integers(0, 2 ** 16))
@settings(max_examples=20, deadline=None)
def test_save_load_round_trips_every_dtype(dt, seed):
    arr = _random_array(dt, seed)
    with tempfile.TemporaryDirectory() as d:
        p = save_pytree(os.path.join(d, "x.ckpt"), {"a": arr})
        out = load_pytree(p, {"a": np.zeros_like(arr)}, backend="numpy")
    got = out["a"]
    assert isinstance(got, np.ndarray) and got.dtype == arr.dtype
    assert got.tobytes() == arr.tobytes()
    got[:] = 0                      # numpy backend must return writable arrays


def test_dtype_mismatch_raises(tmp_path):
    p = save_pytree(str(tmp_path / "x.ckpt"),
                    {"a": np.ones((2, 2), np.float32)})
    with pytest.raises(ValueError, match="dtype mismatch"):
        load_pytree(p, {"a": np.ones((2, 2), np.int32)})


def test_duplicate_leaf_path_raises(tmp_path):
    # {"a": {"b": ...}} and a literal "a/b" key flatten to the same path —
    # silently keeping one of the two would corrupt whichever loads second
    tree = {"a": {"b": np.ones(2)}, "a/b": np.zeros(2)}
    with pytest.raises(ValueError, match="duplicate leaf path"):
        save_pytree(str(tmp_path / "x.ckpt"), tree)


def test_truncated_and_garbage_files_raise_valueerror(tmp_path):
    p = save_pytree(str(tmp_path / "x.ckpt"), {"a": np.arange(100.0)})
    blob = open(p, "rb").read()
    trunc = tmp_path / "trunc.ckpt"
    trunc.write_bytes(blob[:len(blob) // 2])
    with pytest.raises(ValueError, match="corrupt or truncated"):
        read_payload(str(trunc))
    garbage = tmp_path / "garbage.ckpt"
    garbage.write_bytes(b"\x00\x01definitely not a checkpoint")
    with pytest.raises(ValueError, match="corrupt or truncated"):
        read_payload(str(garbage))


def test_payload_without_meta_raises(tmp_path):
    import msgpack
    import zlib
    bad = tmp_path / "bad.ckpt"
    bad.write_bytes(zlib.compress(msgpack.packb({"a": 1})))
    with pytest.raises(ValueError, match="missing __meta__"):
        read_payload(str(bad))


def test_latest_step_ignores_tmp_and_foreign_files(tmp_path):
    d = tmp_path / "ck"
    p = save_pytree(str(d), {"a": np.ones(2)}, step=3)
    # a crash mid-save leaves a .tmp; a foreign file must not match either
    (d / "step_00000009.ckpt.tmp").write_bytes(b"partial")
    (d / "notes.txt").write_text("hi")
    assert latest_step(str(d)) == p


def test_cross_codec_zlib_always_loads(tmp_path, monkeypatch):
    # force the zlib fallback on write; the sniffing reader must load it
    # regardless of which codec the current process would pick
    arr = np.arange(6.0).reshape(2, 3)
    monkeypatch.setattr(ckpt_io, "zstd", None)
    p = save_pytree(str(tmp_path / "z.ckpt"), {"a": arr})
    monkeypatch.undo()
    out = load_pytree(p, {"a": np.zeros_like(arr)}, backend="numpy")
    assert out["a"].tobytes() == arr.tobytes()


@pytest.mark.skipif(HAVE_ZSTD, reason="needs the zstd-less fallback path")
def test_zstd_frame_without_library_raises_runtimeerror(tmp_path):
    p = tmp_path / "z.ckpt"
    p.write_bytes(ckpt_io._ZSTD_MAGIC + b"\x00" * 16)
    with pytest.raises(RuntimeError, match="zstandard"):
        read_payload(str(p))


@pytest.mark.skipif(not HAVE_ZSTD, reason="zstandard not installed")
def test_cross_codec_zstd_roundtrip(tmp_path):
    arr = np.arange(6.0)
    p = save_pytree(str(tmp_path / "z.ckpt"), {"a": arr})
    assert open(p, "rb").read()[:4] == ckpt_io._ZSTD_MAGIC
    out = load_pytree(p, {"a": np.zeros_like(arr)}, backend="numpy")
    assert out["a"].tobytes() == arr.tobytes()


# ----------------------------------------------------------------------
# engine manifest codec
# ----------------------------------------------------------------------

def _gnarly_state():
    return {
        "none": None,
        "flags": (True, False),
        "big_int": 2 ** 80 + 3,
        "exact_float": 0.1 + 0.2,
        "label": "ep0",
        "int_keys": {0: "a", 7: {"nested": [1, 2.5, None]}},
        "np_scalar": np.float64(1.0 / 3.0),
        "np_f64": np.linspace(0, 1, 7),            # float64 survives x64=off
        "np_bool": np.array([True, False, True]),
        "jax_arr": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "heap": [(0.5, 1, "done", 0), (0.75, 2, "fault", {"kind": "crash"})],
    }


def test_skeleton_roundtrip_is_exact():
    state = _gnarly_state()
    skeleton, arrays = encode_state(state)
    # the skeleton must be JSON-able (that's what the manifest stores)
    skeleton = json.loads(json.dumps(skeleton))
    out = decode_state(skeleton, arrays)
    assert out["none"] is None
    assert out["flags"] == (True, False) and isinstance(out["flags"], tuple)
    assert out["big_int"] == 2 ** 80 + 3
    assert out["exact_float"] == 0.1 + 0.2          # exact, not approximate
    assert out["int_keys"][7]["nested"] == [1, 2.5, None]
    assert isinstance(out["np_scalar"], np.float64)
    assert out["np_scalar"] == np.float64(1.0 / 3.0)
    assert isinstance(out["np_f64"], np.ndarray)
    assert out["np_f64"].dtype == np.float64
    assert out["np_f64"].tobytes() == state["np_f64"].tobytes()
    assert out["np_bool"].dtype == bool
    assert isinstance(out["jax_arr"], jax.Array)
    assert np.asarray(out["jax_arr"]).tobytes() == \
        np.asarray(state["jax_arr"]).tobytes()
    assert out["heap"][0] == (0.5, 1, "done", 0)
    assert out["heap"][1][3] == {"kind": "crash"}


def test_engine_checkpointer_save_load_rotate(tmp_path):
    ck = EngineCheckpointer(str(tmp_path), keep=2)
    for step in (2, 4, 6):
        ck.save({"step": step, "arr": np.full(3, float(step))},
                {"episode": 0, "step": step})
    names = sorted(os.listdir(tmp_path))
    assert [n for n in names if n.endswith(".manifest.json")] == [
        "ep0000_step00000004.manifest.json",
        "ep0000_step00000006.manifest.json"]
    assert [n for n in names if n.endswith(".ckpt")] == [
        "ep0000_step00000004.ckpt", "ep0000_step00000006.ckpt"]
    state, meta = ck.load()                      # latest
    assert meta["step"] == 6 and state["step"] == 6
    assert state["arr"].tolist() == [6.0, 6.0, 6.0]


def test_orphaned_arrays_file_is_invisible(tmp_path):
    # crash between the .ckpt write and the manifest write leaves an
    # orphan; latest() must keep pointing at the previous complete save
    ck = EngineCheckpointer(str(tmp_path), keep=3)
    good = ck.save({"x": 1}, {"episode": 0, "step": 1})
    (tmp_path / "ep0000_step00000002.ckpt").write_bytes(b"partial")
    assert ck.latest() == good
    state, meta = ck.load()
    assert meta["step"] == 1


def test_manifest_version_gate(tmp_path):
    ck = EngineCheckpointer(str(tmp_path))
    path = ck.save({"x": 1}, {"episode": 0, "step": 1})
    manifest = json.load(open(path))
    manifest["version"] = 999
    json.dump(manifest, open(path, "w"))
    with pytest.raises(ValueError, match="version 999"):
        ck.load(path)


def test_rng_state_roundtrip():
    gen = np.random.default_rng(42)
    gen.standard_normal(5)
    snap = rng_state(gen)
    want = gen.standard_normal(8)
    fresh = np.random.default_rng(0)
    set_rng_state(fresh, snap)
    assert np.array_equal(fresh.standard_normal(8), want)
    assert rng_state(None) is None


def test_config_fingerprint_ignores_process_knobs(tmp_path):
    from repro.fl import FLConfig
    a = FLConfig(seed=3)
    b = FLConfig(seed=3, checkpoint_dir=str(tmp_path), checkpoint_every=5,
                 checkpoint_keep=7, resume=True)
    c = FLConfig(seed=4)
    assert config_fingerprint(a) == config_fingerprint(b)
    assert config_fingerprint(a) != config_fingerprint(c)
