"""Rule ``host-sync-in-hot-path``.

Flags operations that force a device->host transfer (and therefore a
blocking XLA sync) inside the per-round/per-event hot paths: ``.item()``,
``.block_until_ready()``, ``jax.device_get(...)``, ``np.asarray(...)`` /
``np.array(...)`` of device values, and ``float(...)``/``int(...)`` of
device values.

The hot set is NOT a grep: it is the call-graph closure of the configured
roots (``RoundEngine`` methods, ``dual_selection_energy_step``,
``ModelFamily.client_update``) plus every module-scope-jitted function —
a sync inside those is either a per-event stall or a tracer leak.

To keep the signal high, host-side values are tracked per function: names
assigned from numpy-rooted expressions, literals, ``len()``-style
builtins, ``jax.device_get`` results, or the configured
``host_returning`` functions are host-local, and ``float``/``int``/
``np.asarray`` over purely host-rooted expressions do not fire.  What
remains is a genuine device pull — either batch it to one sync per event
tick (``jax.device_get`` of everything the tick needs) or justify it with
``# jaxlint: allow(host-sync-in-hot-path) -- <why>``.  ``device_get``
itself still fires, deliberately: every batched pull carries its written
justification.
"""
from __future__ import annotations

import ast
from typing import List, Set

from ..callgraph import build_call_graph, reachable_from, resolve_roots
from ..core import Finding, FuncInfo, Module, RepoIndex

RULE = "host-sync-in-hot-path"

_SCALAR_ANN = {"int", "float", "bool", "str"}
_CONTAINER_ANN = {"Sequence", "List", "Tuple", "Dict", "Optional",
                  "Iterable", "Mapping", "Set", "FrozenSet"}

_HOST_BUILTINS = {"len", "range", "int", "float", "bool", "str", "round",
                  "sorted", "list", "tuple", "dict", "set", "min", "max",
                  "abs", "sum", "enumerate", "zip", "isinstance", "getattr",
                  "hasattr", "repr", "print", "id", "type"}
_HOST_MODULES = {"time", "os", "math", "heapq", "json", "re", "sys",
                 "dataclasses", "functools", "itertools", "collections"}
_NUMPY_MODULES = {"numpy", "numpy.random"}


def _module_root(mod: Module, name: str) -> str:
    """The imported module a bare name refers to, or ''."""
    return mod.module_aliases.get(name, "")


def _is_numpy_name(mod: Module, name: str) -> bool:
    return _module_root(mod, name) in _NUMPY_MODULES


def _attr_chain_root(node: ast.AST):
    while isinstance(node, ast.Attribute):
        node = node.value
    return node


def _is_module_attr(mod: Module, func: ast.AST, modnames: Set[str]) -> bool:
    """True for ``alias.attr(...)`` where alias imports one of modnames."""
    if not isinstance(func, ast.Attribute):
        return False
    root = _attr_chain_root(func)
    return (isinstance(root, ast.Name)
            and _module_root(mod, root.id) in modnames)


def _is_host_returning(mod: Module, func: ast.AST, config) -> bool:
    qual_entries = {e for e in config.host_returning if ":" in e}
    bare_entries = {e for e in config.host_returning if ":" not in e}
    if isinstance(func, ast.Name):
        if func.id in bare_entries:
            return True
        imp = mod.from_imports.get(func.id)
        if imp and f"{imp[0]}:{imp[1]}" in qual_entries:
            return True
    if isinstance(func, ast.Attribute):
        if func.attr in bare_entries:
            return True
        base = func.value
        if isinstance(base, ast.Name):
            imp = mod.from_imports.get(base.id)
            if imp and f"{imp[0]}.{imp[1]}:{func.attr}" in qual_entries:
                return True
            alias = _module_root(mod, base.id)
            if alias and f"{alias}:{func.attr}" in qual_entries:
                return True
    return False


def _host_annotation(mod: Module, ann: ast.expr) -> bool:
    """Annotations that mean "this value lives on the host": scalar
    builtins, typing containers, numpy arrays (numpy data IS host data —
    converting it costs nothing)."""
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        head = ann.value.split("[")[0].strip().split(".")[-1]
        return head in _SCALAR_ANN | _CONTAINER_ANN | {"ndarray"}
    if isinstance(ann, ast.Name):
        return ann.id in _SCALAR_ANN | _CONTAINER_ANN
    if isinstance(ann, ast.Subscript):
        return _host_annotation(mod, ann.value)
    if isinstance(ann, ast.Attribute):
        root = _attr_chain_root(ann)
        if isinstance(root, ast.Name) and _is_numpy_name(mod, root.id):
            return True
        return ann.attr in _SCALAR_ANN | _CONTAINER_ANN
    return False


def _host_params(mod: Module, fn_node) -> Set[str]:
    """Parameters whose annotation or literal default pins them host."""
    out: Set[str] = set()
    a = fn_node.args
    pos = a.posonlyargs + a.args
    defaults = [None] * (len(pos) - len(a.defaults)) + list(a.defaults)
    for p, d in list(zip(pos, defaults)) + list(zip(a.kwonlyargs,
                                                    a.kw_defaults)):
        if p.annotation is not None and _host_annotation(mod, p.annotation):
            out.add(p.arg)
        elif isinstance(d, ast.Constant) and not isinstance(d.value, bytes):
            out.add(p.arg)
    return out


def _host_globals(mod: Module) -> Set[str]:
    """Module-level names bound to literal constants (STAGE_CHANNELS-style
    tables) — host by construction."""
    out: Set[str] = set()
    for node in mod.tree.body:
        targets = []
        value = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        if value is None or not _is_literal(value):
            continue
        for t in targets:
            if isinstance(t, ast.Name):
                out.add(t.id)
    return out


def _is_literal(node: ast.expr) -> bool:
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return all(_is_literal(e) for e in node.elts)
    if isinstance(node, ast.Dict):
        return all(_is_literal(e) for e in node.keys + node.values
                   if e is not None)
    if isinstance(node, ast.UnaryOp):
        return _is_literal(node.operand)
    if isinstance(node, ast.BinOp):
        return _is_literal(node.left) and _is_literal(node.right)
    return False


class _FuncScanner:
    """Single ordered pass over one hot function's body: tracks host-local
    names, emits findings for sync triggers."""

    def __init__(self, info: FuncInfo, mod: Module, config,
                 index: RepoIndex, findings: List[Finding]):
        self.info = info
        self.mod = mod
        self.config = config
        self.index = index
        self.findings = findings
        self.params = {p.arg for p in (info.node.args.posonlyargs
                                       + info.node.args.args
                                       + info.node.args.kwonlyargs)}
        self.host: Set[str] = (_host_params(mod, info.node)
                               | _host_globals(mod))
        self.host_attrs = set(getattr(config, "host_attrs",
                                      ("cfg", "config", "rng")))

    # -- host-rootedness ---------------------------------------------------

    def is_host(self, node: ast.AST) -> bool:
        m = self.mod
        if isinstance(node, (ast.Constant, ast.JoinedStr)):
            return True
        if isinstance(node, (ast.List, ast.Tuple, ast.Dict, ast.Set,
                             ast.ListComp, ast.SetComp, ast.DictComp,
                             ast.GeneratorExp)):
            return True
        if isinstance(node, ast.Name):
            return node.id in self.host
        if isinstance(node, ast.Starred):
            return self.is_host(node.value)
        if isinstance(node, ast.UnaryOp):
            return self.is_host(node.operand)
        if isinstance(node, ast.BinOp):
            return self.is_host(node.left) and self.is_host(node.right)
        if isinstance(node, ast.BoolOp):
            return all(self.is_host(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return (self.is_host(node.left)
                    and all(self.is_host(c) for c in node.comparators))
        if isinstance(node, ast.IfExp):
            return self.is_host(node.body) and self.is_host(node.orelse)
        if isinstance(node, ast.Subscript):
            return self.is_host(node.value)
        if isinstance(node, ast.Attribute):
            root = _attr_chain_root(node)
            chain = {node.attr}
            cur = node.value
            while isinstance(cur, ast.Attribute):
                chain.add(cur.attr)
                cur = cur.value
            if isinstance(cur, ast.Name):
                chain.add(cur.id)
            if chain & self.host_attrs:
                return True              # cfg.*, self.cfg.*, self.rng.*
            if isinstance(root, ast.Name):
                if _is_numpy_name(m, root.id):
                    return True          # np.float64, np.random, ...
                if _module_root(m, root.id) in _HOST_MODULES:
                    return True
            return self.is_host(node.value)
        if isinstance(node, ast.Call):
            func = node.func
            if isinstance(func, ast.Name) and func.id in _HOST_BUILTINS:
                return True
            if _is_module_attr(m, func, _NUMPY_MODULES | _HOST_MODULES):
                return True              # np.mean(...), time.time(), ...
            if _is_jax_device_get(m, func):
                return True              # the pull result lives on host
            if _is_host_returning(m, func, self.config):
                return True
            if self._scalar_return(func):
                return True              # callee annotated -> int/float/...
            # method on a host value: host_list.copy(), host_arr.sum(), ...
            if isinstance(func, ast.Attribute) and self.is_host(func.value):
                return True
            return False
        return False

    def _scalar_return(self, func: ast.AST) -> bool:
        """True when the called repo function's return annotation pins the
        result to a host scalar (``-> int``/``-> float``/...)."""
        infos: List[FuncInfo] = []
        if isinstance(func, ast.Name):
            imp = self.mod.from_imports.get(func.id)
            if imp:
                hit = self.index.functions.get(f"{imp[0]}:{imp[1]}")
                if hit:
                    infos.append(hit)
            hit = self.index.functions.get(f"{self.mod.modname}:{func.id}")
            if hit:
                infos.append(hit)
        elif isinstance(func, ast.Attribute):
            infos = [f for f in self.index.functions.values()
                     if f.name == func.attr]
        if not infos:
            return False
        anns = [getattr(f.node, "returns", None) for f in infos]
        return all(isinstance(a, ast.Name) and a.id in _SCALAR_ANN
                   for a in anns)

    # -- traversal ---------------------------------------------------------

    def scan(self) -> None:
        for stmt in self.info.node.body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs share the env; their own params are host glue
            # (device data reaches closures through captured names)
            for p in (stmt.args.posonlyargs + stmt.args.args
                      + stmt.args.kwonlyargs):
                self.host.add(p.arg)
            for s in stmt.body:
                self._stmt(s)
            return
        if isinstance(stmt, ast.Assign):
            self._expr(stmt.value)
            host_val = self.is_host(stmt.value)
            for tgt in stmt.targets:
                self._bind(tgt, host_val)
            return
        if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            if stmt.value is not None:
                self._expr(stmt.value)
                if isinstance(stmt, ast.AnnAssign):
                    self._bind(stmt.target, self.is_host(stmt.value))
            return
        if isinstance(stmt, ast.For):
            self._expr(stmt.iter)
            self._bind(stmt.target, self._iter_is_host(stmt.iter))
            for s in stmt.body + stmt.orelse:
                self._stmt(s)
            return
        if isinstance(stmt, (ast.If, ast.While)):
            self._expr(stmt.test)
            for s in stmt.body + stmt.orelse:
                self._stmt(s)
            return
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                self._expr(item.context_expr)
            for s in stmt.body:
                self._stmt(s)
            return
        if isinstance(stmt, ast.Try):
            for s in (stmt.body + stmt.orelse + stmt.finalbody
                      + [h for hh in stmt.handlers for h in hh.body]):
                self._stmt(s)
            return
        if isinstance(stmt, (ast.Expr, ast.Return)) and stmt.value is not None:
            self._expr(stmt.value)
            return
        # other statements (pass, break, raise, ...): check embedded exprs
        for node in ast.iter_child_nodes(stmt):
            if isinstance(node, ast.expr):
                self._expr(node)

    def _bind(self, target: ast.expr, host_val: bool) -> None:
        if isinstance(target, ast.Name):
            (self.host.add if host_val else self.host.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._bind(el, host_val)

    def _iter_is_host(self, it: ast.expr) -> bool:
        # iterating a bare parameter: callers pass host sequences into
        # these loops; a device array would be sliced, not iterated
        if isinstance(it, ast.Name) and it.id in self.params:
            return True
        return self.is_host(it)

    def _expr(self, node: ast.expr) -> None:
        # comprehension targets over host iterables, and lambda params,
        # are host for the duration of this expression
        added: List[str] = []

        def bind(name: str) -> None:
            if name not in self.host:
                self.host.add(name)
                added.append(name)

        for n in ast.walk(node):
            if isinstance(n, (ast.ListComp, ast.SetComp, ast.DictComp,
                              ast.GeneratorExp)):
                for gen in n.generators:
                    if self._iter_is_host(gen.iter):
                        for t in ast.walk(gen.target):
                            if isinstance(t, ast.Name):
                                bind(t.id)
            elif isinstance(n, ast.Lambda):
                for p in (n.args.posonlyargs + n.args.args
                          + n.args.kwonlyargs):
                    bind(p.arg)
        for call in [n for n in ast.walk(node) if isinstance(n, ast.Call)]:
            self._check_call(call)
        for name in added:
            self.host.discard(name)

    # -- triggers ----------------------------------------------------------

    def _flag(self, node: ast.AST, what: str) -> None:
        self.findings.append(Finding(
            rule=RULE, file=self.mod.relpath, line=node.lineno,
            message=f"{what} in hot path "
                    f"({self.info.qualname.split(':')[-1]})"))

    def _arg_is_checkable(self, arg: ast.expr) -> bool:
        """Bare parameters are not flagged: ``float(lr)`` inside
        ``f(lr: ...)`` is the caller's sync if it is one at all — charging
        it here would force a pragma on every scalar-coercion helper."""
        if isinstance(arg, ast.Name) and arg.id in self.params:
            return False
        return not self.is_host(arg)

    def _check_call(self, call: ast.Call) -> None:
        func = call.func
        m = self.mod
        if isinstance(func, ast.Attribute):
            if func.attr == "item" and not call.args:
                self._flag(call, ".item() forces a device sync")
                return
            if func.attr == "block_until_ready":
                self._flag(call, ".block_until_ready() blocks on the device")
                return
            if _is_jax_device_get(m, func):
                self._flag(call, "jax.device_get pulls device values "
                                 "(one batched pull per event tick needs a "
                                 "written reason)")
                return
            if (func.attr in ("asarray", "array")
                    and isinstance(_attr_chain_root(func), ast.Name)
                    and _is_numpy_name(m,
                                       _attr_chain_root(func).id)):
                if call.args and self._arg_is_checkable(call.args[0]):
                    self._flag(call, f"np.{func.attr}() of a device value "
                                     "forces a sync")
                return
        if isinstance(func, ast.Name) and func.id in ("float", "int"):
            if len(call.args) == 1 and self._arg_is_checkable(call.args[0]):
                self._flag(call, f"{func.id}() of a device value forces "
                                 "a sync")


def _is_jax_device_get(mod: Module, func: ast.AST) -> bool:
    if not (isinstance(func, ast.Attribute) and func.attr == "device_get"):
        return False
    root = _attr_chain_root(func)
    return (isinstance(root, ast.Name)
            and mod.module_aliases.get(root.id, "") == "jax")


def _jitted_functions(index: RepoIndex) -> Set[str]:
    """Functions jitted at module scope (decorator or module-level alias):
    a host sync inside them is a tracer leak, not just a stall."""
    out: Set[str] = set()
    for mod in index.modules.values():
        for alias, (target, _) in mod.jit_aliases.items():
            hit = index.functions.get(f"{mod.modname}:{target}")
            if hit:
                out.add(hit.qualname)
        for info in index.functions_in(mod.modname):
            node = info.node
            for deco in getattr(node, "decorator_list", ()):
                expr = deco.func if isinstance(deco, ast.Call) else deco
                if (isinstance(expr, ast.Attribute) and expr.attr == "jit"):
                    out.add(info.qualname)
                if (isinstance(deco, ast.Call)
                        and isinstance(deco.func, ast.Attribute)
                        and deco.func.attr == "partial" and deco.args
                        and isinstance(deco.args[0], ast.Attribute)
                        and deco.args[0].attr == "jit"):
                    out.add(info.qualname)
    return out


def check(index: RepoIndex, config) -> List[Finding]:
    graph = build_call_graph(index)
    roots = resolve_roots(index, config.hot_roots)
    hot = reachable_from(graph, roots) | _jitted_functions(index)
    findings: List[Finding] = []
    for qual in sorted(hot):
        info = index.functions[qual]
        _FuncScanner(info, index.modules[info.module], config, index,
                     findings).scan()
    return findings
