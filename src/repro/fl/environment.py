"""Gym-style environment wrapper around the DR-FL energy simulation.

For MARL research use: exposes the paper's MDP (§4.3) — per-agent
observations (Eq. 9), joint actions (submodel choice / abstain per device),
team reward (Eq. 10) — without running actual model training.  The accuracy
term in the reward is driven by a pluggable *accuracy proxy* (default: a
diminishing-returns curve of useful aggregated work), so policy research can
iterate thousands of episodes per minute; the full simulation
(:mod:`repro.fl.simulation`) swaps in real training for the final numbers.

The fleet is a vectorized :class:`repro.core.fleet.FleetState`; one ``step``
is a constant number of batched array ops regardless of fleet size (numpy
float64 backend: for the small fleets policy research sweeps, dispatch
overhead beats jit, and the dynamics match the scalar reference
bit-for-bit).

``FLEnvConfig.mode`` selects the reward clock: ``"sync"`` pays the round
barrier (max completion time over participants), ``"async"`` mirrors the
event-driven engine — busy devices auto-abstain via their ``busy_until``
virtual clocks and the time term pays only the gap to the next completion
event, so policies observe event-time rewards.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import numpy as np

from repro.core.fleet import (FleetState, fleet_charge, fleet_cost_matrix,
                              fleet_idle, fleet_total_remaining,
                              make_fleet_state)
from repro.core.selection import OBS_DIM, fleet_obs


def default_accuracy_proxy(progress: float) -> float:
    """Diminishing-returns accuracy curve: acc in [0.1, ~0.95]."""
    return 0.1 + 0.85 * (1.0 - np.exp(-progress))


@dataclasses.dataclass
class FLEnvConfig:
    n_devices: int = 20
    n_rounds: int = 50
    k_fraction: float = 0.1            # Top-K participation
    n_models: int = 4
    model_bytes: Tuple[float, ...] = (2.8e6, 8.4e6, 22.5e6, 44.8e6)
    model_fractions: Tuple[float, ...] = (0.11, 0.3, 0.72, 1.0)
    reward_weights: Tuple[float, float, float] = (1000.0, 0.01, 1.0)
    energy_scale: float = 0.15
    local_epochs: int = 5
    seed: int = 0
    mode: str = "sync"                 # sync (barrier) | async (event-time)

    @classmethod
    def for_family(cls, family: str = "cnn", num_classes: int = 10,
                   **kwargs) -> "FLEnvConfig":
        """Env config whose action space and cost model come from a
        registered :class:`repro.models.family.ModelFamily` (the same
        paper-scale Eq. 5/7 calibration ``build_world`` charges), so
        policies researched here transfer to ``run_simulation`` on that
        family."""
        from repro.models.family import get_family
        fam = get_family(family)
        sizes, fractions = fam.cost_model(num_classes)
        return cls(n_models=fam.num_submodels(),
                   model_bytes=tuple(float(s) for s in sizes),
                   model_fractions=tuple(float(f) for f in fractions),
                   **kwargs)


class FLEnv:
    """step(actions) -> (obs, reward, done, info).

    actions: int array [n_devices]; value in [0, n_models) = train that
    submodel, n_models = do not participate.  Top-K filtering is the
    CALLER's job (the paper filters by Q value; the env accepts any subset).

    ``mode="sync"`` advances the clock by the round barrier ``max(t_cost)``
    and the reward's time term pays that barrier.  ``mode="async"`` mirrors
    the event-driven engine: devices still mid-task (``busy_until`` beyond
    the clock) auto-abstain, the clock advances to the NEXT completion
    event, and the reward's time term pays only that event gap — so
    policies trained here observe event-time rewards, not barrier rewards.
    ``info`` always carries ``sim_time`` and the round's ``idle_time``
    (straggler wait at the barrier; zero in async mode).
    """

    def __init__(self, cfg: FLEnvConfig,
                 accuracy_proxy: Callable[[float], float] = default_accuracy_proxy):
        self.cfg = cfg
        self.proxy = accuracy_proxy
        self.obs_dim = OBS_DIM
        self.reset()

    def reset(self) -> np.ndarray:
        cfg = self.cfg
        fleet = make_fleet_state(cfg.n_devices, cfg.seed, backend="numpy")
        self.fleet: FleetState = fleet.replace(
            remaining=fleet.battery * cfg.energy_scale)
        self.t = 0
        self.sim_time = 0.0
        self.progress = 0.0
        self.acc = self.proxy(0.0)
        self.e_prev = fleet_total_remaining(self.fleet)
        return self._obs()

    def _obs(self) -> np.ndarray:
        return fleet_obs(self.fleet, self.t, self.cfg.n_rounds)

    @property
    def state(self) -> np.ndarray:
        return self._obs().reshape(-1)

    @property
    def state_factored(self) -> np.ndarray:
        """Fixed-width factored global state (``fleet_summary`` priced with
        the env's cost model) — the scale-independent twin of ``state``,
        matching what ``MarlSelector(state_mode="factored")`` sees."""
        from repro.core.fleet import fleet_summary
        cfg = self.cfg
        return np.asarray(fleet_summary(
            self.fleet, cfg.model_bytes, cfg.model_fractions, self.t,
            cfg.n_rounds, cfg.local_epochs), np.float32)

    def step(self, actions: np.ndarray):
        cfg = self.cfg
        a = np.asarray(actions, np.int64)
        active = (a < cfg.n_models) & np.asarray(self.fleet.alive)
        if cfg.mode == "async":
            # event semantics: devices still mid-task cannot be dispatched
            active &= fleet_idle(self.fleet, self.sim_time)
        m_idx = np.clip(a, 0, cfg.n_models - 1)
        rows = np.arange(len(self.fleet))
        t_tra, t_com, e_tra, e_com = fleet_cost_matrix(
            self.fleet, cfg.model_bytes, cfg.model_fractions,
            cfg.local_epochs)
        need = (e_tra + e_com)[rows, m_idx]
        self.fleet, ok = fleet_charge(self.fleet, need, active)
        dropouts = int((active & ~ok).sum())
        t_cost = (t_tra + t_com)[rows, m_idx]
        t_round = float(np.max(t_cost, where=ok, initial=0.0))
        if cfg.mode == "async":
            # dispatched tasks run on per-device virtual clocks; the server
            # wakes at the NEXT completion event instead of the barrier
            done_at = np.where(ok, self.sim_time + t_cost,
                               np.asarray(self.fleet.busy_until))
            self.fleet = self.fleet.replace(busy_until=done_at)
            pending = done_at[done_at > self.sim_time + 1e-9]
            t_step = (float(pending.min()) - self.sim_time) if len(pending) \
                else 0.0
            idle_time = 0.0                # no barrier: no straggler wait
        else:
            t_step = t_round
            idle_time = float(np.sum(t_round - t_cost, where=ok, initial=0.0))
        # contribution to global-model progress ~ data x submodel depth
        useful = float(np.sum(
            (np.asarray(self.fleet.data_size) / 1000.0)
            * np.asarray(cfg.model_fractions)[m_idx], where=ok, initial=0.0))

        self.progress += 0.25 * useful
        new_acc = self.proxy(self.progress)
        e_now = fleet_total_remaining(self.fleet)
        w1, w2, w3 = cfg.reward_weights
        # event-time reward: the time term pays the elapsed virtual time of
        # THIS event (the barrier in sync mode, the event gap in async)
        reward = (w1 * (new_acc - self.acc) - w2 * (self.e_prev - e_now)
                  - w3 * (t_step / 60.0))
        self.acc, self.e_prev = new_acc, e_now
        self.t += 1
        self.sim_time += t_step
        done = (self.t >= cfg.n_rounds
                or not bool(np.asarray(self.fleet.alive).any()))
        info = {"acc": self.acc, "energy": e_now, "round_time": t_round,
                "alive": int(np.asarray(self.fleet.alive).sum()),
                "dropouts": dropouts, "sim_time": self.sim_time,
                "idle_time": idle_time}
        return self._obs(), float(reward), done, info
