"""FL server: global-model bookkeeping, aggregation dispatch, evaluation.

Aggregation arms:
* DR-FL      — layer-aligned masked averaging (paper Step 2); optionally
               staleness-aware (FedAsync-style per-exit-layer decay) for
               updates arriving late under the async round engine
* HeteroFL   — width-slice scatter averaging
* ScaleFL    — depth+width scatter averaging (structure-tolerant)

Model-specific structure (masks, aggregation groups, stack templates,
evaluation forward passes) is delegated to the pluggable
:class:`repro.models.family.ModelFamily`; every entry point takes an
optional ``family`` (name or instance) and defaults to the registered
default family, so existing flat callsites keep working unchanged.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core.aggregation import (DELTA_MAG_CAP, delta_valid,
                                    layerwise_aggregate, sanitize_delta,
                                    tree_path_align, tree_path_items)
from repro.models.family import resolve_family


# ---------------------------------------------------------------------------
# evaluation (paper: small validation set on the cloud server)
# ---------------------------------------------------------------------------


def evaluate(params, x_val: np.ndarray, y_val: np.ndarray,
             batch: int = 256, family=None) -> np.ndarray:
    """Per-exit accuracy on the server validation set."""
    eval_batch = resolve_family(family).eval_fn()
    accs, n = [], 0
    for i in range(0, len(x_val), batch):
        xb = jnp.asarray(x_val[i:i + batch])
        yb = jnp.asarray(y_val[i:i + batch])
        # jaxlint: allow(host-sync-in-hot-path) -- one pull per eval batch; evaluate returns host accuracies by contract
        accs.append(np.asarray(eval_batch(params, xb, yb)) * len(xb))
        n += len(xb)
    return np.sum(accs, axis=0) / max(n, 1)


# ---------------------------------------------------------------------------
# DR-FL layer-aligned aggregation (list-based parity reference)
# ---------------------------------------------------------------------------


def staleness_scale(staleness: float, decay: float = 0.5) -> float:
    """FedAsync-style polynomial staleness discount: (1 + s)^(-decay).

    ``s`` counts how many aggregations advanced the global model between a
    client's dispatch and the arrival of its delta; s = 0 (fresh) maps to
    exactly 1.0, so the sync path is bit-for-bit unaffected."""
    if staleness <= 0:
        return 1.0
    return float((1.0 + float(staleness)) ** (-float(decay)))


def aggregate_drfl(global_params, deltas: List, model_idxs: List[int],
                   weights: Sequence[float], server_lr: float = 1.0,
                   staleness: Optional[Sequence[float]] = None,
                   staleness_decay: float = 0.5, family=None,
                   validate: bool = True, mag_cap: float = DELTA_MAG_CAP,
                   with_stats: bool = False):
    """DR-FL layer-aligned aggregation, optionally staleness-aware.

    With ``staleness`` given (one entry per delta: aggregations elapsed
    since that client's dispatch), each stale delta is down-weighted by
    ``staleness_scale(s, staleness_decay)`` APPLIED PER EXIT-LAYER: the
    decay is materialized as an alpha-valued mask over exactly the
    stages/exits the client's submodel holds and multiplied into the delta,
    so a lone stale contributor moves a layer by alpha * update (absolute
    FedAsync damping), not by the full update renormalized.  ``staleness``
    of all zeros (or None) reproduces the synchronous path bit-for-bit.

    ``validate`` quarantines poisoned deltas (non-finite anywhere, or any
    element beyond ``mag_cap``): the offender's mask is zeroed so the
    exact-rescale denominator removes it, and its elements are zeroed so
    nan can't leak through the numerator.  All-valid input is bit-for-bit
    the unvalidated path (mask * 1.0, element-exact ``where``).
    ``with_stats`` additionally returns the [N] device-side validity —
    callers batch the host pull (one device_get at their barrier)."""
    fam = resolve_family(family)
    masks = [fam.update_mask(global_params, m) for m in model_idxs]
    valid = None
    if validate:
        valid = [delta_valid(d, mag_cap) for d in deltas]
        deltas = [sanitize_delta(d) for d in deltas]
        masks = [jax.tree.map(lambda mm: mm * v.astype(jnp.float32), mask)
                 for mask, v in zip(masks, valid)]
    if staleness is not None and any(s > 0 for s in staleness):
        scaled = []
        for d, m, s in zip(deltas, model_idxs, staleness):
            a = staleness_scale(s, staleness_decay)
            if a == 1.0:
                scaled.append(d)
                continue
            smask = fam.update_mask(global_params, m, scale=a)
            scaled.append(jax.tree.map(
                lambda u, sm: (u.astype(jnp.float32) * sm).astype(u.dtype),
                d, smask))
        deltas = scaled
    out = layerwise_aggregate(global_params, deltas, masks, weights,
                              server_lr=server_lr)
    if with_stats:
        return out, (jnp.stack(valid) if valid is not None else None)
    return out


# ---------------------------------------------------------------------------
# stacked DR-FL aggregation: [N, R, seg] rows -> Pallas layer_agg kernel
# ---------------------------------------------------------------------------
#
# A family's aggregation groups (``family.stack_groups`` — for layer-wise
# trees: stem + stages[i] + exits[i], the units ``family.update_mask``
# masks as wholes) each flatten into consecutive fixed-width segment rows
# (core.aggregation.StackTemplate); the per-client hold masks and staleness
# alphas become a [N, R] mask matrix, and the whole of DR-FL Step 2 is ONE
# fused kernel dispatch (interpret mode on CPU, the MXU kernel on TPU)
# instead of a tree.map over ~60 leaves per client.  The list-based path
# above stays as the parity reference.


@functools.partial(
    jax.jit,
    static_argnames=("family", "model_idxs", "server_lr", "any_stale",
                     "use_kernel", "interpret", "validate", "mag_cap"))
def _stacked_agg_program(global_params, deltas, weights, alphas, *,
                         family, model_idxs, server_lr, any_stale,
                         use_kernel, interpret, validate=True,
                         mag_cap=DELTA_MAG_CAP):
    """The whole of DR-FL Step 2 as ONE jit program: flatten bucket-stacked
    deltas into [N, R, seg] rows, quarantine poisoned rows, masked-mean
    (Pallas kernel on TPU / fused einsum elsewhere), scatter the averaged
    rows back onto the global tree.  Compiled once per (family, bucket
    model indices, padded shapes).

    Quarantine (``validate``): a client row that is non-finite anywhere or
    exceeds ``mag_cap`` gets its mask column zeroed — the denominator's
    exact rescale then removes it from the mean — and its elements zeroed
    (0 * nan = nan, so masking alone cannot keep nan out of the
    numerator).  All-valid input is bit-for-bit the unvalidated program.
    Returns ``(new_params, valid)`` with ``valid`` a [N_total] device bool
    (None when validation is off)."""
    template = family.stack_template(global_params)
    us, row_masks = [], []
    for model_idx, delta in zip(model_idxs, deltas):
        held = family.held_groups(global_params, model_idx)
        u = aggregation.stack_group_rows(family.stack_groups(delta),
                                         template, held,
                                         stacked=True)        # [P, R, seg]
        row_mask = aggregation.group_row_mask(held, template)  # [R]
        us.append(u)
        row_masks.append(
            jnp.broadcast_to(row_mask, (u.shape[0], template.n_rows)))
    u_all = jnp.concatenate(us, axis=0)
    m_all = jnp.concatenate(row_masks, axis=0)
    w_all = jnp.concatenate(weights)
    a_all = jnp.concatenate(alphas) if any_stale else None
    valid = None
    if validate:
        valid = aggregation.stacked_rows_valid(u_all, mag_cap)  # [N_total]
        u_all = jnp.where(valid[:, None, None], u_all, 0.0)
        m_all = m_all * valid[:, None].astype(m_all.dtype)
    rows = aggregation.stacked_masked_mean(
        u_all, m_all, w_all, a_all, interpret=interpret,
        use_kernel=use_kernel)
    new_groups = aggregation.unstack_apply(family.stack_groups(global_params),
                                           rows, template,
                                           server_lr=server_lr)
    return family.unstack_groups(global_params, new_groups), valid


def aggregate_drfl_stacked(global_params, buckets, server_lr: float = 1.0,
                           staleness_decay: float = 0.5,
                           interpret: Optional[bool] = None,
                           use_kernel: Optional[bool] = None, family=None,
                           validate: bool = True,
                           mag_cap: float = DELTA_MAG_CAP,
                           with_stats: bool = False):
    """DR-FL layer-aligned aggregation over bucket-stacked deltas.

    ``buckets``: iterable of ``(model_idx, stacked_delta, weights,
    staleness)`` where ``stacked_delta`` is the submodel pytree with a
    leading participant axis ``[P, ...]`` (repro.fl.batch.BucketResult —
    pow2-padded rows carry weight 0.0 and drop out of the weighted mean
    exactly), ``weights`` has P data sizes, and ``staleness`` is None or P
    counts.  Staleness alphas are folded into the mask matrix numerator
    with the denominator kept at the 0/1 hold mask (absolute FedAsync
    damping, same semantics as :func:`aggregate_drfl`); all-fresh input
    skips the rescale so it is exactly the plain masked mean.

    ``validate``/``mag_cap``: see :func:`_stacked_agg_program` (quarantine
    of poisoned rows; padded rows with garbage contents are harmless either
    way — their weight is already 0 — but quarantine also zeroes them, so
    a non-finite pad row can no longer poison the numerator).
    ``with_stats`` returns ``(params, valid)`` with the [N_total] row
    validity left ON DEVICE — callers batch the pull."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    fam = resolve_family(family)
    model_idxs, deltas, ws, alphas = [], [], [], []
    any_stale = False
    for model_idx, delta, weights, stal in buckets:
        p = len(weights)
        model_idxs.append(int(model_idx))
        deltas.append(delta)
        ws.append(jnp.asarray([float(x) for x in weights], jnp.float32))
        if stal is None:
            alphas.append(jnp.ones((p,), jnp.float32))
        else:
            scales = [staleness_scale(s, staleness_decay) for s in stal]
            any_stale = any_stale or any(a != 1.0 for a in scales)
            alphas.append(jnp.asarray(scales, jnp.float32))
    if not deltas:
        return (global_params, None) if with_stats else global_params
    out, valid = _stacked_agg_program(
        global_params, tuple(deltas), tuple(ws), tuple(alphas),
        family=fam, model_idxs=tuple(model_idxs),
        server_lr=float(server_lr), any_stale=any_stale,
        use_kernel=bool(use_kernel), interpret=interpret,
        validate=bool(validate), mag_cap=float(mag_cap))
    return (out, valid) if with_stats else out


def aggregate_drfl_from_list(global_params, deltas: List,
                             model_idxs: List[int],
                             weights: Sequence[float],
                             server_lr: float = 1.0,
                             staleness: Optional[Sequence[float]] = None,
                             staleness_decay: float = 0.5,
                             interpret: Optional[bool] = None,
                             use_kernel: Optional[bool] = None,
                             family=None, validate: bool = True,
                             mag_cap: float = DELTA_MAG_CAP,
                             with_stats: bool = False):
    """Stacked-kernel aggregation over FULL-STRUCTURE delta pytrees (the
    list-based :func:`aggregate_drfl` contract) — each delta becomes a
    P=1 bucket.  Used for parity testing the kernel path against the
    list-based reference on identical inputs."""
    fam = resolve_family(family)
    buckets = []
    for j, (d, m) in enumerate(zip(deltas, model_idxs)):
        sub = fam.submodel_tree(d, m)
        stal = None if staleness is None else [staleness[j]]
        buckets.append((m, jax.tree.map(lambda a: a[None], sub),
                        [weights[j]], stal))
    return aggregate_drfl_stacked(global_params, buckets,
                                  server_lr=server_lr,
                                  staleness_decay=staleness_decay,
                                  interpret=interpret,
                                  use_kernel=use_kernel, family=fam,
                                  validate=validate, mag_cap=mag_cap,
                                  with_stats=with_stats)


# ---------------------------------------------------------------------------
# HeteroFL / ScaleFL aggregation (width / depth+width scatter)
# ---------------------------------------------------------------------------


def _scatter_avg(gp, contribs):
    """contribs: list of (delta_leaf, weight); delta may be channel-sliced."""
    num = jnp.zeros(gp.shape, jnp.float32)
    den = jnp.zeros(gp.shape, jnp.float32)
    for u, w in contribs:
        pad = [(0, gs - us) for gs, us in zip(gp.shape, u.shape)]
        num = num + w * jnp.pad(u.astype(jnp.float32), pad)
        den = den + w * jnp.pad(jnp.ones(u.shape, jnp.float32), pad)
    avg = jnp.where(den > 0, num / jnp.maximum(den, 1e-12), 0.0)
    return (gp.astype(jnp.float32) + avg).astype(gp.dtype)


def aggregate_sliced(global_params, deltas: List, weights: Sequence[float],
                     validate: bool = True,
                     mag_cap: float = DELTA_MAG_CAP,
                     with_stats: bool = False):
    """Structure- and shape-tolerant scatter aggregation (HeteroFL/ScaleFL).

    Contributions are collected per TREE PATH: a client's (possibly
    depth-truncated, width-sliced) delta subtree is aligned against the
    global tree position-by-position, so aliased leaves — the same array
    object reachable at two paths, which an ``id()``-keyed table would
    silently merge — stay independent aggregation targets.

    ``validate`` quarantines poisoned deltas exactly as
    :func:`aggregate_drfl` does: the client's weight is scaled by its
    device-side validity (0 drops it from numerator AND denominator, and
    the shared total cancels, so surviving clients are renormalized
    exactly) and non-finite elements are zeroed."""
    valid = None
    if validate:
        valid = [delta_valid(d, mag_cap) for d in deltas]
        deltas = [sanitize_delta(d) for d in deltas]
    table: Dict[tuple, list] = {
        path: [] for path, _ in tree_path_items(global_params)}
    for j, (d, w) in enumerate(zip(deltas, weights)):
        wj = float(w) if valid is None else float(w) * valid[j].astype(
            jnp.float32)
        for path, leaf in tree_path_align(global_params, d):
            if leaf is not None:
                table[path].append((leaf, wj))
    wtot = float(sum(weights)) or 1.0

    def rebuild(gp, path=()):
        if isinstance(gp, dict):
            return {k: rebuild(v, path + (k,)) for k, v in gp.items()}
        if isinstance(gp, (list, tuple)):
            t = [rebuild(v, path + (i,)) for i, v in enumerate(gp)]
            return t if isinstance(gp, list) else tuple(t)
        contribs = table[path]
        if not contribs:
            return gp
        contribs = [(u, w / wtot) for u, w in contribs]
        return _scatter_avg(gp, contribs)

    out = rebuild(global_params)
    if with_stats:
        return out, (jnp.stack(valid) if valid is not None else None)
    return out
