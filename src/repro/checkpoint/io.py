"""Pytree checkpoints: msgpack + zstd, path-keyed leaves.

Format: a zstd-compressed msgpack map
    {"__meta__": {"version": 1}, "<leaf path>": {"dtype","shape","data"}}
Restoring requires a template pytree (shapes/structure are validated) —
this catches silent arch/config drift between save and load.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:          # offline container: fall back to stdlib zlib
    zstd = None
import zlib

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"

# Leaf paths a FleetState checkpoint carries, in pytree order — kept in
# lockstep with ``repro.core.fleet._ARRAY_FIELDS`` (set-equality enforced
# by the ``pytree-field-coverage`` jaxlint rule, so a field added to the
# fleet cannot silently drop out of checkpoints).
FLEET_CHECKPOINT_FIELDS = ("compute", "p_train", "p_com", "bandwidth",
                           "battery", "remaining", "data_size",
                           "mode_compute", "mode_power", "alive",
                           "busy_until", "charge_rate", "tz_phase")


def _compress(raw: bytes) -> bytes:
    if zstd is not None:
        return zstd.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def _decompress(blob: bytes) -> bytes:
    # Sniff the frame magic so either codec's checkpoints load regardless of
    # which library is installed now.
    if blob[:4] == _ZSTD_MAGIC:
        if zstd is None:
            raise RuntimeError("checkpoint is zstd-compressed but the "
                               "zstandard package is not installed")
        return zstd.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# jaxlint: allow(host-sync-in-hot-path) -- checkpoint save is an explicit
# barrier: every leaf must land on the host to persist
def save_pytree(path: str, tree: Any, step: Optional[int] = None) -> str:
    if step is not None:
        os.makedirs(path, exist_ok=True)
        path = os.path.join(path, f"step_{step:08d}.ckpt")
    payload = {"__meta__": {"version": 1}}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for kp, leaf in leaves:
        key = _path_str(kp)
        if key in payload:
            raise ValueError(f"duplicate leaf path {key!r}: two pytree "
                             "leaves flatten to the same checkpoint key")
        arr = np.asarray(leaf)
        payload[key] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    raw = msgpack.packb(payload, use_bin_type=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(_compress(raw))
    os.replace(tmp, path)
    return path


def read_payload(path: str) -> dict:
    """Decompress + unpack a checkpoint file into its raw payload map.

    Raises ValueError on truncated or corrupted files (codec / msgpack
    errors are chained) so callers get one predictable error type.
    """
    with open(path, "rb") as f:
        blob = f.read()
    try:
        raw = _decompress(blob)
        payload = msgpack.unpackb(raw, raw=False)
    except RuntimeError:
        raise                      # zstd-without-library: keep the message
    except Exception as e:
        raise ValueError(f"corrupt or truncated checkpoint {path!r}: "
                         f"{type(e).__name__}: {e}") from e
    if not isinstance(payload, dict) or "__meta__" not in payload:
        raise ValueError(f"corrupt checkpoint {path!r}: missing __meta__")
    return payload


def load_pytree(path: str, template: Any, backend: str = "jax") -> Any:
    payload = read_payload(path)
    leaves = jax.tree_util.tree_flatten_with_path(template)
    kps, tmpl_leaves = zip(*leaves[0]) if leaves[0] else ((), ())
    treedef = jax.tree_util.tree_structure(template)
    out = []
    for kp, tl in zip(kps, tmpl_leaves):
        key = _path_str(kp)
        if key not in payload:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        rec = payload[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        arr = arr.reshape(rec["shape"])
        if tuple(arr.shape) != tuple(np.shape(tl)):
            raise ValueError(f"shape mismatch at {key}: ckpt {arr.shape} "
                             f"vs template {np.shape(tl)}")
        want = np.dtype(getattr(tl, "dtype", None) or np.asarray(tl).dtype)
        if arr.dtype != want:
            raise ValueError(f"dtype mismatch at {key}: ckpt {arr.dtype} "
                             f"vs template {want}")
        out.append(jnp.asarray(arr) if backend == "jax" else arr.copy())
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    best, best_step = None, -1
    for name in os.listdir(ckpt_dir):
        m = re.match(r"step_(\d+)\.ckpt$", name)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(ckpt_dir, name), int(m.group(1))
    return best
