"""Energy-aware federated LM training over the early-exit transformer
family (``model_family="transformer"``, docs/FAMILIES.md).

The paper's dual-selection workflow on a language task: a fleet of
battery-powered devices trains the early-exit decoder on the synthetic
next-token corpus; each round the selector picks WHO participates and
WHICH depth prefix (Model_1..Model_4) each client trains, and the server
layer-align aggregates the zero-filled deltas.

    PYTHONPATH=src python examples/train_lm.py                    # MARL
    PYTHONPATH=src python examples/train_lm.py --selector greedy \
        --rounds 12 --devices 16 --ckpt /tmp/lm.msgpack

``--ckpt`` saves the final global params for ``examples/serve_lm.py``
(early-exit greedy decoding from the same tree).

``--local`` skips the fleet and runs plain local DR-FL client updates on
one simulated device per depth — the smallest possible demo of
``family.client_update`` + ``aggregate_drfl`` without the engine.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.checkpoint import save_pytree
from repro.fl import FLConfig, run_simulation
from repro.fl import server as fl_server
from repro.models.family import get_family


def run_local(args):
    """Engine-free mini round-loop: one client per depth prefix."""
    fam = get_family("transformer")
    x, y = fam.make_dataset(args.n_train, 10, hw=args.seq, noise=1.0,
                            seed=args.seed)
    n_val = max(64, args.n_train // 10)
    x_val, y_val = x[:n_val], y[:n_val]
    x_tr, y_tr = x[n_val:], y[n_val:]
    gp = fam.init(jax.random.PRNGKey(args.seed), 10, width_mult=args.width,
                  hw=args.seq)
    M = fam.num_submodels()
    shards = np.array_split(np.arange(len(x_tr)), M)
    for rnd in range(args.rounds):
        deltas, idxs, weights, losses = [], [], [], []
        for m in range(M):
            sh = shards[m]
            d, loss = fam.client_update(
                "drfl", gp, m, x_tr[sh], y_tr[sh], epochs=args.epochs,
                batch=args.batch, lr=args.lr, seed=args.seed + rnd * M + m)
            deltas.append(d)
            idxs.append(m)
            weights.append(float(len(sh)))
            losses.append(loss)
        gp = fl_server.aggregate_drfl(gp, deltas, idxs, weights,
                                      server_lr=0.7, family=fam)
        accs = np.asarray(fl_server.evaluate(gp, x_val, y_val, family=fam))
        print(f"round {rnd:3d} losses={np.round(losses, 3)} "
              f"exit accs={np.round(accs, 3)}")
    return gp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--selector", default="marl",
                    choices=["marl", "greedy", "random", "static"])
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--participation", type=float, default=0.5)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seq", type=int, default=8,
                    help="context window length (cfg.hw)")
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--n-train", type=int, default=1200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--async", dest="engine_async", action="store_true",
                    help="event-driven async rounds instead of sync barriers")
    ap.add_argument("--local", action="store_true",
                    help="engine-free client_update/aggregate demo")
    ap.add_argument("--ckpt", default=None,
                    help="save final global params (msgpack) for serve_lm")
    args = ap.parse_args(argv)

    if args.local:
        gp = run_local(args)
    else:
        cfg = FLConfig(
            n_devices=args.devices, n_rounds=args.rounds,
            participation=args.participation, local_epochs=args.epochs,
            batch_size=args.batch, lr=args.lr, n_train=args.n_train,
            hw=args.seq, width_mult=args.width, seed=args.seed,
            model_family="transformer", method="drfl",
            selector=args.selector, energy_scale=0.05,
            engine_mode="async" if args.engine_async else "sync")
        t0 = time.time()
        h = run_simulation(cfg, verbose=True)
        print(f"\n{cfg.engine_mode} run: {len(h['acc_mean'])} evals in "
              f"{time.time() - t0:.1f}s wall; final mean exit acc "
              f"{h['acc_mean'][-1]:.3f}, fleet energy left "
              f"{h['energy'][-1]:,.0f} J, dropouts {h['dropouts']}")
        gp = h["params"]

    if args.ckpt:
        save_pytree(args.ckpt, gp)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
