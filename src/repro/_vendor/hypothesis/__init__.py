"""Minimal offline stand-in for the ``hypothesis`` property-testing API.

Only importable when the real package is missing: ``tests/conftest.py``
prepends this directory to ``sys.path`` *iff* ``import hypothesis`` fails,
so an installed hypothesis always wins.

Supported surface (what the repo's tests use):

* ``@given(**kwargs)`` — draws ``max_examples`` deterministic examples per
  test from the supplied strategies and runs the test once per example.
  Seeding derives from the test's qualified name, so failures reproduce.
* ``@settings(max_examples=..., deadline=...)`` — ``max_examples`` is
  honoured; everything else is accepted and ignored.
* ``assume(cond)`` — skips the current example when ``cond`` is falsy.
* ``strategies`` — see :mod:`hypothesis.strategies` (integers, floats,
  booleans, sampled_from, just, lists, tuples).

This is NOT shrinking, targeted, or database-backed generation — it is a
deterministic sweep that keeps property tests meaningful offline.
"""
from __future__ import annotations

import enum
import functools
import inspect
import random

from . import strategies  # noqa: F401  (re-export for `hypothesis.strategies`)

__version__ = "0.0.0+repro.fallback"
_SETTINGS_ATTR = "_repro_fallback_settings"
_DEFAULT_MAX_EXAMPLES = 10


class _Unsatisfied(Exception):
    """Raised by assume() to discard the current example."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class HealthCheck(enum.Enum):  # accepted by settings(suppress_health_check=...)
    too_slow = 1
    filter_too_much = 2
    data_too_large = 3


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator recording ``max_examples``; order with @given is free."""

    def deco(fn):
        setattr(fn, _SETTINGS_ATTR, {"max_examples": int(max_examples)})
        return fn

    return deco


def given(**strategy_kwargs):
    """Deterministic-sweep replacement for hypothesis.given."""
    for name, strat in strategy_kwargs.items():
        if not isinstance(strat, strategies.SearchStrategy):
            raise TypeError(f"@given argument {name!r} is not a strategy: "
                            f"{strat!r}")

    def deco(fn):

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, _SETTINGS_ATTR, None) \
                or getattr(fn, _SETTINGS_ATTR, None) \
                or {"max_examples": _DEFAULT_MAX_EXAMPLES}
            # Seed from the test identity (sha-based for str seeds: stable
            # across processes, unlike hash()).
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            ran = 0
            for _ in range(max(1, cfg["max_examples"]) * 5):
                if ran >= cfg["max_examples"]:
                    break
                drawn = {name: strat.example(rng)
                         for name, strat in strategy_kwargs.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except _Unsatisfied:
                    continue
                ran += 1
            return None

        # pytest must not see the strategy-drawn parameters (it would demand
        # fixtures for them): hide the original signature and publish one
        # with those parameters removed.
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strategy_kwargs])
        # NOTE: deliberately no `wrapper.hypothesis` attribute — pytest's
        # builtin hypothesis integration introspects it and would break.
        return wrapper

    return deco
