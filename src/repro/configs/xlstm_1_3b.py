"""xLSTM-1.3B — sLSTM + mLSTM blocks [arXiv:2405.04517]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    head_dim=512,
    ssm_expand=2, ssm_chunk=64,
    exit_points=(12, 24, 36, 48),
    source="arXiv:2405.04517",
)
