"""Kernel microbenchmarks: Pallas (interpret on CPU — structural check) vs
the pure-jnp reference, plus the XLA fallback attention in the model.

On CPU the interpret-mode numbers are NOT performance claims; the derived
column records bytes/flops so the TPU roofline expectation is visible."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, emit
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.layer_agg import layer_agg_op, layer_agg_ref
from repro.kernels.rmsnorm import rmsnorm_op, rmsnorm_ref


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def main():
    key = jax.random.PRNGKey(0)
    B, S, H, D = (1, 256, 4, 64) if FAST else (4, 1024, 8, 128)
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k, v = q + 0.1, q - 0.1
    us_ref = _time(lambda a, b, c: attention_ref(
        a.transpose(0, 2, 1, 3).reshape(B * H, S, D),
        b.transpose(0, 2, 1, 3).reshape(B * H, S, D),
        c.transpose(0, 2, 1, 3).reshape(B * H, S, D)), q, k, v)
    flops = 4 * B * H * S * S * D
    emit("kernels/attention_ref_xla", us_ref, f"flops={flops:.3g}")
    us_pal = _time(lambda a, b, c: flash_attention(a, b, c, interpret=True,
                                                   block_q=128, block_k=128),
                   q, k, v)
    emit("kernels/flash_attention_interp", us_pal,
         f"flops={flops:.3g};note=interpret-mode-structural")

    N, L, Dd = (8, 8, 4096) if FAST else (32, 60, 65536)
    U = jax.random.normal(key, (N, L, Dd))
    M = (jax.random.uniform(key, (N, L)) > 0.3).astype(jnp.float32)
    w = jnp.ones((N,))
    us = _time(lambda a, b, c: layer_agg_ref(a, b, c), U, M, w)
    emit("kernels/layer_agg_ref_xla", us, f"bytes={U.size * 4:.3g}")
    us = _time(lambda a, b, c: layer_agg_op(a, b, c, interpret=True), U, M, w)
    emit("kernels/layer_agg_interp", us, f"bytes={U.size * 4:.3g}")

    x = jax.random.normal(key, (512, 1024), jnp.float32)
    s = jnp.ones((1024,))
    us = _time(lambda a, b: rmsnorm_ref(a, b), x, s)
    emit("kernels/rmsnorm_ref_xla", us, f"bytes={x.size * 4:.3g}")
    us = _time(lambda a, b: rmsnorm_op(a, b, interpret=True), x, s)
    emit("kernels/rmsnorm_interp", us, f"bytes={x.size * 4:.3g}")


if __name__ == "__main__":
    main()
