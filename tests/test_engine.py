"""Event-driven round engine (repro.fl.engine) vs the frozen reference loop.

Contracts:
* ``engine_mode="sync"`` reproduces the legacy synchronous round loop
  (``simulation._run_once_reference``) BIT-FOR-BIT — acc per exit, energy
  ledger, round times, participant sets, rewards — for both the greedy and
  the MARL selector, with and without hot-plug.
* ``engine_mode="async"`` does the same amount of client work without a
  round barrier: staleness-aware per-event aggregation, strictly lower
  straggler wait, hot-plug as a timeline event (full batteries, current
  global model, Top-K repriced at the join).
* staleness-aware ``aggregate_drfl`` damps stale deltas by (1+s)^-decay
  and leaves fresh (s=0) aggregation bit-for-bit unchanged.
* client-update seeds are collision-free across (round, device) — the old
  ``base*1000 + t*100 + i`` mix collided for any 100+ device fleet.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import FLConfig, run_simulation
from repro.fl import client as fl_client
from repro.fl import server as fl_server
from repro.fl.simulation import _run_once_reference
from repro.models import cnn

PARITY_KEYS = ("acc_mean", "energy", "round_time", "alive", "participants",
               "model_choices", "reward", "dropouts")


def _assert_parity(h_engine, h_ref):
    for key in PARITY_KEYS:
        assert h_engine[key] == h_ref[key], key
    for a, b in zip(h_engine["acc"], h_ref["acc"]):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(h_engine["final_acc"], h_ref["final_acc"])


# ---------------------------------------------------------------------------
# sync mode: bit-for-bit parity with the frozen reference loop
# ---------------------------------------------------------------------------


def test_sync_parity_greedy_with_hotplug():
    cfg = FLConfig(n_devices=5, n_rounds=4, participation=0.6, n_train=600,
                   local_epochs=1, method="drfl", selector="greedy", seed=4,
                   hotplug_round=2, hotplug_n=3)
    h_engine = run_simulation(cfg)
    h_ref, _, _ = _run_once_reference(cfg)
    _assert_parity(h_engine, h_ref)


def test_sync_parity_marl():
    cfg = FLConfig(n_devices=6, n_rounds=4, participation=0.5, n_train=500,
                   local_epochs=1, method="drfl", selector="marl", seed=0)
    h_engine = run_simulation(cfg)
    h_ref, _, _ = _run_once_reference(cfg)
    _assert_parity(h_engine, h_ref)


def test_sync_parity_baseline_method():
    cfg = FLConfig(n_devices=6, n_rounds=2, participation=0.5, n_train=500,
                   local_epochs=1, method="heterofl", seed=1)
    h_engine = run_simulation(cfg)
    h_ref, _, _ = _run_once_reference(cfg)
    _assert_parity(h_engine, h_ref)


def test_sync_reports_straggler_wait():
    cfg = FLConfig(n_devices=6, n_rounds=3, participation=0.5, n_train=500,
                   local_epochs=1, method="drfl", selector="greedy", seed=1)
    h = run_simulation(cfg)
    # heterogeneous tiers: some participant always outpaces the straggler
    assert h["engine"] == "sync"
    assert h["idle_time"] > 0.0
    assert len(h["idle"]) == len(h["round_time"])
    assert h["sim_time_total"] == pytest.approx(sum(h["round_time"]))


# ---------------------------------------------------------------------------
# async mode: event timeline
# ---------------------------------------------------------------------------


def _async_cfg(**kw):
    base = dict(n_devices=8, n_rounds=4, participation=0.5, n_train=600,
                local_epochs=1, method="drfl", selector="greedy", seed=1,
                engine_mode="async")
    base.update(kw)
    return FLConfig(**base)


def test_async_same_work_lower_straggler_wait():
    cfg = _async_cfg()
    h_sync = run_simulation(dataclasses.replace(cfg, engine_mode="sync"))
    h_async = run_simulation(cfg)
    # same client-task budget as the sync run dispatched at most...
    assert h_async["n_tasks"] <= cfg.n_rounds * 4
    assert h_async["n_tasks"] == sum(len(p) for p in h_async["participants"])
    # ... finished in no more simulated time, with strictly less idle
    assert h_async["sim_time_total"] <= h_sync["sim_time_total"] + 1e-6
    assert h_sync["idle_time"] > 0.0
    assert h_async["idle_time"] < h_sync["idle_time"]
    assert np.isfinite(h_async["acc_mean"]).all()
    # per-event aggregation: one version bump per arriving update
    assert h_async["n_aggregations"] == len(h_async["staleness"])


def test_async_staleness_observed_and_bounded():
    h = run_simulation(_async_cfg())
    stale = np.asarray(h["staleness"])
    assert (stale >= 0).all()
    # overlapping tasks mean SOME update lands late
    assert stale.max() >= 1
    assert stale.max() < h["n_aggregations"]


def test_async_respects_time_horizon():
    cfg = _async_cfg()
    h_full = run_simulation(cfg)
    horizon = h_full["sim_time_total"] * 0.5
    h_cut = run_simulation(dataclasses.replace(
        cfg, async_time_horizon=horizon))
    assert h_cut["sim_time_total"] <= horizon + 1e-6
    assert h_cut["n_tasks"] < h_full["n_tasks"]


def test_async_marl_arm_runs():
    cfg = _async_cfg(selector="marl", n_devices=6, participation=0.5, seed=0)
    h = run_simulation(cfg)
    assert h["n_tasks"] > 0
    assert np.isfinite(h["reward"]).all()


def test_async_marl_custom_task_budget():
    # a budget larger than the sync equivalent must size the replay buffer
    # from the ACTUAL budget (regression: episode overflow at add_episode)
    cfg = _async_cfg(selector="marl", n_devices=6, participation=0.5, seed=0,
                     async_task_budget=30)
    h = run_simulation(cfg)
    assert 0 < h["n_tasks"] <= 30


def test_async_energy_ledger_monotone():
    h = run_simulation(_async_cfg())
    e = h["energy"]
    assert all(e[i + 1] <= e[i] + 1e-6 for i in range(len(e) - 1))
    assert e[-1] >= 0.0


# ---------------------------------------------------------------------------
# hot-plug as a timeline event (ISSUE 2 satellite)
# ---------------------------------------------------------------------------


def test_async_hotplug_joins_on_timeline_event():
    cfg = _async_cfg(n_devices=5, participation=1.0, n_rounds=6, seed=4,
                     hotplug_round=2, hotplug_n=3, energy_scale=0.5)
    h = run_simulation(cfg)
    hp = h["hotplug"]
    assert hp is not None
    # joins with FULL (scaled) batteries at the join event
    from repro.core.energy import BATTERY_JOULES
    assert len(hp["join_remaining"]) == 3
    for r in hp["join_remaining"]:
        assert r == pytest.approx(BATTERY_JOULES * 0.5, rel=0.25)
    # Top-K k is repriced on the join event itself: 5 -> 8 connected
    assert hp["k_before"] == 5
    assert hp["k_after"] == 8
    assert h["k_final"] == 8
    # joined devices are dispatched, and every task they run was sent with
    # the CURRENT global model (a snapshot no older than the join version)
    join_tasks = [t for t in h["task_log"] if t["device"] >= 5]
    assert join_tasks, "hot-plug devices never participated"
    assert all(t["version"] >= hp["version"] for t in join_tasks)
    assert all(t["t_dispatch"] >= hp["sim_time"] - 1e-9 for t in join_tasks)
    # the join event itself opens dispatch slots: with full batteries and
    # greedy energy-ordered Top-K, a joiner is dispatched AT the join time
    assert any(t["t_dispatch"] == pytest.approx(hp["sim_time"])
               for t in join_tasks)


def test_async_hotplug_joins_even_when_initial_fleet_stalls():
    """If the initial fleet drains before the join boundary, the event heap
    empties with no completion left to advance the virtual round — but sync
    mode reaches the join by ticking empty rounds, so async must force the
    hot-plug rather than strand fresh-battery joiners offline."""
    cfg = _async_cfg(n_devices=4, participation=1.0, n_rounds=6, seed=0,
                     hotplug_round=4, hotplug_n=3, energy_scale=0.001)
    h = run_simulation(cfg)
    hp = h["hotplug"]
    assert hp is not None
    # the join fired before the boundary round count was ever reached
    assert hp["vround"] < 4
    # and the joiners actually took work
    assert any(t["device"] >= 4 for t in h["task_log"])


# ---------------------------------------------------------------------------
# FLEnv event-time mode (repro.fl.environment)
# ---------------------------------------------------------------------------


def test_fl_env_async_event_time():
    from repro.fl.environment import FLEnv, FLEnvConfig
    env = FLEnv(FLEnvConfig(n_devices=6, n_rounds=4, seed=0, mode="async"))
    env.reset()
    _, r0, _, i0 = env.step(np.full(6, 0))
    # everyone got dispatched; the clock advanced to the FIRST completion,
    # not the barrier, and there is no straggler wait
    assert 0.0 < i0["sim_time"] < i0["round_time"]
    assert i0["idle_time"] == 0.0
    # mid-task devices auto-abstain: re-issuing actions spends energy only
    # for devices whose virtual clock has freed up
    e_before = i0["energy"]
    _, _, _, i1 = env.step(np.full(6, 0))
    busy_spend = e_before - i1["energy"]
    env_sync = FLEnv(FLEnvConfig(n_devices=6, n_rounds=4, seed=0,
                                 mode="sync"))
    env_sync.reset()
    _, _, _, s0 = env_sync.step(np.full(6, 0))
    _, _, _, s1 = env_sync.step(np.full(6, 0))
    assert busy_spend < (s0["energy"] - s1["energy"])
    assert s0["idle_time"] > 0.0
    assert s0["sim_time"] == pytest.approx(s0["round_time"])


# ---------------------------------------------------------------------------
# staleness-aware aggregation (repro.fl.server)
# ---------------------------------------------------------------------------


def _tiny_params_and_delta():
    params = cnn.init(jax.random.PRNGKey(0), 10, width_mult=0.25)
    delta = jax.tree.map(jnp.ones_like, params)
    return params, delta


def test_staleness_scale_values():
    assert fl_server.staleness_scale(0, 0.5) == 1.0
    assert fl_server.staleness_scale(3, 0.5) == pytest.approx(0.5)
    assert fl_server.staleness_scale(1, 1.0) == pytest.approx(0.5)
    s = [fl_server.staleness_scale(i, 0.5) for i in range(5)]
    assert s == sorted(s, reverse=True)      # monotone damping


def test_aggregate_drfl_fresh_staleness_bitexact():
    params, delta = _tiny_params_and_delta()
    ref = fl_server.aggregate_drfl(params, [delta], [1], [1.0])
    got = fl_server.aggregate_drfl(params, [delta], [1], [1.0],
                                   staleness=[0], staleness_decay=0.5)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_aggregate_drfl_stale_update_damped_per_layer():
    params, delta = _tiny_params_and_delta()
    fresh = fl_server.aggregate_drfl(params, [delta], [1], [1.0],
                                     staleness=[0])
    stale = fl_server.aggregate_drfl(params, [delta], [1], [1.0],
                                     staleness=[3], staleness_decay=0.5)
    alpha = fl_server.staleness_scale(3, 0.5)
    # held layers: the applied step shrinks by exactly alpha (absolute
    # FedAsync damping, not renormalized away)
    for gp, f, s in zip(jax.tree.leaves(params["stem"]),
                        jax.tree.leaves(fresh["stem"]),
                        jax.tree.leaves(stale["stem"])):
        np.testing.assert_allclose(np.asarray(s - gp),
                                   alpha * np.asarray(f - gp), rtol=1e-5)
    # layers outside the submodel stay untouched either way
    for gp, s in zip(jax.tree.leaves(params["stages"][3]),
                     jax.tree.leaves(stale["stages"][3])):
        np.testing.assert_array_equal(np.asarray(gp), np.asarray(s))


# ---------------------------------------------------------------------------
# collision-free client-update seeds (ISSUE 2 satellite)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_async_bench_256_acceptance():
    """ISSUE 2 acceptance: at n=256 the async engine finishes the same
    simulated-time budget as sync with strictly lower straggler wait."""
    from benchmarks.async_bench import main
    r = main(n=256)
    assert r["async"]["sim_time_total"] <= r["horizon"] + 1e-6
    assert r["async"]["idle_time"] < r["sync"]["idle_time"]
    assert r["async"]["n_tasks"] > 0


def test_client_update_seed_collision_free():
    # the old mix (seed*1000 + t*100 + i) collided whenever i >= 100:
    # (t=0, i=100) == (t=1, i=0).  The SeedSequence mix must not.
    seeds = {fl_client.client_update_seed(0, t, i)
             for t in range(40) for i in range(300)}
    assert len(seeds) == 40 * 300
    # and distinct base seeds do not collide either on a spot-check grid
    other = {fl_client.client_update_seed(1, t, i)
             for t in range(40) for i in range(300)}
    assert not (seeds & other)
