"""Llama-3.2-Vision-style VLM backbone (hf:meta-llama/Llama-3.2-11B-Vision).

40 decoder layers = 8 groups of (4 self-attn layers + 1 gated cross-attn
layer).  The vision frontend (ViT + projector) is a **stub** per the
assignment carve-out: ``image_embeds`` arrive as precomputed patch
embeddings ``[B, num_image_tokens, d_model]``.

Scan structure: outer scan over the 8 groups; inner scan over the 4 self
layers of each group.  Cross layers use tanh-gated residuals (zero-init
gates, as in the reference model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.rules import constrain
from repro.models import transformer as T


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def group_shape(cfg):
    k = cfg.cross_attn_every
    n_self_per_group = k - 1
    n_groups = cfg.num_layers // k
    assert n_groups * k == cfg.num_layers
    return n_groups, n_self_per_group


def cross_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attention_init(ks[0], cfg, dtype, cross=True),
        "mlp_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
        "gate_attn": jnp.zeros((), jnp.float32),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


def cross_block_apply(p, cfg, x, img, gate, *, cache=None):
    h = L.rmsnorm_apply(p["attn_norm"], x, cfg.norm_eps)
    a, new_cache = L.attention_apply(p["attn"], cfg, h, jnp.arange(x.shape[1]),
                                     causal=False, kv_src=img, cache=cache,
                                     norm_eps=cfg.norm_eps)
    x = x + gate * jnp.tanh(p["gate_attn"]).astype(x.dtype) * a
    h = L.rmsnorm_apply(p["mlp_norm"], x, cfg.norm_eps)
    x = x + gate * jnp.tanh(p["gate_mlp"]).astype(x.dtype) * L.swiglu_apply(p["mlp"], h)
    return x, new_cache


def init(key, cfg):
    dtype = _dt(cfg)
    n_groups, n_self = group_shape(cfg)
    k_emb, k_self, k_cross, k_out = jax.random.split(key, 4)

    def group_self(k):
        return jax.vmap(lambda kk: T.block_init(kk, cfg, dtype))(jax.random.split(k, n_self))

    return {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "self_blocks": jax.vmap(group_self)(jax.random.split(k_self, n_groups)),
        "cross_blocks": jax.vmap(lambda k: cross_block_init(k, cfg, dtype))(
            jax.random.split(k_cross, n_groups)),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "unembed": L.dense_init(k_out, cfg.d_model, cfg.vocab_size, dtype),
    }


def unembed_matrix(params, cfg):
    return params["unembed"]["w"]


def apply(params, cfg, tokens, image_embeds, *, layer_mask=None, window=None,
          use_pallas=False, attn_chunk=0, remat="full"):
    """tokens: [B,S]; image_embeds: [B,T_img,d]."""
    B, S = tokens.shape
    x = params["embed"]["emb"][tokens]
    img = image_embeds.astype(x.dtype)
    positions = jnp.arange(S)
    n_groups, n_self = group_shape(cfg)
    mask = (jnp.ones((cfg.num_layers,), jnp.float32)
            if layer_mask is None else layer_mask.astype(jnp.float32))
    mask = mask.reshape(n_groups, n_self + 1)

    def self_body(x, scanned):
        bp, gate = scanned
        x, _, _ = T.block_apply(bp, cfg, x, positions, gate.astype(x.dtype),
                                window=window, use_pallas=use_pallas,
                                attn_chunk=attn_chunk)
        return x, None

    def group_body(x, scanned):
        sp, cp, gates = scanned
        x, _ = jax.lax.scan(self_body, x, (sp, gates[:n_self]))
        x, _ = cross_block_apply(cp, cfg, x, img, gates[n_self].astype(x.dtype))
        return constrain(x), None

    body = jax.checkpoint(group_body) if remat != "none" else group_body
    x, _ = jax.lax.scan(body, x, (params["self_blocks"], params["cross_blocks"], mask))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def logits_fn(params, cfg, hidden):
    return (hidden @ unembed_matrix(params, cfg)).astype(jnp.float32)


def decode_init(params, cfg, batch: int, seq_len: int, *, window=None,
                image_embeds=None):
    """Self-attn KV caches + precomputed static cross KV per group."""
    w = cfg.window if window is None else window
    clen = min(seq_len, w) if w else seq_len
    dtype = _dt(cfg)
    n_groups, n_self = group_shape(cfg)
    Hkv, hd = cfg.num_kv_heads, cfg.hd
    if image_embeds is None:
        image_embeds = jnp.zeros((batch, cfg.num_image_tokens, cfg.d_model), dtype)

    def cross_kv(cp):
        k = L.dense_apply(cp["attn"]["wk"], image_embeds)
        v = L.dense_apply(cp["attn"]["wv"], image_embeds)
        k = k.reshape(batch, -1, Hkv, hd)
        v = v.reshape(batch, -1, Hkv, hd)
        if "k_norm" in cp["attn"]:
            k = L.rmsnorm_apply(cp["attn"]["k_norm"], k, cfg.norm_eps)
        return {"k": k, "v": v, "pos": jnp.zeros((), jnp.int32)}

    return {
        "self": {
            "k": jnp.zeros((n_groups, n_self, batch, clen, Hkv, hd), dtype),
            "v": jnp.zeros((n_groups, n_self, batch, clen, Hkv, hd), dtype),
            "pos": jnp.zeros((n_groups, n_self), jnp.int32),
        },
        "cross": jax.vmap(cross_kv)(params["cross_blocks"]),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg, cache, tokens, pos, *, layer_mask=None, window=None):
    x = params["embed"]["emb"][tokens]
    n_groups, n_self = group_shape(cfg)
    mask = (jnp.ones((cfg.num_layers,), jnp.float32)
            if layer_mask is None else layer_mask.astype(jnp.float32))
    mask = mask.reshape(n_groups, n_self + 1)
    positions = pos[None] if jnp.ndim(pos) == 0 else pos

    def self_body(x, scanned):
        bp, c, gate = scanned
        x, c, _ = T.block_apply(bp, cfg, x, positions, gate.astype(x.dtype),
                                window=window, cache=c)
        return x, c

    def group_body(x, scanned):
        sp, cp, sc, cc, gates = scanned
        x, sc = jax.lax.scan(self_body, x, (sp, sc, gates[:n_self]))
        h = L.rmsnorm_apply(cp["attn_norm"], x, cfg.norm_eps)
        a, _ = L.attention_apply(cp["attn"], cfg, h, positions, causal=False,
                                 kv_src=None if cc is None else h, cache=cc,
                                 norm_eps=cfg.norm_eps)
        g = gates[n_self].astype(x.dtype)
        x = x + g * jnp.tanh(cp["gate_attn"]).astype(x.dtype) * a
        h = L.rmsnorm_apply(cp["mlp_norm"], x, cfg.norm_eps)
        x = x + g * jnp.tanh(cp["gate_mlp"]).astype(x.dtype) * L.swiglu_apply(cp["mlp"], h)
        return x, sc

    x, new_self = jax.lax.scan(
        group_body, x,
        (params["self_blocks"], params["cross_blocks"], cache["self"],
         cache["cross"], mask))
    new_cache = {"self": new_self, "cross": cache["cross"], "pos": cache["pos"] + 1}
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, x), new_cache
