"""Loop-aware HLO cost model: validates trip-count scaling (the reason this
module exists — XLA's cost_analysis counts while bodies once) and dot/shape
parsing against analytically known programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze, HloModule


def _compile(f, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(f).lower(*args).compile()


def test_scan_trip_count_scaling():
    def make(n):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=n)
            return y
        return f

    expect = lambda n: n * (2 * 128 * 256 * 256 + 128 * 256)
    for n in (2, 16):
        c = _compile(make(n), (128, 256), (256, 256))
        t = analyze(c.as_text())
        assert t["flops"] == pytest.approx(expect(n), rel=0.05), n
    # XLA's own number does NOT scale — that's the bug we correct
    ca2 = _compile(make(2), (128, 256), (256, 256)).cost_analysis()
    ca16 = _compile(make(16), (128, 256), (256, 256)).cost_analysis()
    ca2 = ca2[0] if isinstance(ca2, list) else ca2
    ca16 = ca16[0] if isinstance(ca16, list) else ca16
    assert ca2.get("flops") == ca16.get("flops")


def test_plain_matmul_flops():
    c = _compile(lambda a, b: a @ b, (64, 128), (128, 32))
    t = analyze(c.as_text())
    assert t["flops"] == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_batched_einsum_flops():
    c = _compile(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                 (4, 16, 32), (4, 32, 8))
    t = analyze(c.as_text())
    assert t["flops"] == pytest.approx(2 * 4 * 16 * 32 * 8, rel=0.01)


def test_bytes_scale_with_tensor_size():
    t1 = analyze(_compile(lambda a: a + 1.0, (256, 256)).as_text())
    t2 = analyze(_compile(lambda a: a + 1.0, (1024, 1024)).as_text())
    assert t2["hbm_bytes"] > 8 * t1["hbm_bytes"]


def test_module_parser_finds_entry():
    c = _compile(lambda a: jnp.sin(a).sum(), (32,))
    mod = HloModule(c.as_text())
    assert mod.entry is not None
    assert mod.entry in mod.computations
