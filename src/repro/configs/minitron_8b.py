"""Minitron-8B — pruned Nemotron dense decoder [arXiv:2407.14679]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=16384, vocab_size=256000,
    head_dim=128,
    exit_points=(8, 16, 24, 32),
    source="arXiv:2407.14679",
)
