"""FL server: global-model bookkeeping, aggregation dispatch, evaluation.

Aggregation arms:
* DR-FL      — layer-aligned masked averaging (paper Step 2); optionally
               staleness-aware (FedAsync-style per-exit-layer decay) for
               updates arriving late under the async round engine
* HeteroFL   — width-slice scatter averaging
* ScaleFL    — depth+width scatter averaging (structure-tolerant)
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import layerwise_aggregate
from repro.models import cnn


# ---------------------------------------------------------------------------
# evaluation (paper: small validation set on the cloud server)
# ---------------------------------------------------------------------------


@jax.jit
def _eval_batch(params, x, y):
    outs = cnn.apply_all_exits(params, x)
    return jnp.stack([jnp.mean((jnp.argmax(o, -1) == y)) for o in outs])


def evaluate(params, x_val: np.ndarray, y_val: np.ndarray,
             batch: int = 256) -> np.ndarray:
    """Per-exit accuracy on the server validation set."""
    accs, n = [], 0
    for i in range(0, len(x_val), batch):
        xb = jnp.asarray(x_val[i:i + batch])
        yb = jnp.asarray(y_val[i:i + batch])
        accs.append(np.asarray(_eval_batch(params, xb, yb)) * len(xb))
        n += len(xb)
    return np.sum(accs, axis=0) / max(n, 1)


# ---------------------------------------------------------------------------
# DR-FL aggregation masks for the CNN tree
# ---------------------------------------------------------------------------


def cnn_update_mask(global_params, model_idx: int, scale: float = 1.0):
    """Scalar masks matching the CNN tree: stem + stages<=m + exits<=m
    (clients deep-supervise every exit their submodel holds).  ``scale``
    replaces the 1.0 of held layers — the staleness path builds decay masks
    (value alpha_s per exit-layer) with the same structure."""
    def const(tree, v):
        return jax.tree.map(lambda _: jnp.asarray(v, jnp.float32), tree)

    return {
        "stem": const(global_params["stem"], scale),
        "stages": [const(s, scale if i <= model_idx else 0.0)
                   for i, s in enumerate(global_params["stages"])],
        "exits": [const(e, scale if i <= model_idx else 0.0)
                  for i, e in enumerate(global_params["exits"])],
    }


def staleness_scale(staleness: float, decay: float = 0.5) -> float:
    """FedAsync-style polynomial staleness discount: (1 + s)^(-decay).

    ``s`` counts how many aggregations advanced the global model between a
    client's dispatch and the arrival of its delta; s = 0 (fresh) maps to
    exactly 1.0, so the sync path is bit-for-bit unaffected."""
    if staleness <= 0:
        return 1.0
    return float((1.0 + float(staleness)) ** (-float(decay)))


def aggregate_drfl(global_params, deltas: List, model_idxs: List[int],
                   weights: Sequence[float], server_lr: float = 1.0,
                   staleness: Optional[Sequence[float]] = None,
                   staleness_decay: float = 0.5):
    """DR-FL layer-aligned aggregation, optionally staleness-aware.

    With ``staleness`` given (one entry per delta: aggregations elapsed
    since that client's dispatch), each stale delta is down-weighted by
    ``staleness_scale(s, staleness_decay)`` APPLIED PER EXIT-LAYER: the
    decay is materialized as an alpha-valued mask over exactly the
    stages/exits the client's submodel holds and multiplied into the delta,
    so a lone stale contributor moves a layer by alpha * update (absolute
    FedAsync damping), not by the full update renormalized.  ``staleness``
    of all zeros (or None) reproduces the synchronous path bit-for-bit."""
    masks = [cnn_update_mask(global_params, m) for m in model_idxs]
    if staleness is not None and any(s > 0 for s in staleness):
        scaled = []
        for d, m, s in zip(deltas, model_idxs, staleness):
            a = staleness_scale(s, staleness_decay)
            if a == 1.0:
                scaled.append(d)
                continue
            smask = cnn_update_mask(global_params, m, scale=a)
            scaled.append(jax.tree.map(
                lambda u, sm: (u.astype(jnp.float32) * sm).astype(u.dtype),
                d, smask))
        deltas = scaled
    return layerwise_aggregate(global_params, deltas, masks, weights,
                               server_lr=server_lr)


# ---------------------------------------------------------------------------
# HeteroFL / ScaleFL aggregation (width / depth+width scatter)
# ---------------------------------------------------------------------------


def _scatter_avg(gp, contribs):
    """contribs: list of (delta_leaf, weight); delta may be channel-sliced."""
    num = jnp.zeros(gp.shape, jnp.float32)
    den = jnp.zeros(gp.shape, jnp.float32)
    for u, w in contribs:
        pad = [(0, gs - us) for gs, us in zip(gp.shape, u.shape)]
        num = num + w * jnp.pad(u.astype(jnp.float32), pad)
        den = den + w * jnp.pad(jnp.ones(u.shape, jnp.float32), pad)
    avg = jnp.where(den > 0, num / jnp.maximum(den, 1e-12), 0.0)
    return (gp.astype(jnp.float32) + avg).astype(gp.dtype)


def _collect(gp, delta, w, out):
    """Recursively align (possibly truncated) delta subtree against global."""
    if isinstance(gp, dict):
        for k, v in gp.items():
            if delta is not None and k in delta:
                _collect(v, delta[k], w, out)
            else:
                _collect(v, None, w, out)
    elif isinstance(gp, (list, tuple)):
        for i, v in enumerate(gp):
            d = delta[i] if (delta is not None and i < len(delta)) else None
            _collect(v, d, w, out)
    else:
        out.setdefault(id(gp), (gp, []))
        if delta is not None:
            out[id(gp)][1].append((delta, w))


def aggregate_sliced(global_params, deltas: List, weights: Sequence[float]):
    """Structure- and shape-tolerant scatter aggregation (HeteroFL/ScaleFL)."""
    table: Dict[int, tuple] = {}
    # first register every global leaf (ordering via one pass with None)
    _collect(global_params, None, 0.0, table)
    for d, w in zip(deltas, weights):
        _collect(global_params, d, float(w), table)
    wtot = float(sum(weights)) or 1.0

    def rebuild(gp):
        if isinstance(gp, dict):
            return {k: rebuild(v) for k, v in gp.items()}
        if isinstance(gp, (list, tuple)):
            t = [rebuild(v) for v in gp]
            return t if isinstance(gp, list) else tuple(t)
        leaf, contribs = table[id(gp)]
        if not contribs:
            return leaf
        contribs = [(u, w / wtot) for u, w in contribs]
        return _scatter_avg(leaf, contribs)

    return rebuild(global_params)
