"""jaxlint tests: every rule fires on a violating fixture mini-repo and
stays quiet on its clean twin; pragma semantics (inline, standalone,
def-header, missing-reason); the runtime compile guard; and — the actual
CI gate — the repo itself lints clean.

Fixture repos are built under ``tmp_path`` and pointed at via the
:class:`LintConfig` anchors, so the same rule code paths that police
``src/repro`` are exercised on three-line examples.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import BAD_PRAGMA, LintConfig, compile_guard, run_lint
from repro.analysis.rules import frozen_refs

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

HOT_SYNC_RULE = "host-sync-in-hot-path"
RETRACE_RULE = "retrace-hazard"
PYTREE_RULE = "pytree-field-coverage"
KERNEL_RULE = "kernel-parity-contract"
FROZEN_RULE = "frozen-reference-integrity"


def make_repo(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return str(tmp_path)


def mini_cfg(root, **kw):
    kw.setdefault("package", "pkg")
    kw.setdefault("frozen_targets", ())
    return LintConfig(repo_root=root, **kw)


# ---------------------------------------------------------------------------
# rule 1: host-sync-in-hot-path (call-graph aware)
# ---------------------------------------------------------------------------


def _sync_repo(tmp_path, util_body):
    return make_repo(tmp_path, {
        "src/pkg/__init__.py": "",
        "src/pkg/hot.py": """
            from pkg.util import helper

            def hot_loop(fleet):
                return helper(fleet)
        """,
        "src/pkg/util.py": util_body,
    })


def test_host_sync_fires_transitively_but_only_on_hot_paths(tmp_path):
    root = _sync_repo(tmp_path, """
        import numpy as np

        def helper(fleet):
            return np.asarray(fleet.remaining)

        def cold(fleet):
            return np.asarray(fleet.remaining)
    """)
    report = run_lint(mini_cfg(root, hot_roots=("pkg.hot:hot_loop",),
                               rules=[HOT_SYNC_RULE]))
    assert [f.rule for f in report.unsuppressed] == [HOT_SYNC_RULE]
    # the sync is flagged where it happens (inside the callee, reached
    # through the call graph), and the identical cold function is not
    assert report.unsuppressed[0].file.endswith("util.py")
    assert "helper" not in {f.message for f in report.unsuppressed if
                            "cold" in f.message}
    assert report.exit_code == 1


def test_host_sync_clean_when_sync_leaves_the_hot_path(tmp_path):
    root = _sync_repo(tmp_path, """
        import numpy as np

        def helper(fleet):
            return fleet.remaining * 2.0

        def cold(fleet):
            return np.asarray(fleet.remaining)
    """)
    report = run_lint(mini_cfg(root, hot_roots=("pkg.hot:hot_loop",),
                               rules=[HOT_SYNC_RULE]))
    assert report.unsuppressed == []
    assert report.exit_code == 0


def test_host_sync_ignores_host_side_scalars(tmp_path):
    root = _sync_repo(tmp_path, """
        def helper(fleet, n: int = 4):
            total = float(n) * len([int(i) for i in range(n)])
            return total
    """)
    report = run_lint(mini_cfg(root, hot_roots=("pkg.hot:hot_loop",),
                               rules=[HOT_SYNC_RULE]))
    assert report.unsuppressed == []


# ---------------------------------------------------------------------------
# rule 2: retrace-hazard
# ---------------------------------------------------------------------------


def test_retrace_fires_on_jit_in_function_body(tmp_path):
    root = make_repo(tmp_path, {
        "src/pkg/__init__.py": "",
        "src/pkg/jitty.py": """
            import jax

            def per_call(x):
                f = jax.jit(lambda a: a + 1)
                return f(x)
        """,
    })
    report = run_lint(mini_cfg(root, rules=[RETRACE_RULE]))
    assert [f.rule for f in report.unsuppressed] == [RETRACE_RULE]
    assert "per_call" in report.unsuppressed[0].message


def test_retrace_fires_on_array_passed_to_static_argname(tmp_path):
    root = make_repo(tmp_path, {
        "src/pkg/__init__.py": "",
        "src/pkg/jitty.py": """
            import jax
            import jax.numpy as jnp

            def step(x, k):
                return x * k

            step_jit = jax.jit(step, static_argnames=("k",))

            def caller():
                k = jnp.ones(3)
                return step_jit(jnp.zeros(3), k)
        """,
    })
    report = run_lint(mini_cfg(root, rules=[RETRACE_RULE]))
    assert len(report.unsuppressed) == 1
    assert "static param 'k'" in report.unsuppressed[0].message


def test_retrace_clean_on_module_level_jit(tmp_path):
    root = make_repo(tmp_path, {
        "src/pkg/__init__.py": "",
        "src/pkg/jitty.py": """
            import jax

            def _impl(a):
                return a + 1

            impl_jit = jax.jit(_impl)

            def per_call(x):
                return impl_jit(x)
        """,
    })
    report = run_lint(mini_cfg(root, rules=[RETRACE_RULE]))
    assert report.unsuppressed == []


# ---------------------------------------------------------------------------
# rule 3: pytree-field-coverage
# ---------------------------------------------------------------------------


_PYTREE_SRC = """
    import jax

    @jax.tree_util.register_pytree_node_class
    class Thing:
        a: object
        b: object

        def __init__(self, a, b):
            self.a = a
            self.b = b

        def tree_flatten(self):
            return ((self.a,{extra}), None)

        @classmethod
        def tree_unflatten(cls, aux, children):
            return cls(*children{fill})
"""


def test_pytree_coverage_fires_on_dropped_field(tmp_path):
    root = make_repo(tmp_path, {
        "src/pkg/__init__.py": "",
        "src/pkg/tree.py": _PYTREE_SRC.format(extra="", fill=", 0"),
    })
    report = run_lint(mini_cfg(root, rules=[PYTREE_RULE]))
    assert len(report.unsuppressed) == 1
    assert "Thing.b" in report.unsuppressed[0].message


def test_pytree_coverage_clean_when_all_fields_flattened(tmp_path):
    root = make_repo(tmp_path, {
        "src/pkg/__init__.py": "",
        "src/pkg/tree.py": _PYTREE_SRC.format(extra=" self.b", fill=""),
    })
    report = run_lint(mini_cfg(root, rules=[PYTREE_RULE]))
    assert report.unsuppressed == []


# ---------------------------------------------------------------------------
# rule 4: kernel-parity-contract
# ---------------------------------------------------------------------------


def _kernel_repo(tmp_path, ref_src):
    return make_repo(tmp_path, {
        "src/pkg/__init__.py": "",
        "src/pkg/kernels/__init__.py": "",
        "src/pkg/kernels/myk/__init__.py": "",
        "src/pkg/kernels/myk/ops.py": """
            def foo_op(x, y):
                return x + y
        """,
        "src/pkg/kernels/myk/ref.py": ref_src,
        "tests/test_kernels.py": "# exercises foo_op and foo_ref\n",
    })


def test_kernel_parity_fires_on_signature_drift(tmp_path):
    root = _kernel_repo(tmp_path, """
        def foo_ref(x):
            return x
    """)
    report = run_lint(mini_cfg(root, rules=[KERNEL_RULE],
                               kernels_rel="src/pkg/kernels"))
    assert len(report.unsuppressed) == 1
    assert "signatures drifted" in report.unsuppressed[0].message


def test_kernel_parity_clean_on_matching_pair(tmp_path):
    root = _kernel_repo(tmp_path, """
        def foo_ref(x, y):
            return x + y
    """)
    report = run_lint(mini_cfg(root, rules=[KERNEL_RULE],
                               kernels_rel="src/pkg/kernels"))
    assert report.unsuppressed == []


# ---------------------------------------------------------------------------
# rule 5: frozen-reference-integrity
# ---------------------------------------------------------------------------


def test_frozen_refs_missing_ledger_then_bless_then_drift(tmp_path):
    root = make_repo(tmp_path, {
        "src/pkg/__init__.py": "",
        "src/pkg/ref.py": """
            def reference():
                return 1
        """,
    })
    cfg = mini_cfg(
        root, rules=[FROZEN_RULE], frozen_ledger_rel="frozen.json",
        frozen_targets=(("ref", "src/pkg/ref.py", "reference", "function"),))

    report = run_lint(cfg)
    assert len(report.unsuppressed) == 1
    assert "ledger missing" in report.unsuppressed[0].message

    hashes = frozen_refs.bless(cfg)
    assert "ref" in hashes
    assert run_lint(cfg).unsuppressed == []

    path = os.path.join(root, "src/pkg/ref.py")
    with open(path, "a") as fh:
        fh.write("\n\ndef reference_v2():\n    return 2\n")
    assert run_lint(cfg).unsuppressed == []   # other code may change freely

    src = open(path).read().replace("return 1", "return 42")
    open(path, "w").write(src)
    report = run_lint(cfg)
    assert len(report.unsuppressed) == 1
    assert "was edited" in report.unsuppressed[0].message
    assert "--bless-frozen" in report.unsuppressed[0].message


# ---------------------------------------------------------------------------
# pragma semantics
# ---------------------------------------------------------------------------


def _pragma_report(tmp_path, util_body):
    root = _sync_repo(tmp_path, util_body)
    return run_lint(mini_cfg(root, hot_roots=("pkg.hot:hot_loop",),
                             rules=[HOT_SYNC_RULE]))


def test_pragma_inline_suppresses_with_reason(tmp_path):
    report = _pragma_report(tmp_path, """
        import numpy as np

        def helper(fleet):
            return np.asarray(fleet.remaining)  # jaxlint: allow(host-sync-in-hot-path) -- one pull per round
    """)
    assert report.exit_code == 0
    sup = [f for f in report.findings if f.suppressed]
    assert len(sup) == 1
    assert sup[0].reason == "one pull per round"


def test_pragma_standalone_covers_next_code_line_only(tmp_path):
    report = _pragma_report(tmp_path, """
        import numpy as np

        def helper(fleet):
            # jaxlint: allow(host-sync-in-hot-path) -- one pull per round
            a = np.asarray(fleet.remaining)
            b = np.asarray(fleet.alive)
            return a, b
    """)
    assert len(report.unsuppressed) == 1          # only the second pull
    assert len([f for f in report.findings if f.suppressed]) == 1


def test_pragma_on_def_header_covers_whole_body(tmp_path):
    report = _pragma_report(tmp_path, """
        import numpy as np

        # jaxlint: allow(host-sync-in-hot-path) -- host-side parity reference by design
        def helper(fleet):
            a = np.asarray(fleet.remaining)
            b = np.asarray(fleet.alive)
            return a, b
    """)
    assert report.unsuppressed == []
    assert len([f for f in report.findings if f.suppressed]) == 2


def test_pragma_without_reason_is_itself_a_finding(tmp_path):
    report = _pragma_report(tmp_path, """
        import numpy as np

        def helper(fleet):
            return np.asarray(fleet.remaining)  # jaxlint: allow(host-sync-in-hot-path)
    """)
    rules = sorted(f.rule for f in report.unsuppressed)
    assert rules == [BAD_PRAGMA, HOT_SYNC_RULE]   # reasonless pragma: no effect
    assert report.exit_code == 1


# ---------------------------------------------------------------------------
# runtime compile guard
# ---------------------------------------------------------------------------


def test_compile_guard_counters_pass_and_fail():
    counters = {"compiles": 2, "executions": 7}
    with compile_guard(counters=counters, max_new=1):
        counters["compiles"] += 1
        counters["executions"] += 5
    with pytest.raises(AssertionError, match="new compilation"):
        with compile_guard(counters=counters, max_new=0):
            counters["compiles"] += 1


# ---------------------------------------------------------------------------
# the gate: this repo lints clean, and the CLI agrees
# ---------------------------------------------------------------------------


def test_repo_lints_clean():
    report = run_lint(LintConfig(repo_root=REPO_ROOT))
    assert len(report.rules) >= 5
    assert report.unsuppressed == [], "\n" + report.render()
    # every suppression carries a written justification
    assert all(f.reason for f in report.findings if f.suppressed)


@pytest.mark.slow
def test_cli_writes_json_report(tmp_path):
    out = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO_ROOT, "scripts", "jaxlint.py"),
         "--json", str(out)],
        capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(out.read_text())
    assert data["version"] == 1
    assert data["summary"]["unsuppressed"] == 0
    assert data["summary"]["suppressed"] == len(
        [f for f in data["findings"] if f["suppressed"]])
