"""Layer-wise (depth-prefix) submodels — the paper's §4.2 mechanism.

A *layer-wise model* ``Model_m`` is the global model truncated to its first
``exit_points[m]`` layers plus an exit head.  Two parameter layouts are
supported:

* **Transformer stacks** (scan-stacked ``[L, ...]`` params): a submodel is a
  float ``[L]`` mask (1 = layer present).  Masked forward is identity on
  skipped layers; masked aggregation averages each layer over exactly the
  clients that trained it.
* **CNN stage lists** (the paper's ResNet-18): a submodel is a stage prefix
  (see :mod:`repro.models.cnn`); the per-stage masks below work on the stage
  index.

Everything is shape-stable: masks change *values*, never pytree structure,
so one jitted program serves all M submodels.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def exit_points(cfg: ModelConfig) -> Sequence[int]:
    if cfg.exit_points:
        return cfg.exit_points
    L = cfg.num_layers
    return (max(1, L // 4), max(1, L // 2), max(1, 3 * L // 4), L)


def num_submodels(cfg: ModelConfig) -> int:
    return len(exit_points(cfg))


def layer_mask(cfg: ModelConfig, model_idx: int) -> jnp.ndarray:
    """Float [num_layers] mask for depth-prefix submodel ``model_idx``."""
    pts = exit_points(cfg)
    k = pts[model_idx]
    return (jnp.arange(cfg.num_layers) < k).astype(jnp.float32)


def submodel_layer_count(cfg: ModelConfig, model_idx: int) -> int:
    return int(exit_points(cfg)[model_idx])


def submodel_fraction(cfg: ModelConfig, model_idx: int) -> float:
    """Fraction of backbone layers a submodel trains (size/energy proxy)."""
    return submodel_layer_count(cfg, model_idx) / cfg.num_layers


def stacked_update_mask(cfg: ModelConfig, model_idx: int, params) -> dict:
    """Per-leaf masks (broadcastable to each stacked param) marking which
    layer slices this submodel contributes to during aggregation.

    Leaves without a stacked layer dim (embed, final norm, unembed, shared
    blocks) get mask 1 — every client trains them.
    """
    lm = layer_mask(cfg, model_idx)
    L = cfg.num_layers

    def leaf_mask(leaf):
        # stacked leaves have leading dim == num stacked units
        if leaf.ndim >= 1 and leaf.shape[0] in _stack_sizes(cfg):
            units = leaf.shape[0]
            m = _unit_mask(cfg, lm, units)
            return m.reshape((units,) + (1,) * (leaf.ndim - 1))
        return jnp.ones((), jnp.float32)

    return jax.tree.map(leaf_mask, params)


def _stack_sizes(cfg: ModelConfig):
    """Possible leading stack sizes for this family."""
    L = cfg.num_layers
    sizes = {L}
    if cfg.family == "ssm":
        sizes.add(L // 2)                   # mLSTM/sLSTM pair stacks
    if cfg.family == "vlm" and cfg.cross_attn_every:
        sizes.add(L // cfg.cross_attn_every)  # group stacks
    return sizes


def _unit_mask(cfg: ModelConfig, lm: jnp.ndarray, units: int) -> jnp.ndarray:
    """Collapse the [L] layer mask to a [units] stack mask (a stacked unit is
    'trained' if ANY of its layers is)."""
    L = cfg.num_layers
    if units == L:
        return lm
    per = L // units
    return lm.reshape(units, per).max(axis=1)
