"""Set/attention QMIX mixer (mixer_mode="set") vs the flat hypernet mixer.

Contracts:
* ``"auto"`` resolves flat at or below FACTORED_AUTO_N agents — the legacy
  bit-for-bit small-fleet path (the full-trajectory parity with explicit
  ``mixer_mode="flat"`` is asserted through ``run_simulation``) — and set
  above.
* the set mixer is permutation-invariant over agents, monotone in every
  per-agent Q (dQ_tot/dq_i >= 0, the QMIX contract), and its parameter
  count is independent of ``n_agents``.
* the importance-weight logit slot is exact self-normalised IS: feeding
  ``logw`` equals an explicit softmax over ``logits + logw`` reference.
* sampled-agent replay bounds episode memory: the selector's trace and the
  buffer's stored width are capped at ``agent_budget``, wide episodes fed
  to a budgeted buffer are column-subsampled, and the batch carries
  ``agent_logw`` only on the budgeted path (flat batches stay key-for-key
  identical to the legacy dict).
* the set-mixer training step compiles ONE executable per (batch,
  sampled-agent) shape (compile_guard, mirroring the dual-selection guard
  in tests/test_shard.py).
* ``_make_buffer`` degradation is loud: shrinking capacity below 64
  episodes emits a warning and the engine records the resolved capacity in
  ``hist["qmix"]``.
"""
import logging
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.runtime import cache_size, compile_guard
from repro.core.fleet import sample_fleet_state
from repro.core.marl.buffer import ReplayBuffer
from repro.core.marl.networks import (attention_reduce, set_mixer_apply,
                                      set_mixer_init)
from repro.core.marl.qmix import QmixConfig, QmixLearner
from repro.core.selection import (FACTORED_AUTO_N, OBS_DIM, MarlSelector,
                                  resolve_mixer_mode)
from repro.fl import FLConfig, run_simulation

SIZES = (2.8e6, 8.4e6, 22.5e6, 44.8e6)
FRACS = (0.11, 0.3, 0.72, 1.0)


def _mixer_params(seed=0, state_dim=25, obs_dim=OBS_DIM):
    return set_mixer_init(jax.random.PRNGKey(seed), state_dim, obs_dim)


def _rand_inputs(seed, B, T, N, state_dim=25, obs_dim=OBS_DIM):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    qs = jax.random.normal(ks[0], (B, T, N))
    obs = jax.random.normal(ks[1], (B, T, N, obs_dim))
    state = jax.random.normal(ks[2], (B, T, state_dim))
    return qs, obs, state


# ---------------------------------------------------------------------------
# mode resolution
# ---------------------------------------------------------------------------


def test_auto_resolution_boundary():
    assert resolve_mixer_mode("auto", FACTORED_AUTO_N) == "flat"
    assert resolve_mixer_mode("auto", FACTORED_AUTO_N + 1) == "set"
    assert resolve_mixer_mode("flat", 10 ** 6) == "flat"
    assert resolve_mixer_mode("set", 2) == "set"
    with pytest.raises(ValueError, match="unknown mixer_mode"):
        resolve_mixer_mode("sett", 8)


def test_spec_roundtrip_mixer_fields():
    from repro.fl.spec import SimulationSpec
    cfg = FLConfig(mixer_mode="set", marl_agent_budget=128)
    spec = SimulationSpec.from_flat(cfg)
    assert spec.marl.mixer_mode == "set"
    assert spec.marl.agent_budget == 128
    assert spec.to_flat() == cfg
    with pytest.raises(ValueError, match="marl.mixer_mode"):
        SimulationSpec.from_flat(FLConfig(mixer_mode="sett"))


# ---------------------------------------------------------------------------
# set-mixer math: invariance, monotonicity, importance slot
# ---------------------------------------------------------------------------


def test_set_mixer_permutation_invariant():
    p = _mixer_params()
    qs, obs, state = _rand_inputs(1, B=3, T=4, N=17)
    out = set_mixer_apply(p, qs, obs, state)
    perm = np.random.default_rng(0).permutation(17)
    out_p = set_mixer_apply(p, qs[..., perm], obs[..., perm, :], state)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_p),
                               atol=1e-5, rtol=1e-5)


def test_set_mixer_monotone_in_agent_qs():
    """QMIX contract: dQ_tot/dq_i >= 0 for every agent at random points."""
    p = _mixer_params()
    for seed in range(3):
        qs, obs, state = _rand_inputs(seed, B=2, T=3, N=9)
        g = jax.grad(lambda q: set_mixer_apply(p, q, obs, state).sum())(qs)
        assert float(g.min()) >= -1e-6, float(g.min())


def test_set_mixer_params_independent_of_n():
    counts = {k: sum(np.asarray(x).size for x in jax.tree.leaves(v))
              for k, v in _mixer_params().items()}
    # nothing in the param tree mentions an agent count: same init serves
    # any N (the flat mixer's hyper_w1 is state_dim -> n*embed instead)
    total = sum(counts.values())
    assert total < 50_000, counts
    qs, obs, state = _rand_inputs(2, B=1, T=2, N=1000)
    out = set_mixer_apply(_mixer_params(), qs, obs, state)
    assert out.shape == (1, 2)


def test_logw_slot_is_exact_self_normalised_is():
    """The key/query slot -1 trick == explicit softmax(logits + logw)."""
    from repro.models.layers import dense_apply, mlp_apply
    p = _mixer_params()
    d, n_seeds = 32, 4
    qs, obs, state = _rand_inputs(3, B=2, T=2, N=11)
    logw = jax.random.normal(jax.random.PRNGKey(9), (2, 2, 11))
    out = set_mixer_apply(p, qs, obs, state, logw=logw)

    # reference: same embeddings, explicit reweighted softmax pooling
    z = mlp_apply(p["obs_embed"], obs)
    keys = dense_apply(p["key_proj"], z)                    # [..., N, d-1]
    seeds = mlp_apply(p["hyper_q"], state).reshape((2, 2, n_seeds, d - 1))
    logits = jnp.einsum("btsd,btnd->btsn", seeds, keys) / math.sqrt(d)
    w = jax.nn.softmax(logits + logw[:, :, None, :], axis=-1)
    w1 = jnp.abs(mlp_apply(p["hyper_w1"], state))
    b1 = mlp_apply(p["hyper_b1"], state)
    vals = jax.nn.elu(qs[..., None] * w1[..., None, :]
                      + dense_apply(p["val_obs"], z) + b1[..., None, :])
    pooled = jnp.einsum("btsn,btnd->btsd", w, vals).reshape((2, 2, -1))
    w2 = jnp.abs(mlp_apply(p["hyper_w2"], state))
    ref = jnp.sum(pooled * w2, axis=-1) + mlp_apply(
        p["hyper_b2"], state)[..., 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_attention_reduce_agrees_with_plain_softmax():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (3, 4, 16))
    k = jax.random.normal(ks[1], (3, 50, 16))
    v = jax.random.normal(ks[2], (3, 50, 16))
    out = attention_reduce(q, k, v)
    logits = jnp.einsum("bsd,bnd->bsn", q, k) / math.sqrt(16)
    ref = jnp.einsum("bsn,bnd->bsd", jax.nn.softmax(logits, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# sampled-agent replay
# ---------------------------------------------------------------------------


def test_budgeted_buffer_subsamples_and_carries_logw():
    buf = ReplayBuffer(4, episode_len=3, n_agents=20, obs_dim=OBS_DIM,
                       state_dim=7, seed=0, agent_budget=6)
    assert buf.N == 6 and buf.n_full == 20
    obs = np.random.default_rng(0).normal(size=(4, 20, OBS_DIM)) \
        .astype(np.float32)
    buf.add_episode(obs, np.zeros((4, 7), np.float32),
                    np.zeros((3, 20), np.int64), [1.0, 2.0, 3.0])
    batch = buf.sample(2)
    assert batch["obs"].shape == (1, 4, 6, OBS_DIM)
    assert batch["actions"].shape == (1, 3, 6)
    assert "agent_logw" in batch and batch["agent_logw"].shape == (1, 6)
    np.testing.assert_array_equal(batch["agent_logw"], 0.0)
    # stored columns are a real subset of the wide episode
    idx = buf.agent_idx[0]
    np.testing.assert_array_equal(buf.obs[0, :4], obs[:, idx])


def test_unbudgeted_buffer_batch_keys_unchanged():
    buf = ReplayBuffer(4, episode_len=2, n_agents=5, obs_dim=OBS_DIM,
                       state_dim=3, seed=0)
    buf.add_episode(np.zeros((3, 5, OBS_DIM)), np.zeros((3, 3)),
                    np.zeros((2, 5), np.int64), [1.0, 1.0])
    assert set(buf.sample(1)) == {"obs", "state", "actions", "rewards",
                                  "mask"}


def test_budgeted_buffer_nbytes_independent_of_fleet_size():
    small = ReplayBuffer(8, 4, 512, OBS_DIM, 25, agent_budget=64)
    large = ReplayBuffer(8, 4, 1 << 20, OBS_DIM, 25, agent_budget=64)
    assert large.nbytes == small.nbytes


def test_selector_trace_is_sampled_and_trains():
    n, budget = 40, 8
    sel = MarlSelector(n, len(SIZES), n_rounds=4, seed=0,
                       state_mode="factored", mixer_mode="set",
                       agent_budget=budget)
    assert sel.n_sampled == budget
    fleet = sample_fleet_state(n, seed=0, backend="jax")
    for t in range(3):
        s = sel.select(fleet, t, 4, SIZES, FRACS)
        assert len(s.model_choice) == n          # selection: FULL fleet
        sel.observe_reward(1.0)
    obs, state, actions, rewards = sel.episode_arrays(fleet, 3)
    assert obs.shape == (4, budget, OBS_DIM)     # trace: sampled agents
    assert actions.shape == (3, budget)
    assert state.shape[1] == sel.learner.cfg.state_dim
    buf = ReplayBuffer(4, 3, n, OBS_DIM, state.shape[1], 0,
                       agent_budget=budget)
    buf.add_episode(obs, state, actions, rewards)
    metrics = sel.learner.update(buf.sample(2))
    assert np.isfinite(metrics["td_loss"])
    # the sample redraws per episode
    idx0 = sel._ep_idx.copy()
    sel.reset_episode()
    assert not np.array_equal(idx0, sel._ep_idx)


def test_selector_flat_state_with_sampled_trace_keeps_full_state():
    """mixer_mode="set" + state_mode="flat": the mixer state stays the
    FULL fleet's n*OBS_DIM concatenation while the per-agent columns are
    sampled."""
    n, budget = 12, 4
    sel = MarlSelector(n, len(SIZES), n_rounds=3, seed=1,
                       state_mode="flat", mixer_mode="set",
                       agent_budget=budget)
    fleet = sample_fleet_state(n, seed=1, backend="jax")
    for t in range(2):
        sel.select(fleet, t, 3, SIZES, FRACS)
        sel.observe_reward(0.5)
    obs, state, actions, _ = sel.episode_arrays(fleet, 2)
    assert obs.shape == (3, budget, OBS_DIM)
    assert state.shape == (3, n * OBS_DIM)


# ---------------------------------------------------------------------------
# end-to-end + parity through run_simulation
# ---------------------------------------------------------------------------


def _small_cfg(**kw):
    base = dict(n_devices=8, n_rounds=3, participation=0.5, n_train=300,
                local_epochs=1, selector="marl", seed=0)
    base.update(kw)
    return FLConfig(**base)


def test_auto_is_bitforbit_flat_at_small_n():
    h_auto = run_simulation(_small_cfg(mixer_mode="auto"))
    h_flat = run_simulation(_small_cfg(mixer_mode="flat"))
    assert h_auto["acc_mean"] == h_flat["acc_mean"]
    assert h_auto["reward"] == h_flat["reward"]
    assert h_auto["participants"] == h_flat["participants"]
    assert h_auto["qmix"]["mixer_mode"] == "flat"


def test_set_mixer_trains_end_to_end():
    h = run_simulation(_small_cfg(n_rounds=4, mixer_mode="set",
                                  marl_agent_budget=4))
    q = h["qmix"]
    assert q["mixer_mode"] == "set"
    assert q["replay_agents"] == 4
    assert q["updates"] >= 1
    assert all(np.isfinite(q["td_loss"]))


# ---------------------------------------------------------------------------
# compile behaviour + buffer degradation telemetry
# ---------------------------------------------------------------------------


def _batch(B, T, N, state_dim, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "obs": rng.normal(size=(B, T + 1, N, OBS_DIM)).astype(np.float32),
        "state": rng.normal(size=(B, T + 1, state_dim)).astype(np.float32),
        "actions": rng.integers(0, 5, size=(B, T, N)),
        "rewards": rng.normal(size=(B, T)).astype(np.float32),
        "mask": np.ones((B, T), np.float32),
        "agent_logw": np.zeros((B, N), np.float32),
    }


def test_set_update_one_executable_per_shape():
    """Mirrors the dual_selection_energy_step_jit guard in test_shard.py:
    the set-mixer training step must not retrace on same-shape batches."""
    cfg = QmixConfig(n_agents=1000, obs_dim=OBS_DIM, num_actions=5,
                     state_dim=25, mixer_mode="set")
    learner = QmixLearner(cfg, jax.random.PRNGKey(0))
    learner.update(_batch(4, 3, 16, 25))         # warm
    if cache_size(learner._update) == 0:
        pytest.skip("jit wrapper does not expose _cache_size")
    with compile_guard(learner._update, max_new=0):
        for seed in range(3):
            learner.update(_batch(4, 3, 16, 25, seed=seed))
    with compile_guard(learner._update, max_new=1):
        learner.update(_batch(4, 3, 8, 25))      # new sampled-agent width


def test_make_buffer_degradation_is_loud(caplog):
    from repro.fl.simulation import _make_buffer
    cfg = FLConfig(n_devices=4096, mixer_mode="flat")
    with caplog.at_level(logging.WARNING, logger="repro.fl.simulation"):
        buf = _make_buffer(cfg)
    assert buf.capacity < 64
    assert any("replay capacity degraded" in r.getMessage()
               for r in caplog.records)
    # the set-mixer path keeps full capacity at the same fleet size
    caplog.clear()
    with caplog.at_level(logging.WARNING, logger="repro.fl.simulation"):
        buf_set = _make_buffer(FLConfig(n_devices=4096, mixer_mode="set",
                                        marl_agent_budget=256))
    assert buf_set.capacity == 64
    assert not caplog.records
