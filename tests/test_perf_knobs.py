"""§Perf optimisation knobs must preserve semantics exactly.

Each knob that changes HOW something is computed (not just sharding hints)
gets an equivalence test against the default path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_smoke_config
from repro.core.layerwise import exit_points, layer_mask
from repro.models import build
from repro.models.layers import gqa_attend
from repro.models.moe import moe_apply, moe_init
from repro.sharding.rules import get_sharding_policy, set_sharding_policy


@pytest.fixture(autouse=True)
def _reset_policy():
    saved = get_sharding_policy()
    yield
    set_sharding_policy(**saved)


def test_repeat_kv_equivalent():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 32, 6, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 32, 2, 16))
    for causal, window in ((True, 0), (True, 8), (False, 0)):
        a = gqa_attend(q, k, v, causal=causal, window=window)
        set_sharding_policy(repeat_kv=True)
        b = gqa_attend(q, k, v, causal=causal, window=window)
        set_sharding_policy(repeat_kv=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=1e-4)


def test_moe_dispatch_decode_equals_gather():
    cfg = dataclasses.replace(get_smoke_config("qwen3-moe-235b-a22b"),
                              moe_capacity_factor=100.0)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 1, cfg.d_model))
    y_g, _ = moe_apply(p, cfg, x)
    cfg_d = dataclasses.replace(cfg, moe_decode_impl="dispatch")
    y_d, _ = moe_apply(p, cfg_d, x, capacity_factor=100.0)
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_d),
                               atol=2e-5, rtol=1e-4)


def test_fl_bucketed_step_bitwise_equals_masked():
    """The beyond-paper bucketed FL step (§Perf C2) must produce the SAME
    optimizer update as the masked step for the same client layout."""
    from repro.launch.steps import (build_fl_bucketed_train_step,
                                    build_fl_train_step)
    from repro.optim import adamw_init
    cfg = get_smoke_config("phi3-mini-3.8b")
    tcfg = TrainConfig(loss_chunk=8, remat="none")
    model, fl_step = build_fl_train_step(cfg, tcfg)
    _, bstep, nb = build_fl_bucketed_train_step(cfg, tcfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    state = {"params": params, "opt": adamw_init(params)}
    B, S = 2 * nb, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                cfg.vocab_size)
    exits = exit_points(cfg)
    gates = jnp.stack(sum(([layer_mask(cfg, b)] * (B // nb)
                           for b in range(nb)), []), axis=1)
    counts = jnp.asarray([sum(1 for k in exits if l < k)
                          for l in range(cfg.num_layers)], jnp.float32)
    batch_m = {"tokens": tokens, "labels": labels, "layer_gates": gates,
               "layer_counts": counts, "n_clients": jnp.float32(nb)}
    batch_b = {"tokens": tokens.reshape(nb, B // nb, S),
               "labels": labels.reshape(nb, B // nb, S)}
    s1, m1 = jax.jit(fl_step)(state, batch_m)
    s2, m2 = jax.jit(bstep)(state, batch_b)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-6)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-6, rtol=1e-5)


def test_dp2d_batch_axes():
    """dp2d adds 'model' to the batch axes on a mesh that has it."""
    import os
    import subprocess
    import sys
    SRC = "src"
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from repro.launch.mesh import make_debug_mesh
from repro.sharding.rules import batch_axes, set_sharding_policy
mesh = make_debug_mesh(multi_pod=True)
assert batch_axes(mesh) == ("pod", "data")
set_sharding_policy(dp2d=True)
assert batch_axes(mesh) == ("data", "model")
set_sharding_policy(dp2d=False)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300, cwd="/root/repo")
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr
