"""Distributed LM training driver over the assigned architectures.

    PYTHONPATH=src python examples/train_lm.py --arch phi3-mini-3.8b --smoke \
        --steps 20
    PYTHONPATH=src python examples/train_lm.py --arch xlstm-1.3b --smoke \
        --steps 50 --fl-pods 4          # DR-FL over pods: layer-masked clients

``--smoke`` uses the reduced same-family config (CPU-runnable); without it
you get the full assigned config (sized for the production mesh — pair with
the dry-run, not a CPU).

``--fl-pods N`` demonstrates the paper's technique inside the training loop:
N simulated clients train depth-prefix submodels (layer masks) and the
server layer-align aggregates their deltas each round.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import TrainConfig, get_config, get_smoke_config
from repro.core.aggregation import layerwise_aggregate
from repro.core.layerwise import layer_mask, num_submodels, stacked_update_mask
from repro.data.synthetic import lm_batches, synthetic_lm_dataset
from repro.launch.steps import build_train_step
from repro.models import extra_inputs
from repro.optim import adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--fl-pods", type=int, default=0,
                    help="simulate N DR-FL clients with layer-wise submodels")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=5,
                       total_steps=args.steps, loss_chunk=32, remat="none")
    model, train_step = build_train_step(cfg, tcfg)
    train_step = jax.jit(train_step, donate_argnums=(0,))
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    state = {"params": params, "opt": adamw_init(params)}
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,} "
          f"(analytic {cfg.param_count():,})")

    toks = synthetic_lm_dataset(200_000, cfg.vocab_size, seed=0)
    it = lm_batches(toks, args.batch, args.seq, seed=0)
    extras = {k: jnp.zeros(shp, dt) for k, (shp, dt)
              in extra_inputs(cfg, args.batch, args.seq).items()}

    if args.fl_pods:
        run_fl(model, cfg, state, it, extras, args)
        return

    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        batch.update(extras)
        state, metrics = train_step(state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({(time.time() - t0) / (step + 1):.2f}s/step)")
    if args.ckpt:
        save_pytree(args.ckpt, state["params"])
        print("saved", args.ckpt)


def run_fl(model, cfg, state, it, extras, args):
    """DR-FL rounds over simulated pods: each client trains a depth-prefix
    submodel (layer mask), server layer-align aggregates (paper Step 2)."""
    from repro.launch.steps import chunked_cross_entropy, _unembed
    M = num_submodels(cfg)
    print(f"DR-FL mode: {args.fl_pods} clients over {M} layer-wise models")

    def client_loss(params, batch, mask):
        hidden, _ = model.apply(params, batch["tokens"], {}, layer_mask=mask,
                                remat="none")
        return chunked_cross_entropy(hidden, _unembed(model, params),
                                     batch["labels"], 32)

    grad_fn = jax.jit(jax.value_and_grad(client_loss))
    gp = state["params"]
    for rnd in range(args.steps):
        deltas, masks, weights = [], [], []
        losses = []
        for c in range(args.fl_pods):
            m_idx = c % M
            mask = layer_mask(cfg, m_idx)
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            loss, g = grad_fn(gp, batch, mask)
            delta = jax.tree.map(lambda x: -args.lr * x, g)
            deltas.append(delta)
            masks.append(stacked_update_mask(cfg, m_idx, gp))
            weights.append(1.0)
            losses.append(float(loss))
        gp = layerwise_aggregate(gp, deltas, masks, weights)
        print(f"round {rnd:3d} client losses="
              f"{np.round(losses, 3)} (layer-aligned aggregated)")
    state["params"] = gp


if __name__ == "__main__":
    main()
