"""Early-exit greedy decoding from the transformer family's global model.

The serving-side payoff of depth-prefix training (docs/FAMILIES.md): the
SAME parameter tree answers at any of its exits, so a battery-poor device
decodes from exit 0 while a charged one uses the full depth — no
re-download, no distillation.

    PYTHONPATH=src python examples/serve_lm.py --gen 24
    PYTHONPATH=src python examples/serve_lm.py --exit 0 --gen 24 \
        --ckpt /tmp/lm.msgpack       # params saved by examples/train_lm.py

Decoding recomputes the full context window each step (the family's
training forward, ``seq``-token sliding window) — honest about what the
FL-scale model is; KV-cache serving is the big-LM stack's job, not this
demo's.  Without ``--ckpt`` the script first runs a few local DR-FL
rounds so the decode has a trained tree to exercise.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.family import get_family


def greedy_decode(fam, params, prompt, gen, exit_idx, seq):
    """Greedy next-token loop over a sliding ``seq``-token window."""
    toks = list(map(int, prompt))
    for _ in range(gen):
        window = jnp.asarray(toks[-seq:], jnp.int32)[None, :]
        logits = fam.apply_all_exits(params, window)[exit_idx]
        toks.append(int(jnp.argmax(logits[0])))
    return toks[len(prompt):]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None,
                    help="msgpack params from examples/train_lm.py")
    ap.add_argument("--exit", dest="exit_idx", type=int, default=-1,
                    help="exit head to decode from (-1 = deepest)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seq", type=int, default=8)
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--train-rounds", type=int, default=6,
                    help="warmup DR-FL rounds when no --ckpt is given")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    fam = get_family("transformer")
    M = fam.num_submodels()
    exit_idx = args.exit_idx % M
    params = fam.init(jax.random.PRNGKey(args.seed), 10,
                      width_mult=args.width, hw=args.seq)
    if args.ckpt:
        from repro.checkpoint import load_pytree
        params = load_pytree(args.ckpt, params)
        print("loaded", args.ckpt)
    else:
        print(f"no --ckpt: {args.train_rounds} local DR-FL warmup rounds")
        x, y = fam.make_dataset(1200, 10, hw=args.seq, noise=1.0,
                                seed=args.seed)
        g = params
        for rnd in range(args.train_rounds):
            d, loss = fam.client_update("drfl", g, M - 1, x, y, epochs=1,
                                        batch=32, lr=0.05,
                                        seed=args.seed + rnd)
            g = jax.tree.map(lambda a, b: a + b, g, d)
            print(f"  round {rnd} loss={float(loss):.3f}")
        params = g

    # prompt: a fresh window from the same Markov stream (held-out offset)
    x_eval, _ = fam.make_dataset(64, 10, hw=args.seq, noise=0.0,
                                 seed=args.seed + 1)
    prompt = np.asarray(x_eval[0])
    print(f"prompt tokens: {prompt.tolist()}")

    for m in sorted({0, exit_idx, M - 1}):
        t0 = time.time()
        out = greedy_decode(fam, params, prompt, args.gen, m, args.seq)
        dt = (time.time() - t0) / args.gen * 1000
        marker = " <-- --exit" if m == exit_idx else ""
        print(f"exit {m} ({m + 1}/{M} blocks): {out}  "
              f"[{dt:.1f} ms/token]{marker}")


if __name__ == "__main__":
    main()
