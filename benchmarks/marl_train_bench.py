"""QMIX *training* scaling bench: flat vs set mixer up to n=1M agents.

PR 5 made the *selection* step O(n/shards); this bench measures the other
half — the MARL TRAINING loop (replay fill + jitted QMIX update) — across
fleet sizes, flat hypernet mixer vs the permutation-invariant set/attention
mixer with sampled-agent replay (``repro.core.marl.networks``).  Each
measured row actually TRAINS: the replay buffer is filled from real
``MarlSelector.select`` episodes over a sampled fleet, then timed gradient
steps run until the smoke horizon, asserting the TD loss decreases.

The flat mixer's hypernet emits one weight row per agent
(``hyper_w1: state_dim -> n*embed``), so at n=65536 its parameters alone
are ~0.5 GB and at n=1M ~8.4 GB (x~5 live copies with target net + Adam
moments + grads) — those rows are recorded as ``skipped`` with the
analytic estimate instead of OOM-killing the bench.  The set mixer's cost
is bounded by the sampled-agent budget, so its per-step time is flat in n
(THE acceptance criterion: set rows at 65536 and 1M match the 4096 row
within noise).

Results land in ``BENCH_marl_train.json``:

    PYTHONPATH=src python -m benchmarks.marl_train_bench            # full
    PYTHONPATH=src python -m benchmarks.marl_train_bench --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import statistics
import sys
import time

SIZES_FULL = (256, 4096, 65536, 1_048_576)
SIZES_SMOKE = (256, 4096)
#: flat-mixer rows above this agent count are recorded analytically, not
#: run: hyper_w1 alone is n*embed^2 floats and the learner holds ~5 live
#: copies (params, target, grads, 2 Adam moments)
FLAT_MAX_MEASURED_N = 4096
EPISODE_LEN = 4            # selector rounds per replay episode
N_EPISODES = 3             # replay episodes filled before timing
OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_marl_train.json")


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _flat_analytic_row(n: int) -> dict:
    """Why the flat mixer cannot train here, in bytes."""
    from repro.core.marl.qmix import QmixConfig
    from repro.core.selection import OBS_DIM

    embed = QmixConfig.__dataclass_fields__["mixer_embed"].default
    hyper_w1_floats = embed * (n * embed)      # the O(n) hypernet output row
    live_copies = 5                            # params/target/grads/Adam m+v
    replay_mb = 64 * (EPISODE_LEN + 1) * n * OBS_DIM * 4 / 1e6
    return {
        "n": n, "mode": "flat", "skipped": True,
        "why": "flat hypernet mixer is O(n): params alone exceed memory",
        "hyper_w1_gb_analytic": round(hyper_w1_floats * 4 / 1e9, 2),
        "learner_gb_analytic": round(
            live_copies * hyper_w1_floats * 4 / 1e9, 2),
        "replay_mb_analytic_64ep": round(replay_mb, 1),
    }


def _bench_one(n: int, mixer_mode: str, iters: int, seed: int = 0,
               agent_budget: int = 4096) -> dict:
    import jax
    import numpy as np

    from repro.core.fleet import sample_fleet_state
    from repro.core.marl.buffer import ReplayBuffer
    from repro.core.selection import OBS_DIM, MarlSelector

    model_sizes = (2.8e6, 8.4e6, 22.5e6, 44.8e6)
    model_fracs = (0.11, 0.3, 0.72, 1.0)
    k = max(1, n // 100)
    n_rounds = EPISODE_LEN

    # factored state at every n: this bench isolates the MIXER axis (the
    # flat state was already measured out in BENCH_fleet_shard / PR 5)
    sel = MarlSelector(n, len(model_sizes), n_rounds, seed=seed,
                       state_mode="factored", mixer_mode=mixer_mode,
                       agent_budget=agent_budget)
    budget = agent_budget if mixer_mode == "set" else None
    buf = ReplayBuffer(8, n_rounds, n, OBS_DIM,
                       sel.learner.cfg.state_dim, seed, agent_budget=budget)

    # --- replay fill: real select() episodes over a sampled fleet --------
    t_fill0 = time.time()
    for ep in range(N_EPISODES):
        fleet = sample_fleet_state(n, seed=seed + ep, backend="jax")
        sel.reset_episode()
        for t in range(n_rounds):
            sel.select(fleet, t, k, model_sizes, model_fracs)
            sel.observe_reward(0.1 * (ep + t))
        buf.add_episode(*sel.episode_arrays(fleet, n_rounds))
    fill_s = time.time() - t_fill0

    # --- timed training steps -------------------------------------------
    losses = []

    def step():
        batch = buf.sample(sel.learner.cfg.batch_size)
        losses.append(sel.learner.update(batch)["td_loss"])

    t0 = time.time()
    step()                                     # compile + warm
    compile_s = time.time() - t0
    # smoke training horizon: enough gradient steps for the TD loss to
    # come down from its cold-start value before the timed window
    for _ in range(12):
        step()
    times = []
    for _ in range(iters):
        t0 = time.time()
        step()
        times.append(time.time() - t0)

    return {
        "n": n, "mode": mixer_mode, "skipped": False,
        "agents_stored": buf.N, "iters": iters,
        "train_step_s": round(statistics.median(times), 4),
        "train_step_min_s": round(min(times), 4),
        "compile_s": round(compile_s, 2),
        "replay_fill_s": round(fill_s, 2),
        "replay_mb": round(buf.nbytes / 1e6, 2),
        "peak_rss_mb": round(_peak_rss_mb(), 1),
        "loss_first": round(float(losses[0]), 4),
        "loss_last": round(float(losses[-1]), 4),
        "loss_decreased": bool(losses[-1] < losses[0]),
        "state_dim": sel.learner.cfg.state_dim,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: n in (256, 4096), fewer iters")
    ap.add_argument("--sizes", type=int, nargs="*", default=None)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--agent-budget", type=int, default=4096)
    ap.add_argument("--no-write", action="store_true")
    args = ap.parse_args(argv)

    import jax

    from benchmarks.common import emit

    sizes = tuple(args.sizes) if args.sizes else (
        SIZES_SMOKE if args.smoke else SIZES_FULL)
    out = {
        "bench": "marl_train",
        "backend": jax.default_backend(),
        "episode_len": EPISODE_LEN,
        "agent_budget": args.agent_budget,
        "rows": [],
    }
    for n in sorted(sizes):
        iters = args.iters or (3 if (args.smoke or n >= 65536) else 5)
        for mode in ("flat", "set"):
            if mode == "flat" and n > FLAT_MAX_MEASURED_N:
                row = _flat_analytic_row(n)
                out["rows"].append(row)
                print(f"marl_train/flat/n{n}: skipped "
                      f"(analytic learner size "
                      f"{row['learner_gb_analytic']} GB)")
                continue
            row = _bench_one(n, mode, iters,
                             agent_budget=args.agent_budget)
            out["rows"].append(row)
            emit(f"marl_train/{mode}/n{n}", row["train_step_s"] * 1e6,
                 f"agents_stored={row['agents_stored']} "
                 f"replay_mb={row['replay_mb']} "
                 f"loss {row['loss_first']}->{row['loss_last']} "
                 f"peak_rss_mb={row['peak_rss_mb']}")
    if not args.no_write:
        path = os.path.abspath(OUT_JSON)
        existing = {}
        if os.path.exists(path):
            with open(path) as fh:
                existing = json.load(fh)
        if args.smoke and existing.get("rows"):
            # CI smoke must not clobber the recorded full-scale rows
            existing["smoke"] = {"rows": out["rows"],
                                 "backend": out["backend"]}
            out = existing
        else:
            # merge by (n, mode): a partial --sizes rerun must not erase
            # the expensive 65536/1M rows
            fresh = {(r["n"], r["mode"]) for r in out["rows"]}
            out["rows"] += [r for r in existing.get("rows", [])
                            if (r["n"], r["mode"]) not in fresh]
            out["rows"].sort(key=lambda r: (r["n"], r["mode"]))
            if "smoke" in existing:
                out["smoke"] = existing["smoke"]
        with open(path, "w") as fh:
            json.dump(out, fh, indent=1)
        print(f"wrote {path}")
    return out


if __name__ == "__main__":
    main()
