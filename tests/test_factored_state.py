"""Factored QMIX state (state_mode="factored") vs the flat legacy state.

Contracts:
* ``state_mode="flat"`` is BIT-FOR-BIT the pre-factoring selector: the
  frozen reference copy of the original select/episode_arrays logic kept
  below must produce identical selections, Q values and episode arrays.
* the factored state's width (QMIX ``state_dim``) is independent of
  ``n_devices`` — the whole point of the refactor — and matches the
  ``ModelFamily.state_summary_width`` registry hook.
* ``fleet_summary`` is permutation-invariant over device order.
* ``"auto"`` resolves flat below FACTORED_AUTO_N and factored above, and
  the factored selector trains end-to-end through ``run_simulation``
  (replay buffer sized by the resolved state_dim).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.fleet import (FleetState, fleet_summary, make_fleet_state,
                              summary_width)
from repro.core.marl.qmix import QmixConfig, QmixLearner, epsilon
from repro.core.selection import (FACTORED_AUTO_N, OBS_DIM, MarlSelector,
                                  Selection, as_fleet_state, fleet_obs,
                                  marl_state_dim, resolve_state_mode)
from repro.models.family import get_family

SIZES = (2.8e6, 8.4e6, 22.5e6, 44.8e6)
FRACS = (0.11, 0.3, 0.72, 1.0)


# ---------------------------------------------------------------------------
# frozen pre-factoring selector (the parity reference)
# ---------------------------------------------------------------------------
#
# Verbatim copy of MarlSelector.select/episode_arrays as they were BEFORE
# state_mode existed (state = obs.reshape(-1), state_dim = n * OBS_DIM).
# Do not "simplify" toward the current implementation — this class is the
# contract that state_mode="flat" reproduces the pre-PR trajectory.


class _PreFactoringMarlSelector:
    def __init__(self, n_devices, n_models, n_rounds, seed=0):
        import jax.numpy as jnp  # noqa: F401  (parity with original imports)
        self.n_models = n_models
        self.n_rounds = n_rounds
        cfg = QmixConfig(
            n_agents=n_devices, obs_dim=OBS_DIM, num_actions=n_models + 1,
            state_dim=n_devices * OBS_DIM,
            eps_decay_rounds=max(10, n_rounds // 2))
        self.learner = QmixLearner(cfg, jax.random.PRNGKey(seed))
        self.key = jax.random.PRNGKey(seed + 1)
        self.hidden = self.learner.init_hidden()
        self.total_rounds = 0
        self.ep_obs, self.ep_state = [], []
        self.ep_actions, self.ep_rewards = [], []

    def select(self, devices, round_idx, k, model_sizes, model_fractions,
               local_epochs=5, batch_size=32):
        import jax.numpy as jnp

        from repro.core.fleet import (fleet_affordability,
                                      fleet_affordability_jit, fleet_is_jax)
        fleet = as_fleet_state(devices)
        obs = fleet_obs(fleet, round_idx, self.n_rounds)
        state = obs.reshape(-1)
        self.key, sub = jax.random.split(self.key)
        eps = epsilon(self.learner.cfg, self.total_rounds)
        self.total_rounds += 1
        aff = (fleet_affordability_jit if fleet_is_jax(fleet)
               else fleet_affordability)
        avail = np.asarray(aff(
            fleet, model_sizes, model_fractions, local_epochs, batch_size))
        actions, qv, self.hidden = self.learner.act(
            jnp.asarray(obs), self.hidden, sub, eps, jnp.asarray(avail))
        qv = np.array(qv)
        alive = np.asarray(fleet.alive)
        actions = np.where(alive, np.array(actions), self.n_models)
        willing = np.flatnonzero(actions < self.n_models)
        order = willing[np.argsort(-qv[willing], kind="stable")]
        chosen = [int(i) for i in order[:k]]
        model_choice = [-1] * len(fleet)
        for i in chosen:
            model_choice[i] = int(actions[i])
        self.ep_obs.append(obs)
        self.ep_state.append(state)
        self.ep_actions.append(actions.copy())
        return Selection(participants=chosen, model_choice=model_choice,
                         q_values=qv)

    def observe_reward(self, reward, sim_time=None):
        self.ep_rewards.append(float(reward))

    def episode_arrays(self, final_devices, round_idx):
        obs = np.stack(self.ep_obs + [fleet_obs(
            as_fleet_state(final_devices), round_idx, self.n_rounds)])
        state = obs.reshape(obs.shape[0], -1)
        return (obs, state, np.stack(self.ep_actions),
                np.asarray(self.ep_rewards, np.float32))


def _drained_fleet(n=8, seed=3):
    fleet = make_fleet_state(n, seed=seed, backend="numpy")
    return fleet.replace(remaining=fleet.battery * 0.05)


def test_flat_mode_bitexact_vs_pre_factoring_selector():
    """state_mode="flat" reproduces the pre-PR selector trajectory
    bit-for-bit at n=8: selections, Q values, episode arrays."""
    fleet = _drained_fleet(8)
    cur = MarlSelector(8, 4, n_rounds=6, seed=0, state_mode="flat")
    ref = _PreFactoringMarlSelector(8, 4, n_rounds=6, seed=0)
    assert cur.learner.cfg == ref.learner.cfg
    for t in range(4):
        a = cur.select(fleet, t, 3, SIZES, FRACS, local_epochs=2)
        b = ref.select(fleet, t, 3, SIZES, FRACS, local_epochs=2)
        assert a.participants == b.participants
        assert a.model_choice == b.model_choice
        np.testing.assert_array_equal(a.q_values, b.q_values)
        cur.observe_reward(0.25 * t)
        ref.observe_reward(0.25 * t)
    for got, want in zip(cur.episode_arrays(fleet, 4),
                         ref.episode_arrays(fleet, 4)):
        np.testing.assert_array_equal(got, want)


def test_run_simulation_flat_equals_auto_at_small_n():
    """"auto" resolves to the flat path below FACTORED_AUTO_N, so the
    default config keeps the legacy trajectory bit-for-bit."""
    from repro.fl import FLConfig, run_simulation
    base = dict(n_devices=8, n_rounds=3, participation=0.5, n_train=500,
                local_epochs=1, method="drfl", selector="marl", seed=0)
    h_auto = run_simulation(FLConfig(**base))
    h_flat = run_simulation(FLConfig(**base, state_mode="flat"))
    for key in ("acc_mean", "energy", "participants", "model_choices",
                "reward"):
        assert h_auto[key] == h_flat[key], key


def test_factored_state_dim_independent_of_n_devices():
    M = 4
    dims = {n: marl_state_dim("factored", n, M)
            for n in (8, 256, 4096, 1_048_576)}
    assert len(set(dims.values())) == 1, dims
    assert dims[8] == summary_width(M)
    # flat scales linearly — the contrast the refactor removes
    assert marl_state_dim("flat", 4096, M) == 4096 * OBS_DIM
    # instantiated learners agree with the helper
    sel = MarlSelector(64, M, n_rounds=10, seed=0, state_mode="factored")
    assert sel.learner.cfg.state_dim == summary_width(M)
    # and the ModelFamily registry hook reports the same width
    fam = get_family("cnn")
    assert fam.state_summary_width() == summary_width(fam.num_submodels())


def test_auto_resolution_thresholds():
    # the boundary is INCLUSIVE on the flat side: the documented Fig. 6
    # n=256 row must keep its legacy trajectory
    assert resolve_state_mode("auto", FACTORED_AUTO_N) == "flat"
    assert resolve_state_mode("auto", FACTORED_AUTO_N + 1) == "factored"
    assert resolve_state_mode("flat", 10 ** 6) == "flat"
    with pytest.raises(ValueError):
        resolve_state_mode("fatored", 8)
    from repro.fl.spec import MarlSpec
    with pytest.raises(ValueError):
        MarlSpec(state_mode="fatored")


def test_summary_permutation_invariant():
    fleet = _drained_fleet(33, seed=7)
    s = fleet_summary(fleet, SIZES, FRACS, 3, 20)
    assert s.shape == (summary_width(len(SIZES)),)
    perm = np.random.default_rng(0).permutation(33)
    fields = {f: getattr(fleet, f)[perm]
              for f in ("compute", "p_train", "p_com", "bandwidth",
                        "battery", "remaining", "data_size", "mode_compute",
                        "mode_power", "alive", "busy_until")}
    s_perm = fleet_summary(fleet.replace(**fields, tiers=(), modes=()),
                           SIZES, FRACS, 3, 20)
    np.testing.assert_allclose(s, s_perm, rtol=1e-6, atol=1e-7)


def test_summary_tracks_fleet_dynamics():
    """Sanity on the feature semantics: draining batteries moves alive
    mass to lower battery bins and shrinks affordability fractions."""
    full = make_fleet_state(64, seed=1, backend="numpy")
    drained = full.replace(remaining=full.battery * 0.02)
    s_full = fleet_summary(full, SIZES, FRACS, 0, 10)
    s_drained = fleet_summary(drained, SIZES, FRACS, 0, 10)
    n_bins = (len(s_full) - len(SIZES) - 5) // 2
    # full fleet: all alive mass in the top battery bin; drained: bottom
    assert s_full[n_bins - 1] == pytest.approx(1.0)
    assert s_drained[0] == pytest.approx(1.0)
    # affordability of the largest model collapses when drained
    aff_full = s_full[2 * n_bins:2 * n_bins + len(SIZES)]
    aff_drained = s_drained[2 * n_bins:2 * n_bins + len(SIZES)]
    assert aff_full[-1] > aff_drained[-1]
    # energy-ratio total matches the ledger
    assert s_drained[2 * n_bins + len(SIZES)] == pytest.approx(0.02)


def test_factored_selector_trains_end_to_end():
    """run_simulation with state_mode="factored" at a small fleet: buffer
    state rows are summary-width, QMIX updates run, history is sane."""
    from repro.fl import FLConfig, run_simulation
    cfg = FLConfig(n_devices=8, n_rounds=4, participation=0.5, n_train=400,
                   local_epochs=1, method="drfl", selector="marl", seed=0,
                   state_mode="factored", marl_train_every=2,
                   marl_episodes=2)
    h = run_simulation(cfg)
    assert len(h["acc_mean"]) == 4
    assert np.isfinite(h["acc_mean"]).all()


def test_reference_loop_supports_factored_state():
    """The frozen sync reference loop sizes its internal replay buffer by
    the resolved state mode too (regression: it hard-coded the flat
    n*OBS_DIM width and crashed on factored episode commits)."""
    from repro.fl import FLConfig
    from repro.fl.simulation import _run_once_reference
    cfg = FLConfig(n_devices=6, n_rounds=2, participation=0.5, n_train=400,
                   local_epochs=1, method="drfl", selector="marl", seed=0,
                   state_mode="factored", marl_train_every=1)
    h, _, buf = _run_once_reference(cfg)
    assert buf.state.shape[-1] == summary_width(4)
    assert len(buf) >= 1
    assert np.isfinite(h["acc_mean"]).all()


def test_factored_selector_async_engine():
    from repro.fl import FLConfig, run_simulation
    cfg = FLConfig(n_devices=8, n_rounds=3, participation=0.5, n_train=400,
                   local_epochs=1, method="drfl", selector="marl", seed=1,
                   state_mode="factored", engine_mode="async")
    h = run_simulation(cfg)
    assert np.isfinite(h["acc_mean"]).all()
    assert h["n_tasks"] > 0
