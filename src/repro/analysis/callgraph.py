"""Static call graph over the repo's own functions.

Edges are resolved conservatively from three kinds of call sites:

* bare names — resolved through the module's ``from``-import table and
  local defs, plus module-level ``x_jit = jax.jit(x)`` aliases;
* ``mod.func(...)`` attribute calls where ``mod`` is an imported module
  alias (``fl_batch.run_bucket`` → ``repro.fl.batch:run_bucket``);
* ``self.method(...)`` / ``cls.method(...)`` → same-class method, and as
  a fallback ``obj.method(...)`` → EVERY repo method of that name (cheap
  over-approximation; catches ``selector.select()``-style dispatch
  without type inference).

The hot-path set used by the host-sync rule is the closure of the root
functions under these edges.  False edges only *widen* the checked set —
safe direction for a performance lint.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set

from .core import FuncInfo, Module, RepoIndex


def _method_name_index(index: RepoIndex) -> Dict[str, List[str]]:
    by_name: Dict[str, List[str]] = {}
    for qual, info in index.functions.items():
        by_name.setdefault(info.name, []).append(qual)
    return by_name


def build_call_graph(index: RepoIndex) -> Dict[str, Set[str]]:
    """qualname -> set of callee qualnames."""
    by_name = _method_name_index(index)
    graph: Dict[str, Set[str]] = {}
    for qual, info in index.functions.items():
        mod = index.modules[info.module]
        graph[qual] = _edges_for(info, mod, index, by_name)
    return graph


def _resolve_name(name: str, mod: Module, index: RepoIndex) -> List[str]:
    """Resolve a bare called name inside ``mod`` to repo qualnames."""
    # module-level jit alias: fall through to the wrapped function
    if name in mod.jit_aliases:
        name = mod.jit_aliases[name][0]
    imp = mod.from_imports.get(name)
    if imp:
        target_mod, orig = imp
        hit = index.functions.get(f"{target_mod}:{orig}")
        if hit:
            return [hit.qualname]
        # from repro.fl import engine  → module object, not a function
        sub = index.modules.get(f"{target_mod}.{orig}")
        if sub is None and index.functions.get(f"{target_mod}:{orig}") is None:
            # re-export through a package __init__: search by bare name
            cands = [q for q in index.functions
                     if q.endswith(f":{orig}")
                     and index.functions[q].class_name is None]
            if len(cands) == 1:
                return cands
        return []
    hit = index.functions.get(f"{mod.modname}:{name}")
    if hit and hit.class_name is None:
        return [hit.qualname]
    # classes called as constructors: Cls() reaches Cls.__init__
    init = index.functions.get(f"{mod.modname}:{name}.__init__")
    if init:
        return [init.qualname]
    return []


def _edges_for(info: FuncInfo, mod: Module, index: RepoIndex,
               by_name: Dict[str, List[str]]) -> Set[str]:
    edges: Set[str] = set()
    for node in ast.walk(info.node):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        if isinstance(func, ast.Name):
            edges.update(_resolve_name(func.id, mod, index))
        elif isinstance(func, ast.Attribute):
            attr = func.attr
            base = func.value
            if isinstance(base, ast.Name):
                if base.id in ("self", "cls") and info.class_name:
                    hit = index.functions.get(
                        f"{info.module}:{info.class_name}.{attr}")
                    if hit:
                        edges.add(hit.qualname)
                        continue
                alias = mod.module_aliases.get(base.id)
                if alias:
                    target = alias if alias.startswith(index.package) else None
                    if target:
                        hit = index.functions.get(f"{target}:{attr}")
                        if hit:
                            edges.add(hit.qualname)
                        continue
                imp = mod.from_imports.get(base.id)
                if imp:
                    # from repro import fl; fl.something(...)
                    submod = f"{imp[0]}.{imp[1]}"
                    hit = index.functions.get(f"{submod}:{attr}")
                    if hit:
                        edges.add(hit.qualname)
                        continue
            # fallback: every repo method with this name.  Over-approximate
            # on purpose: `selector.select(...)` must reach every Selector
            # implementation; false edges only widen the hot set.
            edges.update(q for q in by_name.get(attr, ())
                         if index.functions[q].class_name is not None)
    return edges


def reachable_from(graph: Dict[str, Set[str]],
                   roots: Iterable[str]) -> Set[str]:
    seen: Set[str] = set()
    stack = [r for r in roots if r in graph]
    while stack:
        cur = stack.pop()
        if cur in seen:
            continue
        seen.add(cur)
        stack.extend(graph.get(cur, ()))
    return seen


def resolve_roots(index: RepoIndex, root_specs: Iterable[str]) -> List[str]:
    """Expand root specs to qualnames.

    A spec is either an exact qualname (``repro.fl.engine:RoundEngine.run``),
    a ``module:Class`` pair (all methods of the class), or a bare function
    spec ``module:func``.
    """
    out: List[str] = []
    for spec in root_specs:
        if spec in index.functions:
            out.append(spec)
            continue
        modname, _, name = spec.partition(":")
        # class root: every method
        hits = [q for q, f in index.functions.items()
                if f.module == modname and f.class_name == name]
        out.extend(hits)
    return out
