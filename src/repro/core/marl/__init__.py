from repro.core.marl.networks import (agent_init, agent_step, mixer_init,
                                      mixer_apply)  # noqa: F401
from repro.core.marl.buffer import ReplayBuffer  # noqa: F401
from repro.core.marl.qmix import QmixLearner, QmixConfig  # noqa: F401
