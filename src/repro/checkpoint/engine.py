"""Full-engine checkpoints: versioned manifest + arrays file.

A checkpoint is two files written atomically in order:

    ep0000_step00000012.ckpt            -- every array leaf, via save_pytree
    ep0000_step00000012.manifest.json   -- skeleton + meta, written LAST

The manifest holds a *skeleton* describing the exact Python structure of
the engine state (nested dicts incl. int keys, lists, tuples, None,
bools, arbitrary-precision ints, exact-repr floats, strings) with array
leaves replaced by ``{"t": "arr", "key", "dtype", "shape", "jax",
"scalar"}`` descriptors pointing into the arrays file.  Because the
manifest is written last with tmp+``os.replace``, a crash mid-save
leaves at most an orphaned ``.ckpt`` that ``latest()`` never sees.

Restore is **bit-for-bit**: numpy leaves come back as numpy with their
saved dtype (float64 ``busy64`` mirrors never round-trip through jax,
which would downcast them with x64 disabled), jax leaves come back as
jax arrays, python floats round-trip exactly through JSON repr.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.io import load_pytree, save_pytree

MANIFEST_VERSION = 1
_NAME_RE = re.compile(r"ep(\d+)_step(\d+)\.manifest\.json$")

# FLConfig fields that describe *how this process runs* rather than what
# is being computed — excluded from the resume-compatibility fingerprint
# so e.g. resuming into a different checkpoint directory is legal.
FINGERPRINT_EXCLUDE = ("checkpoint_dir", "checkpoint_every",
                       "checkpoint_keep", "resume", "log_every")


class CheckpointHalt(RuntimeError):
    """Raised by the engine right after a scheduled checkpoint save when a
    test/bench asked for a simulated crash (``halt_after_saves``)."""


def config_fingerprint(cfg: Any) -> str:
    """Stable hash of the semantic config; mismatch blocks resume."""
    d = dataclasses.asdict(cfg)
    for k in FINGERPRINT_EXCLUDE:
        d.pop(k, None)
    blob = json.dumps(d, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def rng_state(gen: Optional[np.random.Generator]) -> Optional[dict]:
    """JSON-able snapshot of a numpy Generator (arbitrary-precision ints)."""
    if gen is None:
        return None
    return gen.bit_generator.state


def set_rng_state(gen: np.random.Generator, state: dict) -> None:
    gen.bit_generator.state = state


# ----------------------------------------------------------------------
# skeleton encode / decode
# ----------------------------------------------------------------------

def encode_state(tree: Any) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Split a state tree into a JSON-able skeleton + flat array dict."""
    arrays: Dict[str, np.ndarray] = {}

    def enc(x: Any) -> Any:
        if x is None:
            return {"t": "none"}
        # np scalars keep their dtype via the array path below; np.float64
        # subclasses python float, so it must be screened out here
        if isinstance(x, bool) and not isinstance(x, np.generic):
            return {"t": "bool", "v": x}
        if isinstance(x, int) and not isinstance(x, np.generic):
            return {"t": "int", "v": x}
        if isinstance(x, float) and not isinstance(x, np.generic):
            return {"t": "float", "v": x}       # json repr round-trips exactly
        if isinstance(x, str):
            return {"t": "str", "v": x}
        if isinstance(x, dict):
            for k in x:
                if not isinstance(k, (str, int)):
                    raise TypeError(f"unsupported dict key type {type(k)}")
            return {"t": "dict", "k": list(x.keys()),
                    "v": [enc(v) for v in x.values()]}
        if isinstance(x, tuple):
            return {"t": "tuple", "v": [enc(v) for v in x]}
        if isinstance(x, list):
            return {"t": "list", "v": [enc(v) for v in x]}
        if isinstance(x, (np.ndarray, np.generic)) or isinstance(x, jax.Array):
            is_jax = isinstance(x, jax.Array)
            # jaxlint: allow(host-sync-in-hot-path) -- checkpoint save is an
            # explicit barrier; every leaf must land on the host to persist.
            a = np.asarray(x)
            key = f"a{len(arrays):06d}"
            arrays[key] = a
            return {"t": "arr", "key": key, "dtype": str(a.dtype),
                    "shape": list(a.shape), "jax": is_jax,
                    "scalar": isinstance(x, np.generic)}
        raise TypeError(f"unsupported leaf type in engine state: {type(x)}")

    return enc(tree), arrays


def decode_state(skeleton: dict, arrays: Dict[str, np.ndarray]) -> Any:
    import jax.numpy as jnp

    def dec(d: dict) -> Any:
        t = d["t"]
        if t == "none":
            return None
        if t in ("bool", "int", "float", "str"):
            return d["v"]
        if t == "dict":
            return {k: dec(v) for k, v in zip(d["k"], d["v"])}
        if t == "tuple":
            return tuple(dec(v) for v in d["v"])
        if t == "list":
            return [dec(v) for v in d["v"]]
        if t == "arr":
            a = arrays[d["key"]]
            if d.get("scalar"):
                return a[()]
            return jnp.asarray(a) if d["jax"] else a
        raise ValueError(f"unknown skeleton tag {t!r}")

    return dec(skeleton)


def _collect_array_descs(skeleton: Any, out: Dict[str, dict]) -> None:
    if isinstance(skeleton, dict):
        if skeleton.get("t") == "arr":
            out[skeleton["key"]] = skeleton
            return
        for v in skeleton.values():
            _collect_array_descs(v, out)
    elif isinstance(skeleton, (list, tuple)):
        for v in skeleton:
            _collect_array_descs(v, out)


# ----------------------------------------------------------------------
# checkpointer
# ----------------------------------------------------------------------

class EngineCheckpointer:
    """Keep-last-k rotating full-engine checkpoints in one directory."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = max(1, int(keep))
        os.makedirs(directory, exist_ok=True)

    def _stem(self, episode: int, step: int) -> str:
        return os.path.join(self.directory,
                            f"ep{episode:04d}_step{step:08d}")

    def save(self, state: Any, meta: Dict[str, Any]) -> str:
        episode = int(meta.get("episode", 0))
        step = int(meta["step"])
        stem = self._stem(episode, step)
        skeleton, arrays = encode_state(state)
        save_pytree(stem + ".ckpt", arrays)
        manifest = {"format": "drfl-engine", "version": MANIFEST_VERSION,
                    "meta": dict(meta),
                    "arrays_file": os.path.basename(stem) + ".ckpt",
                    "skeleton": skeleton}
        tmp = stem + ".manifest.json.tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, stem + ".manifest.json")
        self._rotate()
        return stem + ".manifest.json"

    # jaxlint: allow(host-sync-in-hot-path) -- int() of regex match
    # groups (filenames), no device values in sight
    def _manifests(self) -> List[Tuple[Tuple[int, int], str]]:
        out = []
        if not os.path.isdir(self.directory):
            return out
        for name in os.listdir(self.directory):
            m = _NAME_RE.match(name)
            if m:
                out.append(((int(m.group(1)), int(m.group(2))),
                            os.path.join(self.directory, name)))
        return sorted(out)

    def latest(self) -> Optional[str]:
        ms = self._manifests()
        return ms[-1][1] if ms else None

    def _rotate(self) -> None:
        ms = self._manifests()
        for _, path in ms[:-self.keep]:
            ckpt = path[:-len(".manifest.json")] + ".ckpt"
            for p in (path, ckpt):
                try:
                    os.remove(p)
                except OSError:
                    pass

    def load(self, path: Optional[str] = None) -> Tuple[Any, Dict[str, Any]]:
        path = path or self.latest()
        if path is None:
            raise FileNotFoundError(
                f"no engine checkpoint found in {self.directory!r}")
        with open(path) as f:
            manifest = json.load(f)
        if manifest.get("format") != "drfl-engine":
            raise ValueError(f"{path!r} is not an engine checkpoint manifest")
        if manifest.get("version") != MANIFEST_VERSION:
            raise ValueError(
                f"manifest version {manifest.get('version')} unsupported "
                f"(this build reads version {MANIFEST_VERSION})")
        descs: Dict[str, dict] = {}
        _collect_array_descs(manifest["skeleton"], descs)
        template = {k: np.zeros(tuple(d["shape"]), np.dtype(d["dtype"]))
                    for k, d in descs.items()}
        arrays_path = os.path.join(os.path.dirname(path),
                                   manifest["arrays_file"])
        arrays = load_pytree(arrays_path, template, backend="numpy")
        state = decode_state(manifest["skeleton"], arrays)
        return state, manifest["meta"]
