"""Dry-run machinery on a debug mesh (8 host devices, subprocess so the main
test process keeps its single CPU device).  The full 512-device production
dry-run for all 40 combos runs via ``python -m repro.launch.dryrun --all``
(results recorded in EXPERIMENTS.md §Dry-run)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_dryrun(args, devices="8"):
    env = dict(os.environ, PYTHONPATH=SRC, DRYRUN_DEVICES=devices,
               JAX_PLATFORMS="cpu")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, timeout=1200)


@pytest.mark.parametrize("arch,shape", [
    ("whisper-medium", "train_4k"),
    ("xlstm-1.3b", "decode_32k"),
])
def test_debug_mesh_dryrun(arch, shape, tmp_path):
    out = tmp_path / "res.json"
    r = _run_dryrun(["--arch", arch, "--shape", shape, "--mesh", "debug",
                     "--json", str(out)])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    res = json.loads(out.read_text())[0]
    assert res["ok"]
    assert res["roofline"]["t_compute_s"] > 0
    assert res["memory"]["total_hbm_bytes"] > 0


def test_debug_multipod_mesh(tmp_path):
    out = tmp_path / "res.json"
    r = _run_dryrun(["--arch", "whisper-medium", "--shape", "decode_32k",
                     "--mesh", "debug-multi", "--json", str(out)])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    res = json.loads(out.read_text())[0]
    assert res["ok"] and res["devices"] == 8


def test_sharding_rules_on_debug_mesh():
    """Param specs: rule table + divisibility fallback, on a real mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_debug_mesh
from repro.sharding.rules import spec_for
mesh = make_debug_mesh()  # (2,2) data,model
# attention wq [L, d, H*hd] -> (None, data, model)
assert spec_for("blocks/attn/wq/w", (4, 64, 64), mesh) == P(None, "data", "model")
# moe experts divisible -> expert axis sharded
assert spec_for("blocks/moe/w_gate", (4, 8, 64, 64), mesh) == P(None, "model", "data", None)
# indivisible expert count -> falls back
assert spec_for("blocks/moe/w_gate", (4, 3, 64, 64), mesh) == P(None, None, "data", "model")
# 1-d params replicate
assert spec_for("blocks/attn_norm/scale", (64,), mesh) == P()
# odd dims fall back to replication
assert spec_for("blocks/mlp/w_up/w", (4, 63, 65), mesh) == P(None, None, None)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr
