"""Step builders: train_step / prefill_step / serve_step per architecture.

The train step computes a sequence-chunked cross-entropy (never
materialises the full ``[B, S, V]`` logits tensor), per-layer remat happens
inside the model ``apply``, and AdamW runs on donated state.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import build
from repro.optim import adamw_init, adamw_update, make_schedule


# ---------------------------------------------------------------------------
# chunked cross-entropy
# ---------------------------------------------------------------------------


def chunked_cross_entropy(hidden, w_unembed, labels, chunk: int):
    """hidden: [B,S,d]; w_unembed: [d,V]; labels: [B,S] int32 -> mean nll.

    Scans over sequence chunks; each step materialises only [B,chunk,V]
    (sharded) logits.  Labels < 0 are masked out.
    """
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # fallback: odd lengths take one chunk
    n = S // chunk
    hc = jnp.moveaxis(hidden.reshape(B, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)

    def body(acc, xs):
        h, lab = xs
        logits = (h @ w_unembed).astype(jnp.float32)           # [B,c,V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1)[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        nll = (lse - tgt) * mask
        return (acc[0] + nll.sum(), acc[1] + mask.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def _unembed(model, params):
    mod_cfg = model.cfg
    if mod_cfg.tie_embeddings:
        return params["embed"]["emb"].T
    return params["unembed"]["w"]


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def make_train_state(model, key, tcfg: TrainConfig):
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params)}


def train_state_shape(model, tcfg: TrainConfig):
    return jax.eval_shape(lambda k: make_train_state(model, k, tcfg),
                          jax.random.PRNGKey(0))


def build_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    model = build(cfg)
    schedule = make_schedule(tcfg.schedule, tcfg.learning_rate,
                             tcfg.warmup_steps, tcfg.total_steps)

    def loss_fn(params, batch):
        extras = {k: batch[k] for k in batch
                  if k not in ("tokens", "labels")}
        hidden, aux = model.apply(params, batch["tokens"], extras,
                                  remat=tcfg.remat, use_pallas=tcfg.use_pallas,
                                  attn_chunk=tcfg.attn_chunk)
        loss = chunked_cross_entropy(hidden, _unembed(model, params),
                                     batch["labels"], tcfg.loss_chunk)
        if cfg.num_experts:
            loss = loss + cfg.moe_aux_coef * aux / max(cfg.num_layers, 1)
        return loss

    def train_step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        lr = schedule(state["opt"]["step"])
        new_params, new_opt, m = adamw_update(
            grads, state["opt"], state["params"], lr=lr,
            beta1=tcfg.beta1, beta2=tcfg.beta2, eps=tcfg.eps,
            weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)
        metrics = {"loss": loss, "lr": lr, **m}
        return {"params": new_params, "opt": new_opt}, metrics

    return model, train_step


# ---------------------------------------------------------------------------
# FL-over-pods train step (the paper's Step 2 as a lowered program)
# ---------------------------------------------------------------------------


def build_fl_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """DR-FL in the multi-pod mapping: every pod (client) trains a
    depth-prefix submodel of the replicated global model.

    The batch carries ``layer_gates [L, B]`` — per-example submodel masks
    (constant within a pod's batch shard, so the gate tensor is sharded over
    the same batch axes as the tokens) and ``layer_counts [L]`` — how many
    pods train each layer.  Because masked-out layers are exact identities,
    their parameter gradients vanish for non-training pods; the global
    batch-mean gradient therefore equals the DR-FL masked SUM over
    contributing clients divided by the total client count.  Rescaling
    stacked-layer grads by ``n_clients / count_l`` turns that into the
    paper's layer-aligned masked MEAN (Eq. 2 generalised) — one jitted
    program, aggregation happening inside the ordinary gradient psum over
    the pod axis.  Only the dense/MoE decoder families support per-example
    gates (DESIGN.md §Arch-applicability)."""
    model = build(cfg)
    schedule = make_schedule(tcfg.schedule, tcfg.learning_rate,
                             tcfg.warmup_steps, tcfg.total_steps)

    def loss_fn(params, batch):
        hidden, aux = model.apply(params, batch["tokens"], {},
                                  layer_mask=batch["layer_gates"],
                                  remat=tcfg.remat, use_pallas=tcfg.use_pallas,
                                  attn_chunk=tcfg.attn_chunk)
        loss = chunked_cross_entropy(hidden, _unembed(model, params),
                                     batch["labels"], tcfg.loss_chunk)
        if cfg.num_experts:
            loss = loss + cfg.moe_aux_coef * aux / max(cfg.num_layers, 1)
        return loss

    def _rescale(grads, counts, n_clients):
        scale = n_clients / jnp.maximum(counts, 1.0)          # [L]

        def leaf(g):
            if g.ndim >= 1 and g.shape[0] == cfg.num_layers:
                return (g.astype(jnp.float32)
                        * scale.reshape((-1,) + (1,) * (g.ndim - 1))
                        ).astype(g.dtype)
            return g
        return jax.tree.map(leaf, grads)

    def fl_train_step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        grads = _rescale(grads, batch["layer_counts"],
                         jnp.float32(batch["n_clients"]))
        lr = schedule(state["opt"]["step"])
        new_params, new_opt, m = adamw_update(
            grads, state["opt"], state["params"], lr=lr,
            beta1=tcfg.beta1, beta2=tcfg.beta2, eps=tcfg.eps,
            weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, "lr": lr, **m})

    return model, fl_train_step


def build_fl_bucketed_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Beyond-paper optimisation of the FL-over-pods step (§Perf, C-line).

    The masked step (``build_fl_train_step``) COMPUTES every layer for every
    client and multiplies masked layers by 0 — "useless training" in
    silicon; its useful-FLOPs ratio is mean(prefix)/L.  Because DR-FL
    submodels are *depth prefixes* from a fixed exit table, clients can be
    **statically bucketed by submodel**: the batch arrives bucket-major
    ([n_exits, B/n_exits, S]) and each bucket scans ONLY its first
    ``exit_points[b]`` layers (a sliced stacked-param tree — gradients for
    unsliced layers are exact zeros by construction).  Per-layer gradient
    rescaling to the DR-FL masked mean uses the static exit table.  No
    retracing across rounds: the dispatch order changes, the bucket shapes
    don't."""
    from repro.core.layerwise import exit_points
    model = build(cfg)
    schedule = make_schedule(tcfg.schedule, tcfg.learning_rate,
                             tcfg.warmup_steps, tcfg.total_steps)
    exits = list(exit_points(cfg))
    nb = len(exits)
    L = cfg.num_layers
    # static per-layer coverage counts
    counts = [sum(1 for k in exits if l < k) for l in range(L)]

    def _slice_blocks(params, k):
        import dataclasses as _dc
        sliced = dict(params)
        sliced["blocks"] = jax.tree.map(lambda a: a[:k], params["blocks"])
        return sliced, _dc.replace(cfg, num_layers=k)

    def loss_fn(params, batch):
        tokens = batch["tokens"]                # [nb, B/nb, S]
        labels = batch["labels"]
        total = 0.0
        from repro.models import transformer as T
        for b, k in enumerate(exits):
            sub, cfg_b = _slice_blocks(params, k)
            hidden, _ = T.apply(sub, cfg_b, tokens[b], remat=tcfg.remat,
                                use_pallas=tcfg.use_pallas,
                                attn_chunk=tcfg.attn_chunk)
            total = total + chunked_cross_entropy(
                hidden, _unembed(model, params), labels[b], tcfg.loss_chunk)
        return total / nb

    def _rescale(grads):
        scale = jnp.asarray([nb / max(c, 1) for c in counts], jnp.float32)

        def leaf(g):
            if g.ndim >= 1 and g.shape[0] == L:
                return (g.astype(jnp.float32)
                        * scale.reshape((-1,) + (1,) * (g.ndim - 1))
                        ).astype(g.dtype)
            return g
        return jax.tree.map(leaf, grads)

    def fl_train_step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        grads = _rescale(grads)
        lr = schedule(state["opt"]["step"])
        new_params, new_opt, m = adamw_update(
            grads, state["opt"], state["params"], lr=lr,
            beta1=tcfg.beta1, beta2=tcfg.beta2, eps=tcfg.eps,
            weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, "lr": lr, **m})

    return model, fl_train_step, nb


def fl_batch_extras(cfg: ModelConfig, shape: ShapeConfig, n_clients: int = 4):
    """ShapeDtypeStructs for the FL-step extra inputs."""
    import jax.numpy as jnp
    B = shape.global_batch
    return {
        "layer_gates": jax.ShapeDtypeStruct((cfg.num_layers, B), jnp.float32),
        "layer_counts": jax.ShapeDtypeStruct((cfg.num_layers,), jnp.float32),
        "n_clients": jax.ShapeDtypeStruct((), jnp.float32),
    }


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, tcfg: Optional[TrainConfig] = None):
    """Batched scoring/prefill: forward pass + last-position logits."""
    model = build(cfg)
    tcfg = tcfg or TrainConfig()

    def prefill_step(params, batch):
        extras = {k: batch[k] for k in batch if k != "tokens"}
        hidden, _ = model.apply(params, batch["tokens"], extras,
                                remat="none", use_pallas=tcfg.use_pallas,
                                attn_chunk=tcfg.attn_chunk)
        return model.logits(params, hidden[:, -1:, :])

    return model, prefill_step


def build_serve_step(cfg: ModelConfig, window_override: Optional[int] = None):
    """One-token greedy decode with a persistent cache (donated)."""
    model = build(cfg)

    def serve_step(params, cache, tokens, pos):
        kw = {}
        if window_override is not None:
            kw["window"] = window_override
        logits, new_cache = model.decode_step(params, cache, tokens, pos, **kw)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache

    return model, serve_step


# ---------------------------------------------------------------------------
# long-context handling
# ---------------------------------------------------------------------------


def adapt_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Auto-enable the SWA long-context variant for full-attention archs on
    ``long_500k`` (documented deviation — DESIGN.md §5)."""
    full_attn = cfg.family in ("dense", "moe", "vlm", "audio") and cfg.window == 0
    if shape.name == "long_500k" and full_attn:
        return dataclasses.replace(cfg, window=8192)
    return cfg
