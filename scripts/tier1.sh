#!/usr/bin/env bash
# Tier-1 verify — the one reproducible entry point for the suite.
# Runs the exact command recorded in ROADMAP.md from any working directory;
# extra args pass through to pytest (e.g. scripts/tier1.sh -m 'not slow').
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
