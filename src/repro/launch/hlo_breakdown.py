"""Profiling aid for §Perf: per-op / per-computation cost attribution over
the compiled HLO (same loop-aware walk as hlo_cost, but keeping the
breakdown instead of totals).  This is the 'profile' available without real
hardware — it tells you WHICH collectives/tensors dominate a term."""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

from repro.launch.hlo_cost import HloCost, _ATTR_RE, _shape_bytes, _TRIVIAL

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def breakdown(text: str, top: int = 12) -> Dict[str, List[Tuple]]:
    hc = HloCost(text)
    mod = hc.mod
    coll: Dict[Tuple, float] = {}
    mem: Dict[Tuple, float] = {}
    flops: Dict[Tuple, float] = {}

    def walk(name: str, mult: float, top_level: bool, seen):
        if name in seen or name not in mod.computations:
            return
        seen = seen | {name}
        for inst in mod.computations[name]:
            op = inst.op
            if op == "while":
                attrs = dict(_ATTR_RE.findall(inst.line))
                walk(attrs.get("body", ""), mult * hc._trip(inst), True, seen)
                continue
            if op == "conditional":
                continue
            for kind, target in _ATTR_RE.findall(inst.line):
                if kind in ("calls", "to_apply"):
                    walk(target, mult, False, seen)
            if op == "dot":
                flops[(name[:48], "dot")] = flops.get((name[:48], "dot"), 0.0) \
                    + mult * hc._dot_flops(name, inst)
            is_coll = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            if is_coll and not op.endswith("-done"):
                b = _shape_bytes(inst.shape) * (2 if is_coll == "all-reduce" else 1)
                key = (name[:48], is_coll, inst.shape[:48])
                coll[key] = coll.get(key, 0.0) + mult * b
            if top_level and op not in _TRIVIAL:
                b = 2.0 * hc._effective_out_bytes(name, inst)
                key = (name[:48], op)
                mem[key] = mem.get(key, 0.0) + mult * b

    walk(mod.entry, 1.0, True, frozenset())
    out = {}
    for label, d in (("collective_bytes", coll), ("hbm_bytes", mem),
                     ("flops", flops)):
        out[label] = sorted(d.items(), key=lambda kv: -kv[1])[:top]
    return out


def print_breakdown(text: str, top: int = 12):
    b = breakdown(text, top)
    for label, rows in b.items():
        print(f"--- top {label} ---")
        for key, v in rows:
            print(f"  {v:.4g}  {key}")
    return b
