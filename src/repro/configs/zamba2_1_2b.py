"""Zamba2-1.2B — Mamba2 backbone + shared attention block [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="mamba-hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    head_dim=64,
    ssm_state=64, ssm_expand=2, ssm_chunk=64,
    shared_attn_every=6,
    exit_points=(10, 19, 29, 38),
    source="arXiv:2411.15242",
)
