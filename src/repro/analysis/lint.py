"""jaxlint driver: config, rule execution, text/JSON reports.

``run_lint(LintConfig(repo_root=...))`` builds the :class:`RepoIndex`,
runs every registered rule, applies suppression pragmas, and returns a
:class:`Report`.  Exit-code contract (used by CI): 0 when every finding
is suppressed with a reason, 1 when any unsuppressed finding remains,
2 on driver misuse.

Every repo-specific anchor a rule needs (hot-path roots, the FleetState
field tuple, the sharding rule table, the kernels directory, the frozen
ledger) lives on :class:`LintConfig` so the fixture tests in
``tests/test_analysis.py`` can point the same rules at tmp mini-repos.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

from .core import Finding, RepoIndex, apply_pragmas


@dataclasses.dataclass
class LintConfig:
    repo_root: str
    src_rel: str = "src"
    package: str = "repro"
    #: run only these rule ids (None = all registered rules)
    rules: Optional[Sequence[str]] = None

    # -- host-sync-in-hot-path ---------------------------------------------
    #: call-graph roots: "module:Class" (every method) or "module:func"
    hot_roots: Tuple[str, ...] = (
        "repro.fl.engine:RoundEngine",
        "repro.core.selection:dual_selection_energy_step",
        "repro.models.family:ModelFamily.client_update",
    )
    #: functions whose RETURN VALUE is host-side data (they pay their own
    #: documented sync internally).  "module:name" entries match resolved
    #: calls; bare names match any attribute/bare call of that name.
    host_returning: Tuple[str, ...] = (
        "repro.fl.server:evaluate",
        "repro.core.fleet:fleet_total_remaining",
        "repro.fl.client:client_update_seed",
        "evaluate", "select", "episode_arrays", "unstacked",
        "device_view", "to_devices", "cost_model",
    )
    #: attribute names that always denote host-side state when they appear
    #: anywhere in an attribute chain (``cfg.n_devices``, ``self.cfg.seed``,
    #: ``self.rng.integers``)
    host_attrs: Tuple[str, ...] = ("cfg", "config", "rng")

    # -- pytree-field-coverage ---------------------------------------------
    fleet_module: str = "repro.core.fleet"
    fleet_fields_name: str = "_ARRAY_FIELDS"
    sharding_module: str = "repro.sharding.fleet"
    sharding_rules_name: str = "FLEET_RULES"
    summary_func: str = "repro.core.fleet:fleet_summary"
    summary_exclusions_name: str = "SUMMARY_EXCLUDED_FIELDS"
    checkpoint_module: str = "repro.checkpoint.io"
    checkpoint_fields_name: str = "FLEET_CHECKPOINT_FIELDS"

    # -- kernel-parity-contract --------------------------------------------
    kernels_rel: str = "src/repro/kernels"
    kernels_test_rel: str = "tests/test_kernels.py"

    # -- frozen-reference-integrity ----------------------------------------
    frozen_ledger_rel: str = "src/repro/analysis/frozen_refs.json"
    #: (id, repo-relative file, top-level name, "function" | "class")
    frozen_targets: Tuple[Tuple[str, str, str, str], ...] = (
        ("sync-reference-loop", "src/repro/fl/simulation.py",
         "_run_once_reference", "function"),
        ("pre-factoring-selector", "tests/test_factored_state.py",
         "_PreFactoringMarlSelector", "class"),
    )


@dataclasses.dataclass
class Report:
    root: str
    rules: List[str]
    findings: List[Finding]

    @property
    def unsuppressed(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def exit_code(self) -> int:
        return 1 if self.unsuppressed else 0

    def to_json(self) -> dict:
        return {
            "version": 1,
            "root": self.root,
            "rules": list(self.rules),
            "summary": {
                "total": len(self.findings),
                "suppressed": len(self.findings) - len(self.unsuppressed),
                "unsuppressed": len(self.unsuppressed),
            },
            "findings": [f.to_json() for f in self.findings],
        }

    def render(self, verbose: bool = False) -> str:
        lines = []
        shown = self.findings if verbose else self.unsuppressed
        for f in sorted(shown, key=lambda f: (f.file, f.line, f.rule)):
            lines.append(f.render())
        n_sup = len(self.findings) - len(self.unsuppressed)
        lines.append(f"jaxlint: {len(self.unsuppressed)} unsuppressed "
                     f"finding(s), {n_sup} suppressed, "
                     f"{len(self.rules)} rule(s)")
        return "\n".join(lines)


def run_lint(config: LintConfig) -> Report:
    from . import rules as rules_pkg
    index = RepoIndex(config.repo_root, config.src_rel, config.package)
    active = {name: fn for name, fn in rules_pkg.ALL_RULES.items()
              if config.rules is None or name in config.rules}
    findings: List[Finding] = list(index.parse_errors)
    for name, rule in active.items():
        findings.extend(rule(index, config))
    findings = apply_pragmas(findings, index)
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return Report(root=os.path.abspath(config.repo_root),
                  rules=sorted(active), findings=findings)


def write_json(report: Report, path: str) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(report.to_json(), fh, indent=2, sort_keys=True)
        fh.write("\n")
