import os
import sys

# Make `pytest tests/` work without PYTHONPATH=src (and never set XLA device
# flags here — smoke tests must see exactly 1 CPU device; the dry-run tests
# spawn subprocesses with their own DRYRUN_DEVICES).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Offline fallback: if the real `hypothesis` package is missing, expose the
# vendored minimal implementation (repro/_vendor/hypothesis) so the
# property-test modules still collect and run.  An installed hypothesis
# always takes precedence because the vendor dir is only added on failure.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src",
                                    "repro", "_vendor"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (large-fleet smokes); deselect with "
        "-m 'not slow'")
