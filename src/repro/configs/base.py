"""Config dataclasses shared across the framework.

Every assigned architecture is expressed as a :class:`ModelConfig`.  Input
shapes (train_4k / prefill_32k / decode_32k / long_500k) are
:class:`ShapeConfig` entries in :data:`INPUT_SHAPES`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description (family-polymorphic).

    ``family`` selects the block implementation:
      dense | moe | ssm (xlstm) | mamba-hybrid | vlm | audio (enc-dec)
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_aux_coef: float = 0.01
    moe_capacity_factor: float = 1.25
    moe_decode_impl: str = "gather"   # gather (weight-streaming) | dispatch
                                      # (token all-to-all via capacity buffers)

    # --- SSM / recurrent ---
    ssm_state: int = 0                # Mamba2 state size N
    ssm_expand: int = 2               # inner-dim expansion factor
    ssm_chunk: int = 64               # SSD chunk length
    # xLSTM: blocks alternate mLSTM (even) / sLSTM (odd)

    # --- hybrid (zamba2-style) ---
    shared_attn_every: int = 0        # apply the shared attn block every k SSM blocks

    # --- attention ---
    window: int = 0                   # sliding-window size; 0 = full causal
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    attn_bias: bool = False
    mlp_bias: bool = False

    # --- VLM ---
    cross_attn_every: int = 0         # a cross-attn layer after every k self layers
    num_image_tokens: int = 0         # stub frontend: precomputed patch embeds

    # --- audio enc-dec ---
    encoder_layers: int = 0
    num_audio_frames: int = 0         # stub frontend: precomputed frame embeds

    # --- numerics ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- DR-FL layer-wise exits (depth-prefix submodels, paper §4.2) ---
    exit_points: Tuple[int, ...] = ()

    # --- provenance ---
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_decoder_only(self) -> bool:
        return self.family != "audio"

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab_size, self.hd
        nh, nkv, L = self.num_heads, self.num_kv_heads, self.num_layers
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        if self.family == "ssm":  # xlstm blocks: internal up/down projections
            inner = self.ssm_expand * d
            per_layer = d * inner * 3 + inner * d + 2 * d  # qkv-ish + out + norms
            return v * d * (1 if self.tie_embeddings else 2) + L * per_layer
        if self.family == "mamba-hybrid":
            inner = self.ssm_expand * d
            mamba = d * (2 * inner + 2 * self.num_heads * self.ssm_state) + inner * d
            shared = attn + 3 * d * f  # one shared block, counted once
            return v * d * 2 + L * (mamba + 2 * d) + shared
        if self.family == "moe":
            ff = 3 * d * f * self.num_experts + d * self.num_experts  # experts + router
        else:
            ff = 3 * d * f
        per_layer = attn + ff + 2 * d
        n = v * d * (1 if self.tie_embeddings else 2) + L * per_layer + d
        if self.family == "vlm":
            n_cross = self.num_layers // max(self.cross_attn_every, 1)
            n += n_cross * (attn + 3 * d * f + 2 * d)
        if self.family == "audio":
            n += self.encoder_layers * (attn + 3 * d * f + 2 * d)
            n += self.num_layers * attn  # decoder cross-attention
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_ff = 3 * d * f * self.num_experts
        active_ff = 3 * d * f * self.experts_per_token
        return self.param_count() - self.num_layers * (dense_ff - active_ff)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Optimizer / training-loop hyperparameters."""

    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | linear | constant
    microbatch: int = 0               # 0 = no microbatching
    remat: str = "full"               # full | dots | none
    loss_chunk: int = 512             # sequence-chunked CE (avoid [B,S,V] logits)
    use_pallas: bool = False          # opt-in kernels (XLA default for dry-run)
    attn_chunk: int = 0               # >0: online-softmax KV-block attention


def reduced(cfg: ModelConfig, **over) -> ModelConfig:
    """A smoke-test variant of the same family: 2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    nh = max(1, min(cfg.num_heads, 4))
    nkv = max(1, min(cfg.num_kv_heads, nh))
    changes = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d,
        num_heads=nh,
        num_kv_heads=nkv,
        head_dim=d // nh,
        d_ff=0 if cfg.d_ff == 0 else min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_chunk=16,
        window=min(cfg.window, 64) if cfg.window else 0,
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        num_image_tokens=min(cfg.num_image_tokens, 16) if cfg.num_image_tokens else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        num_audio_frames=min(cfg.num_audio_frames, 32) if cfg.num_audio_frames else 0,
        shared_attn_every=2 if cfg.shared_attn_every else 0,
        exit_points=(1, 2) if cfg.exit_points else (),
        dtype="float32",
    )
    changes.update(over)
    return dataclasses.replace(cfg, **changes)
