"""Family conformance suite (ISSUE 10).

EVERY registered :class:`~repro.models.family.ModelFamily` — cnn, mlp and
the early-exit transformer — must satisfy the same layer-wise contract
before the FL stack will treat it as interchangeable:

* layout agreement — ``stack_groups`` / ``stack_template`` /
  ``update_mask`` / ``held_groups`` describe the SAME ``[stem] + stages
  + exits`` group decomposition, and ``unstack_groups`` inverts
  ``stack_groups``;
* submodel monotonicity — deeper depth prefixes strictly grow in bytes
  and FLOPs, and ``submodel_tree(params, m)`` holds exactly ``m + 1``
  stages/exits;
* engine parity — ``run_simulation`` (sync RoundEngine) matches the
  frozen reference loop ``_run_once_reference`` bit-for-bit at n=8;
* executor parity — the bucketed-vmap cohort executor agrees with the
  per-client path on every delta;
* cost-model sanity — positive byte sizes, fractions in (0, 1] ending
  at exactly 1.0, both strictly increasing;
* property tests (hypothesis) — mask/template invariants hold for
  arbitrary (m, scale) and arbitrary widths.

Transformer-specific pins live at the bottom: single-compilation across
all traced depths, Pallas-interpret vs ref-math forward parity, the
exactly-zero-delta-past-prefix contract, and the frozen n=8 sync/async
trajectories (``tests/data/frozen_transformer_n8.json``).
"""
import json
import os

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import FLConfig, run_simulation
from repro.fl import batch as fl_batch
from repro.fl import client as fl_client
from repro.models.family import get_family, known_families

FAMILIES = sorted(known_families())
FROZEN_TRANSFORMER = os.path.join(os.path.dirname(__file__), "data",
                                  "frozen_transformer_n8.json")

# per-family small-but-real init/bench knobs (CPU budget)
_WIDTH = {"cnn": 0.06, "mlp": 0.25, "transformer": 0.25}
_HW = 8


def _params(name, num_classes=10):
    fam = get_family(name)
    return fam.init(jax.random.PRNGKey(0), num_classes,
                    width_mult=_WIDTH[name], hw=_HW)


def _data(name, n=200, seed=0):
    """The family's OWN corpus — rows are opaque to the FL stack."""
    return get_family(name).make_dataset(n, 10, hw=_HW, noise=1.0, seed=seed)


def _cfg(name, **kw):
    base = dict(n_devices=6, n_rounds=2, participation=0.5, n_train=400,
                local_epochs=1, method="drfl", selector="greedy", seed=1,
                model_family=name, hw=_HW, width_mult=_WIDTH[name],
                energy_scale=0.05)
    base.update(kw)
    return FLConfig(**base)


# ---------------------------------------------------------------------------
# layout agreement: groups / template / masks / held flags
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", FAMILIES)
def test_group_layout_agreement(name):
    fam = get_family(name)
    params = _params(name)
    M = fam.num_submodels()
    assert M >= 2
    assert len(params["stages"]) == M and len(params["exits"]) == M

    groups = fam.stack_groups(params)
    legacy = [params["stem"]] + list(params["stages"]) + list(params["exits"])
    assert len(groups) == 1 + 2 * M
    for g, l in zip(groups, legacy):
        assert jax.tree.structure(g) == jax.tree.structure(l)

    template = fam.stack_template(params)
    sizes = tuple(sum(l.size for l in jax.tree.leaves(g)) for g in groups)
    assert template.group_sizes == sizes
    assert fam.stack_template(params) is template        # cache hit

    rebuilt = fam.unstack_groups(params, groups)
    for a, b in zip(jax.tree.leaves(rebuilt), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    for m in range(M):
        held = fam.held_groups(params, m)
        stage_held = [i <= m for i in range(M)]
        assert held == [True] + stage_held + stage_held


@pytest.mark.parametrize("name", FAMILIES)
def test_update_mask_matches_held_groups(name):
    fam = get_family(name)
    params = _params(name)
    for m in range(fam.num_submodels()):
        mask = fam.update_mask(params, m, scale=1.0)
        assert jax.tree.structure(mask) == jax.tree.structure(params)
        held = fam.held_groups(params, m)
        for g, h in zip(fam.stack_groups(mask), held):
            for leaf in jax.tree.leaves(g):
                assert float(leaf) == (1.0 if h else 0.0)
        assert fam.update_mask(params, m, scale=1.0) is mask    # cache hit


# ---------------------------------------------------------------------------
# submodel monotonicity + cost model
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", FAMILIES)
def test_submodel_monotonicity(name):
    fam = get_family(name)
    params = _params(name)
    M = fam.num_submodels()
    nbytes, flops = [], []
    for m in range(M):
        sub = fam.submodel_tree(params, m)
        assert len(sub["stages"]) == m + 1 and len(sub["exits"]) == m + 1
        nbytes.append(sum(l.size * l.dtype.itemsize
                          for l in jax.tree.leaves(fam._size_tree(params, m))))
        flops.append(fam.flops_per_sample(m, _HW, _WIDTH[name]))
    assert all(a < b for a, b in zip(nbytes, nbytes[1:]))
    assert all(a < b for a, b in zip(flops, flops[1:]))


@pytest.mark.parametrize("name", FAMILIES)
def test_cost_model_positive_and_monotone(name):
    fam = get_family(name)
    sizes, fractions = fam.cost_model(10)
    M = fam.num_submodels()
    assert len(sizes) == len(fractions) == M
    assert all(s > 0 for s in sizes)
    assert all(0.0 < f <= 1.0 for f in fractions)
    assert fractions[-1] == 1.0
    assert all(a < b for a, b in zip(sizes, sizes[1:]))
    assert all(a < b for a, b in zip(fractions, fractions[1:]))
    assert fam.cost_model(10) == (sizes, fractions)      # cached, stable


# ---------------------------------------------------------------------------
# forward + training semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", FAMILIES)
def test_truncated_tree_is_a_forward_prefix(name):
    """Exit i of submodel m equals exit i of the full model (depth-prefix
    semantics: truncation never changes shallow computation)."""
    fam = get_family(name)
    params = _params(name)
    x, _ = _data(name, n=8)
    full = fam.apply_all_exits(params, jnp.asarray(x))
    assert len(full) == fam.num_submodels()
    assert all(o.shape == (8, 10) for o in full)
    for m in range(fam.num_submodels()):
        sub_outs = fam.apply_all_exits(fam.submodel_tree(params, m),
                                       jnp.asarray(x))
        assert len(sub_outs) == m + 1
        for a, b in zip(sub_outs, full):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("name", FAMILIES)
def test_drfl_delta_zero_past_prefix(name):
    """client_update("drfl") returns full-structure deltas that are
    EXACTLY zero outside the held prefix — the layer-aligned aggregation
    contract."""
    fam = get_family(name)
    params = _params(name)
    x, y = _data(name, n=64)
    m = 1
    delta, loss = fam.client_update("drfl", params, m, x, y, epochs=1,
                                    batch=32, lr=0.05, seed=7)
    assert jax.tree.structure(delta) == jax.tree.structure(params)
    assert np.isfinite(loss)
    for si in range(fam.num_submodels()):
        leaves = (jax.tree.leaves(delta["stages"][si])
                  + jax.tree.leaves(delta["exits"][si]))
        if si <= m:
            assert any(np.abs(np.asarray(l)).sum() > 0 for l in leaves)
        else:
            for l in leaves:
                np.testing.assert_array_equal(np.asarray(l), 0.0)


# ---------------------------------------------------------------------------
# engine parity: sync RoundEngine == frozen reference loop, bit-for-bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", FAMILIES)
def test_sync_engine_matches_frozen_reference_n8(name):
    from repro.fl.simulation import _run_once_reference
    cfg = _cfg(name, n_devices=8, n_rounds=3)
    h_engine = run_simulation(cfg)
    h_ref, _, _ = _run_once_reference(cfg)
    for key in ("acc_mean", "energy", "round_time", "alive", "participants",
                "model_choices", "reward", "dropouts"):
        assert h_engine[key] == h_ref[key], key
    for a, b in zip(h_engine["acc"], h_ref["acc"]):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("name", FAMILIES)
def test_run_simulation_async_completes(name):
    h = run_simulation(_cfg(name, engine_mode="async", n_rounds=3))
    assert h["engine"] == "async" and h["n_tasks"] > 0
    assert np.isfinite(h["acc_mean"]).all()


# ---------------------------------------------------------------------------
# executor parity: bucketed vmap(scan) == per-client loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", FAMILIES)
def test_bucketed_executor_matches_per_client(name):
    fam = get_family(name)
    x, y = _data(name, n=200)
    params = _params(name)
    parts = [np.arange(0, 40), np.arange(40, 100), np.arange(100, 140)]
    ids, ms = [0, 1, 2], [0, 1, fam.num_submodels() - 1]
    seeds = [fl_client.client_update_seed(0, 0, i) for i in ids]
    res = fl_batch.run_cohort("drfl", params, x, y, parts, ids, ms, seeds,
                              epochs=1, batch=32, lr=0.05, family=fam)
    for dev, m, delta, w, loss in res.unstacked():
        d_ref, l_ref = fam.client_update(
            "drfl", params, m, x[parts[dev]], y[parts[dev]], epochs=1,
            batch=32, lr=0.05, seed=seeds[dev])
        d_ref = fam.submodel_tree(d_ref, m)
        for a, b in zip(jax.tree.leaves(delta), jax.tree.leaves(d_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-4, rtol=0)
        assert loss == pytest.approx(l_ref, abs=1e-3)


# ---------------------------------------------------------------------------
# property tests: mask/template invariants
# ---------------------------------------------------------------------------


@hypothesis.given(name=st.sampled_from(FAMILIES),
                  m=st.integers(0, 3),
                  scale=st.floats(0.01, 2.0))
@hypothesis.settings(max_examples=15, deadline=None)
def test_mask_scale_property(name, m, scale):
    """Every mask leaf is exactly ``scale`` on held groups, 0 elsewhere,
    for arbitrary (m, scale); structure always matches the params."""
    fam = get_family(name)
    m = min(m, fam.num_submodels() - 1)
    params = _params(name)
    mask = fam.update_mask(params, m, scale=scale)
    assert jax.tree.structure(mask) == jax.tree.structure(params)
    held = fam.held_groups(params, m)
    for g, h in zip(fam.stack_groups(mask), held):
        for leaf in jax.tree.leaves(g):
            assert float(leaf) == (np.float32(scale) if h else 0.0)


@hypothesis.given(name=st.sampled_from(FAMILIES),
                  widx=st.integers(0, 2), seed=st.integers(0, 99))
@hypothesis.settings(max_examples=10, deadline=None)
def test_template_tracks_width_property(name, widx, seed):
    """stack_template group sizes always sum to the tree's leaf count,
    whatever the init width/key — and group count never changes."""
    fam = get_family(name)
    width = (0.06, 0.12, 0.25)[widx] if name == "cnn" else \
        (0.1, 0.25, 0.5)[widx]
    params = fam.init(jax.random.PRNGKey(seed), 10, width_mult=width, hw=_HW)
    template = fam.stack_template(params)
    assert len(template.group_sizes) == 1 + 2 * fam.num_submodels()
    assert sum(template.group_sizes) == sum(
        l.size for l in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# transformer-specific pins
# ---------------------------------------------------------------------------


def test_transformer_single_program_across_depths():
    """The depth-heterogeneous DR-FL step is ONE compiled program: the
    held depth is a traced argument, not a static one (the no-retrace
    ``layer_mask`` idiom — cnn/mlp pay one compile per depth instead)."""
    fam = get_family("transformer")
    fam._jit_cache.pop(("step", "drfl"), None)           # fresh program
    step = fam._step_fn("drfl")
    params = _params("transformer")
    x, y = _data("transformer", n=16)
    for m in range(fam.num_submodels()):
        step(params, jnp.asarray(x), jnp.asarray(y), m, 0.05)
    assert step._cache_size() == 1


def test_transformer_masked_loss_matches_truncated_loss():
    """The traced-depth masked joint CE == the truncated-tree ``_drfl_loss``
    (same weighting, same normalisation) at every depth."""
    fam = get_family("transformer")
    params = _params("transformer")
    x, y = _data("transformer", n=32)
    x, y = jnp.asarray(x), jnp.asarray(y)
    loss_fn = fam.loss_fn("drfl")
    for m in range(fam.num_submodels()):
        masked = fam._masked_drfl_loss(params, x, y, m)
        truncated = loss_fn(fam.submodel_tree(params, m), x, y)
        np.testing.assert_allclose(float(masked), float(truncated),
                                   atol=1e-6, rtol=1e-6)


def test_transformer_kernel_paths_agree():
    """Pallas ops (interpret mode off-TPU) and the pure-jnp ref math give
    the same forward — the family may route either way by backend."""
    from repro.models import transformer_family as tf
    params = _params("transformer")
    x, _ = _data("transformer", n=8)
    x = jnp.asarray(x)
    with tf.kernel_mode("ref"):
        ref = [np.asarray(o) for o in tf.apply_all_exits(params, x)]
    with tf.kernel_mode("pallas"):
        pal = [np.asarray(o) for o in tf.apply_all_exits(params, x)]
    for a, b in zip(ref, pal):
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_transformer_kernel_mode_validates():
    from repro.models import transformer_family as tf
    with pytest.raises(ValueError, match="kernel_mode"):
        with tf.kernel_mode("gpu"):
            pass


def test_transformer_token_dataset_contract():
    """Rows are [seq] int32 context windows, labels are next tokens in
    [0, vocab) — the classification framing the FL stack requires."""
    x, y = _data("transformer", n=100)
    assert x.shape == (100, _HW) and x.dtype == np.int32
    assert y.shape == (100,) and y.dtype == np.int32
    assert x.min() >= 0 and x.max() < 10
    assert y.min() >= 0 and y.max() < 10
    x2, y2 = _data("transformer", n=100)
    np.testing.assert_array_equal(x, x2)                 # deterministic
    np.testing.assert_array_equal(y, y2)


def test_transformer_learns_above_chance():
    """A few local epochs on the order-2 Markov corpus beat 10-way chance
    at every exit, and deeper exits do better at the end."""
    fam = get_family("transformer")
    x, y = fam.make_dataset(1200, 10, hw=_HW, noise=1.0, seed=0)
    params = fam.init(jax.random.PRNGKey(0), 10, width_mult=0.25, hw=_HW)
    g = params
    for ep in range(4):
        d, _ = fam.client_update("drfl", g, 3, x[200:], y[200:], epochs=1,
                                 batch=32, lr=0.05, seed=ep)
        g = jax.tree.map(lambda a, b: a + b, g, d)
    accs = np.asarray(fam.eval_fn()(g, jnp.asarray(x[:200]),
                                    jnp.asarray(y[:200])))
    assert (accs > 0.2).all(), accs


def _assert_frozen_transformer(mode):
    with open(FROZEN_TRANSFORMER) as fh:
        ref = json.load(fh)
    cfg = FLConfig(**{**ref["config"], "engine_mode": mode})
    h = run_simulation(cfg, verbose=False)
    r = ref[mode]
    np.testing.assert_array_equal(np.asarray(h["acc_mean"]), r["acc_mean"])
    np.testing.assert_array_equal(np.asarray(h["energy"]), r["energy"])
    np.testing.assert_array_equal(np.asarray(h["reward"]), r["reward"])
    np.testing.assert_array_equal(np.asarray(h["sim_time"]), r["sim_time"])
    assert [list(p) for p in h["participants"]] == r["participants"]
    assert [list(m) for m in h["model_choices"]] == r["model_choices"]
    assert list(h["alive"]) == r["alive"]
    assert h["dropouts"] == r["dropouts"]


def test_transformer_frozen_trajectory_sync():
    _assert_frozen_transformer("sync")


def test_transformer_frozen_trajectory_async():
    _assert_frozen_transformer("async")
