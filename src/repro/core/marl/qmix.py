"""QMIX learner (Rashid et al. 2018) — pure JAX, jitted end-to-end.

TD target (paper §3.2):
    y_t = r_t + gamma * Q_tot^target(s_{t+1}, argmax_a Q(s_{t+1}, a))
    L   = E[(y_t - Q_tot(s_t, a_t))^2]

Double-Q action selection uses the online net; the target net parameters are
periodically copied (``target_update_every``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.marl.networks import (agent_hidden_init, agent_init,
                                      agent_step, mixer_apply, mixer_init,
                                      set_mixer_apply, set_mixer_init)
from repro.optim import adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class QmixConfig:
    n_agents: int
    obs_dim: int
    num_actions: int          # M submodels + 1 no-participate
    state_dim: int
    hidden: int = 64
    mixer_embed: int = 32
    gamma: float = 0.95
    lr: float = 5e-4
    target_update_every: int = 20
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_rounds: int = 200
    batch_size: int = 16
    # "flat" = per-agent hypernet mixer (legacy, O(n_agents) params);
    # "set" = permutation-invariant set/attention mixer (n-free params,
    # trains on sampled-agent replay minibatches)
    mixer_mode: str = "flat"
    n_seeds: int = 4          # set-mixer seed queries


def epsilon(cfg: QmixConfig, round_idx: int) -> float:
    frac = min(1.0, round_idx / max(1, cfg.eps_decay_rounds))
    return cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac


class QmixLearner:
    """Owns online + target params and the jitted act/update functions."""

    def __init__(self, cfg: QmixConfig, key):
        self.cfg = cfg
        k1, k2 = jax.random.split(key)
        if cfg.mixer_mode == "set":
            mixer = set_mixer_init(k2, cfg.state_dim, cfg.obs_dim,
                                   cfg.mixer_embed, cfg.n_seeds)
        else:
            mixer = mixer_init(k2, cfg.n_agents, cfg.state_dim,
                               cfg.mixer_embed)
        self.params = {
            "agent": agent_init(k1, cfg.obs_dim, cfg.num_actions, cfg.hidden),
            "mixer": mixer,
        }
        self.target = jax.tree.map(jnp.copy, self.params)
        self.opt = adamw_init(self.params)
        self.updates = 0
        # jaxlint: allow(retrace-hazard) -- jitted once per learner instance; both live for the whole run
        self._act = jax.jit(functools.partial(_act, cfg))
        # jaxlint: allow(retrace-hazard) -- jitted once per learner instance; both live for the whole run
        self._update = jax.jit(functools.partial(_update, cfg))

    def act(self, obs, hidden, key, eps: float, avail=None
            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """obs [N, obs_dim] -> (actions [N], q_chosen [N], new_hidden)."""
        if avail is None:
            avail = jnp.ones((self.cfg.n_agents, self.cfg.num_actions), bool)
        return self._act(self.params, obs, hidden, key, eps, avail)

    def init_hidden(self):
        return agent_hidden_init(self.cfg.n_agents, self.cfg.hidden)

    def update(self, batch: Dict) -> Dict[str, float]:
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        self.params, self.opt, metrics = self._update(
            self.params, self.target, self.opt, batch)
        self.updates += 1
        if self.updates % self.cfg.target_update_every == 0:
            self.target = jax.tree.map(jnp.copy, self.params)
        # jaxlint: allow(host-sync-in-hot-path) -- one batched metrics pull per QMIX update
        metrics = jax.device_get(metrics)
        return {k: float(v) for k, v in metrics.items()}

    def state_dict(self) -> Dict:
        """Checkpointable snapshot: everything but the jitted closures
        (those are rebuilt from ``cfg`` on construction)."""
        return {"params": self.params, "target": self.target,
                "opt": self.opt, "updates": self.updates}

    def load_state_dict(self, state: Dict) -> None:
        self.params = state["params"]
        self.target = state["target"]
        self.opt = state["opt"]
        self.updates = int(state["updates"])


def _act(cfg: QmixConfig, params, obs, hidden, key, eps, avail):
    """avail: [N, A] bool — affordability action mask (unaffordable model
    choices are never taken; exploration samples among available actions)."""
    q, h = agent_step(params["agent"], obs, hidden)              # [N,A]
    q_masked = jnp.where(avail, q, -1e9)
    greedy = jnp.argmax(q_masked, axis=-1)
    k1, k2 = jax.random.split(key)
    logits = jnp.where(avail, 0.0, -1e9)
    rand_a = jax.random.categorical(k1, logits, axis=-1)
    explore = jax.random.uniform(k2, greedy.shape) < eps
    act = jnp.where(explore, rand_a, greedy)
    q_chosen = jnp.take_along_axis(q, act[:, None], axis=-1)[:, 0]
    return act, q_chosen, h


def _unroll(cfg: QmixConfig, params, obs_seq):
    """obs_seq: [B, T+1, N, obs] -> qs [B, T+1, N, A] via GRU unroll.

    N is the batch's agent axis — ``cfg.n_agents`` for full-fleet replay,
    the sampled-agent budget for set-mixer replay minibatches (shared
    agent weights make the unroll agnostic to which agents are present).
    """
    B = obs_seq.shape[0]
    h0 = jnp.zeros((B, obs_seq.shape[2], cfg.hidden), jnp.float32)

    def step(h, obs_t):                                  # obs_t: [B,N,obs]
        q, h = jax.vmap(lambda o, hh: agent_step(params["agent"], o, hh))(obs_t, h)
        return h, q

    _, qs = jax.lax.scan(step, h0, jnp.moveaxis(obs_seq, 1, 0))
    return jnp.moveaxis(qs, 0, 1)                        # [B,T+1,N,A]


def _mix(cfg: QmixConfig, mix_params, q_agents, obs_steps, state_steps,
         logw):
    """Route per-agent Qs through the configured mixer (static branch)."""
    if cfg.mixer_mode == "set":
        return set_mixer_apply(mix_params, q_agents, obs_steps, state_steps,
                               n_seeds=cfg.n_seeds, embed=cfg.mixer_embed,
                               logw=logw)
    return mixer_apply(mix_params, q_agents, state_steps, cfg.n_agents,
                       cfg.mixer_embed)


def _update(cfg: QmixConfig, params, target, opt, batch):
    obs, state = batch["obs"], batch["state"]            # [B,T+1,...]
    actions, rewards, mask = batch["actions"], batch["rewards"], batch["mask"]
    # sampled-agent replay importance log-weights [B, N] (zeros under
    # uniform sampling; absent from flat-mode batches)
    logw = batch.get("agent_logw")
    if logw is not None:
        logw = logw[:, None, :]                          # broadcast over T

    def loss_fn(p):
        qs = _unroll(cfg, p, obs)                         # [B,T+1,N,A]
        q_taken = jnp.take_along_axis(
            qs[:, :-1], actions[..., None], axis=-1)[..., 0]   # [B,T,N]
        q_tot = _mix(cfg, p["mixer"], q_taken, obs[:, :-1],
                     state[:, :-1], logw)                 # [B,T]

        tq = _unroll(cfg, target, obs)                    # [B,T+1,N,A]
        next_best = jnp.argmax(qs[:, 1:], axis=-1)        # double-Q: online argmax
        tq_next = jnp.take_along_axis(
            tq[:, 1:], next_best[..., None], axis=-1)[..., 0]  # [B,T,N]
        tq_tot = _mix(cfg, target["mixer"], tq_next, obs[:, 1:],
                      state[:, 1:], logw)
        y = rewards + cfg.gamma * jax.lax.stop_gradient(tq_tot) * mask
        td = (y - q_tot) * mask
        return jnp.sum(td ** 2) / jnp.maximum(mask.sum(), 1.0)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_params, new_opt, m = adamw_update(grads, opt, params, lr=cfg.lr,
                                          weight_decay=0.0, grad_clip=10.0)
    return new_params, new_opt, {"td_loss": loss, **m}
