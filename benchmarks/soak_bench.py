"""Crash-safe soak bench: churn kill/resume parity + checkpoint latency.

Two measurements for the crash-safe fleet service:

* **churn soak** — a short async DR-FL run under seeded fault injection
  (crash / timeout / disconnect / corrupt) with periodic full-engine
  checkpoints; the run is killed right after a save
  (``halt_after_saves``), resumed from disk, and the resumed history +
  global params are asserted **bit-identical** to an uninterrupted
  reference run.  The recorded row is the parity verdict plus the fault
  ledger (events, reaps, quarantines).
* **checkpoint latency** — ``EngineCheckpointer.save``/``load`` on a
  synthetic full-engine state (all :data:`FLEET_CHECKPOINT_FIELDS`
  arrays from :func:`sample_fleet_state`, float64 host mirrors, global
  CNN params) at n in {4096, 65536} devices: median wall seconds and
  on-disk bytes per snapshot.

Results land in ``BENCH_checkpoint.json`` (smoke runs never clobber the
recorded full-scale rows):

    PYTHONPATH=src python -m benchmarks.soak_bench            # full
    PYTHONPATH=src python -m benchmarks.soak_bench --smoke    # CI
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import statistics
import sys
import tempfile
import time

SIZES_FULL = (4096, 65536)
SIZES_SMOKE = (4096,)
OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_checkpoint.json")


def _churn_config(smoke: bool):
    from repro.fl import FLConfig
    # full participation + healthy batteries so injected faults land on
    # live, in-flight devices (a dead fleet exercises nothing)
    return FLConfig(n_devices=8, n_rounds=4 if smoke else 8,
                    participation=1.0, local_epochs=1, batch_size=8,
                    n_train=256, hw=8, seed=3, selector="greedy",
                    energy_scale=50.0, engine_mode="async",
                    async_time_horizon=200.0 if smoke else 400.0,
                    fault_crashes=1, fault_timeouts=2,
                    fault_disconnects=1, fault_corrupts=3)


def _hist_fingerprint(hist) -> dict:
    """Canonical bytes of everything parity-relevant in a run history."""
    import hashlib

    import jax
    import numpy as np

    def canon(x):
        if isinstance(x, (np.ndarray, jax.Array)):
            a = np.asarray(x)
            return ["arr", str(a.dtype), a.tobytes().hex()]
        if isinstance(x, dict):
            return {str(k): canon(v) for k, v in sorted(x.items())}
        if isinstance(x, (list, tuple)):
            return [canon(v) for v in x]
        return repr(x)

    digests = {}
    for k in sorted(hist):
        if k == "wall_clock":
            continue
        blob = json.dumps(canon(hist[k])).encode()
        digests[k] = hashlib.sha256(blob).hexdigest()
    return digests


def run_churn(smoke: bool) -> dict:
    from repro.checkpoint import CheckpointHalt
    from repro.fl import run_simulation
    cfg = _churn_config(smoke)
    t0 = time.time()
    ref = run_simulation(cfg)
    t_ref = time.time() - t0
    with tempfile.TemporaryDirectory() as d:
        ck = dataclasses.replace(cfg, checkpoint_dir=d, checkpoint_every=2)
        try:
            run_simulation(ck, halt_after_saves=1)
            raise AssertionError("halt_after_saves=1 did not kill the run")
        except CheckpointHalt:
            pass
        t0 = time.time()
        res = run_simulation(dataclasses.replace(ck, resume=True))
        t_res = time.time() - t0
    fa, fb = _hist_fingerprint(ref), _hist_fingerprint(res)
    mismatched = sorted(k for k in fa if fa.get(k) != fb.get(k))
    if mismatched or set(fa) != set(fb):
        raise AssertionError(
            f"kill-and-resume diverged from the uninterrupted run on "
            f"hist keys {mismatched or sorted(set(fa) ^ set(fb))}")
    faults = ref["faults"]
    return {
        "parity": "bit-identical",
        "n_fault_events": len(faults["events"]),
        "n_reaped": faults["n_reaped"],
        "n_quarantined": faults["n_quarantined"],
        "terminated": ref["terminated"]["reason"],
        "vrounds": len(ref["acc_mean"]),
        "ref_wall_s": round(t_ref, 3),
        "resumed_wall_s": round(t_res, 3),
    }


def _synthetic_engine_state(n: int):
    import jax
    import numpy as np

    from repro.checkpoint.io import FLEET_CHECKPOINT_FIELDS
    from repro.core.fleet import sample_fleet_state
    from repro.models import cnn

    fleet = sample_fleet_state(n, seed=0)
    return {
        "mode": "async",
        "fleet": {f: getattr(fleet, f) for f in FLEET_CHECKPOINT_FIELDS},
        "global_params": cnn.init(jax.random.PRNGKey(0), num_classes=10,
                                  width_mult=0.25),
        "busy64": np.zeros(n, np.float64),
        "alive_host": np.ones(n, bool),
        "state": {"version": 7, "seq": 123, "sim_time": 512.25},
    }


def bench_checkpoint(n: int, iters: int) -> dict:
    state = _synthetic_engine_state(n)
    saves, loads = [], []
    with tempfile.TemporaryDirectory() as d:
        from repro.checkpoint import EngineCheckpointer
        ck = EngineCheckpointer(d, keep=2)
        path = None
        for i in range(iters):
            t0 = time.time()
            path = ck.save(state, {"episode": 0, "step": i})
            saves.append(time.time() - t0)
        arrays = path.replace(".manifest.json", ".ckpt")
        nbytes = os.path.getsize(path) + os.path.getsize(arrays)
        for _ in range(iters):
            t0 = time.time()
            restored, _meta = ck.load(path)
            loads.append(time.time() - t0)
        assert restored["fleet"]["battery"].shape[0] == n
    return {
        "n": n,
        "iters": iters,
        "save_s_median": round(statistics.median(saves), 4),
        "load_s_median": round(statistics.median(loads), 4),
        "snapshot_bytes": nbytes,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: n=4096 only, short churn run")
    ap.add_argument("--sizes", type=int, nargs="*", default=None)
    ap.add_argument("--iters", type=int, default=0)
    ap.add_argument("--no-write", action="store_true")
    args = ap.parse_args(argv)

    import jax

    from benchmarks.common import emit

    sizes = tuple(args.sizes) if args.sizes else (
        SIZES_SMOKE if args.smoke else SIZES_FULL)
    out = {"bench": "checkpoint", "backend": jax.default_backend(),
           "rows": []}
    for n in sorted(sizes):
        iters = args.iters or (3 if args.smoke else 5)
        row = bench_checkpoint(n, iters)
        out["rows"].append(row)
        emit(f"checkpoint/save/n{n}", row["save_s_median"] * 1e6,
             f"bytes={row['snapshot_bytes']} "
             f"load_s={row['load_s_median']}")
    out["churn"] = run_churn(args.smoke)
    emit("checkpoint/churn", out["churn"]["resumed_wall_s"] * 1e6,
         f"parity={out['churn']['parity']} "
         f"faults={out['churn']['n_fault_events']} "
         f"quarantined={out['churn']['n_quarantined']}")

    if not args.no_write:
        path = os.path.abspath(OUT_JSON)
        existing = {}
        if os.path.exists(path):
            with open(path) as fh:
                existing = json.load(fh)
        if args.smoke and existing.get("rows"):
            # CI smoke must not clobber the recorded full-scale rows
            existing["smoke"] = {k: out[k] for k in ("rows", "churn")}
            out = existing
        else:
            fresh = {r["n"] for r in out["rows"]}
            out["rows"] += [r for r in existing.get("rows", [])
                            if r["n"] not in fresh]
            out["rows"].sort(key=lambda r: r["n"])
            if "smoke" in existing:
                out["smoke"] = existing["smoke"]
        with open(path, "w") as fh:
            json.dump(out, fh, indent=1)
        print(f"wrote {path}")
    return out


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
