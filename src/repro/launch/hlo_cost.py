"""Loop-aware static cost model over compiled (post-SPMD) HLO text.

Why: ``compiled.cost_analysis()`` counts a ``while`` body ONCE, regardless of
trip count (verified empirically — a scanned matmul reports identical FLOPs
for length 2 and 32).  Our transformer stacks are `lax.scan`s over 24–94
layers, so XLA's own numbers under-report loop-resident FLOPs / bytes /
collective traffic by 1–2 orders of magnitude.  This module parses the HLO
module into per-computation symbol tables and walks the call graph with
loop trip counts, producing corrected per-device totals:

* ``flops`` — 2·prod(out)·prod(contracted lhs dims) for every ``dot``
  (operand shapes resolved through the symbol table,
  ``lhs_contracting_dims`` from the attribute text); convolutions via
  output × kernel-per-output-channel; 1 flop/elem for transcendentals.
* ``hbm_bytes`` — 2 × Σ output bytes of every top-level materialising op
  (ENTRY / while bodies / conditional branches; fusion internals are
  VMEM-resident and excluded; factor 2 ≈ one write + one downstream read).
* ``collective_bytes`` — ring-adjusted bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute.

Trip counts: ``backend_config={"known_trip_count":{"n":...}}`` when
present, else the max integer constant in the loop-condition computation.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-_]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_INT = re.compile(r"constant\((\d+)\)")
_ATTR_RE = re.compile(r"(body|condition|calls|to_apply)=%?([\w\.\-_]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-_]+)")

_TRIVIAL = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "after-all", "iota", "partition-id", "replica-id"}
_TRANSCENDENTAL = {"exponential", "tanh", "logistic", "rsqrt", "divide",
                   "log", "power", "sine", "cosine", "sqrt",
                   "exponential-minus-one", "log-plus-one"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(s: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(s: str) -> int:
    m = _SHAPE_RE.search(s)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(s: str) -> List[int]:
    m = _SHAPE_RE.search(s)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Instr:
    __slots__ = ("name", "shape", "op", "operands", "line")

    def __init__(self, name, shape, op, operands, line):
        self.name, self.shape, self.op = name, shape, op
        self.operands, self.line = operands, line


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.symbols: Dict[str, Dict[str, str]] = {}
        self.entry: Optional[str] = None
        self._parse(text)

    def _parse(self, text: str):
        cur = None
        comment = re.compile(r"/\*.*?\*/")
        for raw in text.splitlines():
            line = comment.sub("", raw).strip()
            if not line:
                continue
            if line.endswith("{") and "=" not in line.split("(")[0]:
                # computation header: [ENTRY] %name (args) -> result {
                is_entry = line.startswith("ENTRY")
                m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(", line)
                if m:
                    cur = m.group(1)
                    self.computations[cur] = []
                    self.symbols[cur] = {}
                    if is_entry:
                        self.entry = cur
                continue
            if line == "}":
                cur = None
                continue
            if cur is None:
                continue
            im = _INSTR_RE.match(line)
            if not im:
                continue
            name, shape, op = im.group(1), im.group(2), im.group(3)
            # operands: inside the first balanced parens after the opcode
            start = line.find(op + "(") + len(op) + 1
            depth, end = 1, start
            while end < len(line) and depth:
                if line[end] == "(":
                    depth += 1
                elif line[end] == ")":
                    depth -= 1
                end += 1
            operand_text = line[start:end - 1]
            operands = _OPERAND_RE.findall(operand_text)
            inst = Instr(name, shape, op, operands, line)
            self.computations[cur].append(inst)
            self.symbols[cur][name] = shape
        if self.entry is None and self.computations:
            self.entry = list(self.computations)[-1]


class HloCost:
    def __init__(self, text: str):
        self.mod = HloModule(text)
        self._memo: Dict[Tuple[str, bool], tuple] = {}

    def _trip(self, inst: Instr) -> int:
        m = _TRIP_RE.search(inst.line)
        if m:
            return int(m.group(1))
        cond = dict(_ATTR_RE.findall(inst.line)).get("condition")
        best = 1
        for ci in self.mod.computations.get(cond, ()):
            for mm in _CONST_INT.finditer(ci.line):
                best = max(best, int(mm.group(1)))
        return best

    def _dot_flops(self, comp: str, inst: Instr) -> float:
        out = 1
        for d in _shape_dims(inst.shape):
            out *= d
        lhs_shape = self.mod.symbols[comp].get(inst.operands[0]) if inst.operands else None
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.line)
        if lhs_shape is None or m is None:
            return 0.0
        lhs_dims = _shape_dims(lhs_shape)
        k = 1
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
        return 2.0 * out * k

    def _conv_flops(self, comp: str, inst: Instr) -> float:
        if len(inst.operands) < 2:
            return 0.0
        kern_shape = self.mod.symbols[comp].get(inst.operands[1])
        if kern_shape is None:
            return 0.0
        kd = _shape_dims(kern_shape)
        out = 1
        for d in _shape_dims(inst.shape):
            out *= d
        if not kd:
            return 0.0
        kern_per_cout = 1
        for d in kd[:-1]:
            kern_per_cout *= d
        return 2.0 * out * kern_per_cout

    def _effective_out_bytes(self, comp: str, inst: Instr) -> float:
        """Output bytes with in-place aliasing awareness.

        dynamic-update-slice (and fusions whose root is one, or a tuple of
        them — the standard XLA lowering of scan-carried buffers and grad
        accumulators) alias their big operand: real traffic is the update
        slice, not the whole buffer."""
        op = inst.op
        if op == "dynamic-update-slice" and len(inst.operands) >= 2:
            return _shape_bytes(self.mod.symbols[comp].get(inst.operands[1], ""))
        if op == "scatter" and len(inst.operands) >= 3:
            return _shape_bytes(self.mod.symbols[comp].get(inst.operands[2], ""))
        if op == "fusion":
            called = None
            for kind, target in _ATTR_RE.findall(inst.line):
                if kind == "calls":
                    called = target
                    break
            if called and called in self.mod.computations:
                insts = self.mod.computations[called]
                by_name = {i.name: i for i in insts}
                root = insts[-1] if insts else None
                if root is not None:
                    if root.op == "dynamic-update-slice":
                        return self._effective_out_bytes(called, root)
                    if root.op == "tuple":
                        tot = 0.0
                        for on in root.operands:
                            oi = by_name.get(on)
                            if oi is not None and oi.op == "dynamic-update-slice":
                                tot += self._effective_out_bytes(called, oi)
                            elif oi is not None:
                                tot += _shape_bytes(oi.shape)
                            else:
                                tot += 0.0
                        return tot
        return _shape_bytes(inst.shape)

    def comp_cost(self, name: str, top_level: bool):
        key = (name, top_level)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = (0.0, 0.0, 0.0, {})   # cycle guard
        flops = bytes_ = coll = 0.0
        coll_k: Dict[str, float] = {}

        def add_child(f, b, c, ck, mult=1.0):
            nonlocal flops, bytes_, coll
            flops += mult * f
            bytes_ += mult * b
            coll += mult * c
            for k, v in ck.items():
                coll_k[k] = coll_k.get(k, 0.0) + mult * v

        for inst in self.mod.computations.get(name, ()):
            op = inst.op
            if op == "dot":
                flops += self._dot_flops(name, inst)
            elif op == "convolution":
                flops += self._conv_flops(name, inst)
            elif op in _TRANSCENDENTAL:
                flops += _shape_elems(inst.shape)

            if op == "while":
                attrs = dict(_ATTR_RE.findall(inst.line))
                body = attrs.get("body")
                if body:
                    add_child(*self.comp_cost(body, True), mult=self._trip(inst))
                continue
            if op == "conditional":
                bm = _BRANCHES_RE.search(inst.line)
                if bm:
                    branches = [b.strip().lstrip("%") for b in bm.group(1).split(",")]
                    costs = [self.comp_cost(b, True) for b in branches if b]
                    if costs:
                        add_child(*max(costs, key=lambda t: t[0] + t[1]))
                continue

            for kind, target in _ATTR_RE.findall(inst.line):
                if kind in ("calls", "to_apply"):
                    f, b, c, ck = self.comp_cost(target, False)
                    add_child(f, 0.0, c, ck)   # fusion internals: no HBM bytes

            is_coll = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            if is_coll and not inst.line.split("=")[1].lstrip().startswith("token"):
                if op.endswith("-done"):
                    pass   # -start carries the shape
                else:
                    b = _shape_bytes(inst.shape)
                    if is_coll == "all-reduce":
                        b *= 2
                    coll += b
                    coll_k[is_coll] = coll_k.get(is_coll, 0.0) + b

            if top_level and op not in _TRIVIAL:
                bytes_ += 2.0 * self._effective_out_bytes(name, inst)

        out = (flops, bytes_, coll, coll_k)
        self._memo[key] = out
        return out

    def totals(self) -> dict:
        f, b, c, ck = self.comp_cost(self.mod.entry, True)
        return {"flops": f, "hbm_bytes": b, "collective_bytes": c,
                "collectives": ck}


def analyze(compiled_text: str) -> dict:
    return HloCost(compiled_text).totals()
