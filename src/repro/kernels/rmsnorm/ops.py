"""Jit'd wrapper for the fused RMSNorm kernel (model layout [..., d])."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.rmsnorm import rmsnorm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_op(x, scale, *, eps=1e-5, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    shape = x.shape
    R = 1
    for s in shape[:-1]:
        R *= s
    x2d = x.reshape(R, shape[-1])
    # pick the largest row block that divides R
    br = 256
    while R % br:
        br //= 2
    out = rmsnorm(x2d, scale, eps=eps, block_rows=max(br, 1),
                  interpret=interpret)
    return out.reshape(shape)
