"""DR-FL core: the paper's contribution.

* layerwise    — depth-prefix submodels + masks (§4.2)
* aggregation  — FedAvg + layer-aligned masked aggregation (Step 2)
* energy       — Eq. 3–7 time/energy system model + device fleet (scalar
                 reference semantics)
* fleet        — vectorized struct-of-arrays FleetState engine (batched
                 Eq. 3–7 kernels; numpy parity + jax/jit backends)
* selection    — dual-selection strategies (MARL / greedy / random / static)
* marl         — QMIX learner (agents, mixer, replay, TD updates)
* baselines    — HeteroFL / ScaleFL comparison arms
"""
from repro.core.aggregation import fedavg, fl_allreduce, layerwise_aggregate  # noqa: F401
from repro.core.energy import (BATTERY_JOULES, DeviceProfile, DeviceState,  # noqa: F401
                               make_fleet, round_cost, charge, total_remaining)
from repro.core.fleet import (FleetState, as_fleet_state,  # noqa: F401
                              fleet_affordability, fleet_charge,
                              fleet_connect, fleet_cost_matrix,
                              fleet_disconnect, fleet_round_cost,
                              fleet_total_remaining, make_fleet_state,
                              set_modes)
from repro.core.layerwise import (exit_points, layer_mask, num_submodels,  # noqa: F401
                                  stacked_update_mask, submodel_fraction)
from repro.core.selection import (GreedySelector, MarlSelector,  # noqa: F401
                                  RandomSelector, Selection, StaticTierSelector)
