from repro.kernels.rmsnorm.ops import rmsnorm_op  # noqa: F401
from repro.kernels.rmsnorm.ref import rmsnorm_ref  # noqa: F401
