"""Pallas TPU flash attention (causal / sliding-window, GQA-aware).

TPU adaptation notes (DESIGN.md §3): the CUDA flash-attention algorithm is
re-blocked for the TPU memory hierarchy — each grid step holds one
``[block_q, head_dim]`` query tile plus the full per-(batch,head) K/V rows
in VMEM (K/V tiles stream through the MXU via an inner ``fori_loop`` over
``block_k`` slices; online-softmax running max/sum live in f32 VREGs).
Block shapes are MXU-aligned (128 multiples).  GQA is handled by the K/V
``index_map`` (query head h reads KV head ``h // group``), so repeated KV
heads are never materialised.

Validated in ``interpret=True`` mode on CPU against ``ref.py``; intended to
be compiled for TPU where ``jax.devices()[0].platform == 'tpu'``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_k, causal, window,
            seq_k):
    bq, D = q_ref.shape[1], q_ref.shape[2]
    q = q_ref[0].astype(jnp.float32) * scale                 # [bq, D]
    iq = pl.program_id(1)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    nk = seq_k // block_k
    if causal:
        # only KV blocks at or before this query block contribute
        nk_live = jnp.minimum(nk, ((iq + 1) * bq + block_k - 1) // block_k)
    else:
        nk_live = nk

    def body(j, carry):
        acc, m, l = carry
        k = k_ref[0, pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T                                          # [bq, bk] f32 (MXU)
        k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + p @ v
        return acc, m_new, l

    acc0 = jnp.zeros((bq, D), jnp.float32)
    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk_live, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window=0,
                         block_q=128, block_k=128, interpret=False):
    """q: [BHq, Sq, D]; k/v: [BHkv, Sk, D] with BHq = BHkv * group."""
    BH, Sq, D = q.shape
    BHkv, Sk, _ = k.shape
    group = BH // BHkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    scale = 1.0 / math.sqrt(D)
    grid = (BH, Sq // block_q)

    kernel = functools.partial(_kernel, scale=scale, block_k=block_k,
                               causal=causal, window=window, seq_k=Sk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, iq: (bh, iq, 0)),
            pl.BlockSpec((1, Sk, D), lambda bh, iq: (bh // group, 0, 0)),
            pl.BlockSpec((1, Sk, D), lambda bh, iq: (bh // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, iq: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
