"""Energy-scenario grid: selectors x charge/availability/budget scenarios.

Runs the full FL loop (``repro.fl.run_simulation``) for every selector in
{marl, greedy, random, static} under the four energy scenarios the
:mod:`repro.energy` subsystem ships —

* ``constant``       — the static-battery baseline (no recharge),
* ``solar``          — phase-shifted sinusoid harvesting,
* ``diurnal``        — day/night availability waves (duty 0.5),
* ``global_budget``  — a fleet-wide joule ceiling over a harvest-backed
  (solar) fleet: the budget meters what the fleet may *attempt*, sized so
  a wasteful selector burns through it,

at n in {256, 4096} devices (Top-K held at ~8 tasks per round via the
participation fraction and per-device shards held constant via
``n_train = 3n``, so the training work per round is size-invariant and
the grid finishes on CPU; MARL auto-switches to the factored QMIX state
above the flat-state cutoff).

``ENERGY_SCALE`` makes batteries BIND: a fresh battery (~19 J) affords
the small submodels everywhere, the mid tier (~12-26 J) only on part of
the fleet, and the largest (~46-104 J) nowhere — so selection quality
decides who survives.  Affordability-blind selection (random) routinely
assigns a submodel its device cannot pay for — ``fleet_charge`` semantics
say the device attempts anyway, wastes its whole remaining battery, and
dies (the paper's useless-training arm) — while the affordability-masked
selectors never take a lethal pick.  Under harvesting the gap compounds:
dead devices stop harvesting, so every kill also forfeits its future
charge; under the budget, lethal and oversized attempts burn shared
joules (~4-6x the masked selectors' spend rate) for zero accuracy
contribution.

Per cell: final mean exit accuracy, surviving devices, net joules drained,
and **joules per accuracy point** (net drain / 100*acc) — the paper's
energy-awareness figure of merit.  The JSON also records the directional
claims the tests/README cite: MARL beats random on joules-per-accuracy-
point under solar harvesting and under the global budget.  MARL cells
pre-train the QMIX policy for ``marl_episodes=3`` (the fig5 precedent);
the deciding mechanism above is the affordability mask, so the claims are
robust to the accuracy noise floor of CPU-scale synthetic runs.

    PYTHONPATH=src python -m benchmarks.energy_bench            # full grid
    PYTHONPATH=src python -m benchmarks.energy_bench --smoke    # n=256, CI

Results land in ``BENCH_energy.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.fl import FLConfig, run_simulation

OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_energy.json")

SELECTORS = ("marl", "greedy", "random", "static")
K_TARGET = 8                    # tasks per round, size-invariant
ENERGY_SCALE = 0.0025           # fresh battery ~19 J: small submodels fit
#                                 everywhere, mid tier (~12-26 J) only on
#                                 the strongest devices, largest never
DAY = 3600.0                    # scenario day length, sim-seconds
BUDGET_PER_PICK = 18.0          # J of shared budget per scheduled pick —
#                                 ~4x the disciplined (affordability-masked)
#                                 per-pick cost but below the ~24 J/pick an
#                                 affordability-blind selector attempts, so
#                                 the cap binds on waste, not on discipline


def scenario_fields(name: str, n: int, n_rounds: int) -> dict:
    """Flat-config field group for one named scenario."""
    if name == "constant":
        return {}
    if name == "solar":
        return dict(charge_profile="solar", charge_rate=2.0,
                    charge_period=DAY)
    if name == "diurnal":
        return dict(availability_profile="diurnal", availability_duty=0.5,
                    charge_period=DAY)
    if name == "global_budget":
        # a shared joule ceiling over a harvest-backed fleet, sized in
        # ABSOLUTE terms from the scheduled pick work (k picks/round —
        # which is n-invariant here — NOT from the fleet's total charge,
        # which would stop binding as n grows): enough to fund every round
        # at mid-submodel cost, not enough to waste on lethal attempts
        return dict(charge_profile="solar", charge_rate=2.0,
                    charge_period=DAY,
                    global_budget_j=BUDGET_PER_PICK * K_TARGET * n_rounds)
    raise ValueError(name)


SCENARIOS = ("constant", "solar", "diurnal", "global_budget")


def run_cell(scenario: str, selector: str, n: int, n_rounds: int,
             seed: int = 0, verbose: bool = False) -> dict:
    cfg = FLConfig(n_devices=n, n_rounds=n_rounds,
                   participation=K_TARGET / n, n_train=3 * n,
                   local_epochs=1, method="drfl", selector=selector,
                   energy_scale=ENERGY_SCALE, seed=seed,
                   marl_episodes=3 if selector == "marl" else 1,
                   **scenario_fields(scenario, n, n_rounds))
    t0 = time.time()
    h = run_simulation(cfg, verbose=verbose)
    e_start = n * 7560.0 * ENERGY_SCALE
    joules = max(e_start - float(h["energy"][-1]), 0.0)
    acc = float(h["acc_mean"][-1])
    row = {
        "scenario": scenario, "selector": selector, "n": n,
        "rounds_run": len(h["acc_mean"]), "final_acc": acc,
        "surviving": int(h["alive"][-1]), "dropouts": int(h["dropouts"]),
        "joules": joules,
        "joules_per_acc_point": joules / max(100.0 * acc, 1e-9),
        "terminated": h["terminated"]["reason"],
        "wall_s": round(time.time() - t0, 1),
    }
    if "budget" in h:
        row["budget_limit"] = h["budget"]["limit"]
        row["budget_spent"] = h["budget"]["spent"]
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: n=256 only, no JSON rewrite")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_JSON)
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    sizes = (256,) if args.smoke else (256, 4096)
    # same round count in both modes: the selector gaps (kills forfeiting
    # future harvest, budget burn) need a few rounds to compound
    n_rounds = args.rounds or 8

    rows = []
    for n in sizes:
        for scenario in SCENARIOS:
            for selector in SELECTORS:
                row = run_cell(scenario, selector, n, n_rounds,
                               seed=args.seed, verbose=args.verbose)
                rows.append(row)
                print(f"{scenario:14s} {selector:7s} n={n:5d} "
                      f"acc={row['final_acc']:.3f} "
                      f"alive={row['surviving']:5d} "
                      f"J={row['joules']:8.1f} "
                      f"J/acc-pt={row['joules_per_acc_point']:7.2f} "
                      f"[{row['terminated']}] {row['wall_s']}s",
                      flush=True)

    def jpap(scenario, selector, n):
        for r in rows:
            if (r["scenario"], r["selector"], r["n"]) == (scenario,
                                                          selector, n):
                return r["joules_per_acc_point"]
        return None

    claims = {}
    for scenario in ("solar", "global_budget"):
        for n in sizes:
            m, r = jpap(scenario, "marl", n), jpap(scenario, "random", n)
            claims[f"marl_beats_random_jpap/{scenario}/n{n}"] = (
                m is not None and r is not None and m < r)
    for k, v in claims.items():
        print(f"claim {k}: {v}")

    if not args.smoke:
        out = {
            "bench": "energy_scenarios",
            "k_target": K_TARGET, "energy_scale": ENERGY_SCALE,
            "n_rounds": n_rounds, "seed": args.seed,
            "rows": rows, "claims": claims,
        }
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {os.path.abspath(args.out)}")
    return rows, claims


if __name__ == "__main__":
    main()
