"""Energy & running-time system model (paper §4.1, Eq. 3–7) + device fleet.

    T_com^n = S_n / V_net          (model bytes / bandwidth)
    T_tra^n = L_n / C_n            (local samples / samples-per-second)
    E_tra^n = P_train * T_tra^n
    E_com^n = P_com  * T_com^n
    T_all   = max_n (T_com^n + T_tra^n)           (Eq. 3–4)
    E_all   = sum_n (E_remain^n - E_tra^n - E_com^n)   (Eq. 6)

Device tiers are calibrated to the paper's test-bed (Jetson Nano vs AGX
Xavier; 7,560 J battery = 1,500 mAh @ 5.04 V).  ``C`` additionally scales
with the *submodel fraction* — training a 1/4-depth Model_1 costs ~1/4 the
per-sample compute of the full backbone (the paper's "variations in the
size of the model lead to fluctuations in the energy consumed").

The MARL selector may also tune the device power mode (the paper's
"adjust the computing capability of AIoT devices"): mode ``turbo`` trades
higher P_train for higher C.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

BATTERY_JOULES = 7_560.0  # 1500 mAh @ 5.04 V (paper §5)

# tier -> (samples/s at full model, P_train W, P_com W)
DEVICE_TIERS = {
    "small": (120.0, 4.0, 1.5),     # Jetson-Nano-class
    "medium": (300.0, 8.0, 2.0),
    "large": (700.0, 18.0, 2.5),    # AGX-Xavier-class
}

POWER_MODES = {          # mode -> (compute multiplier, power multiplier)
    "eco": (0.7, 0.55),
    "normal": (1.0, 1.0),
    "turbo": (1.3, 1.6),
}


@dataclasses.dataclass
class DeviceProfile:
    tier: str
    compute: float            # samples/s at full model, normal mode
    p_train: float            # W
    p_com: float               # W
    bandwidth: float = 2.5e6   # bytes/s uplink
    battery: float = BATTERY_JOULES

    @classmethod
    def from_tier(cls, tier: str, rng: Optional[np.random.Generator] = None,
                  jitter: float = 0.15):
        c, pt, pc = DEVICE_TIERS[tier]
        if rng is not None:
            f = lambda v: float(v * rng.uniform(1 - jitter, 1 + jitter))
        else:
            f = float
        return cls(tier=tier, compute=f(c), p_train=f(pt), p_com=f(pc))


@dataclasses.dataclass
class DeviceState:
    profile: DeviceProfile
    remaining: float            # J
    data_size: int              # L_n local samples
    mode: str = "normal"
    alive: bool = True

    def effective_compute(self, model_fraction: float) -> float:
        cm, _ = POWER_MODES[self.mode]
        return self.profile.compute * cm / max(model_fraction, 1e-6)

    def train_power(self) -> float:
        _, pm = POWER_MODES[self.mode]
        return self.profile.p_train * pm


def round_cost(dev: DeviceState, model_bytes: float, model_fraction: float,
               local_epochs: int = 5, batch_size: int = 32):
    """(T_tra, T_com, E_tra, E_com) for one FL round (Eq. 5 & 7)."""
    samples = dev.data_size * local_epochs
    t_tra = samples / dev.effective_compute(model_fraction)
    t_com = 2.0 * model_bytes / dev.profile.bandwidth   # down + up
    e_tra = dev.train_power() * t_tra
    e_com = dev.profile.p_com * t_com
    return t_tra, t_com, e_tra, e_com


def charge(dev: DeviceState, e_tra: float, e_com: float) -> bool:
    """Deduct energy; returns False (and marks dead) on battery exhaustion.

    Matches the paper's failure mode: a device that can train but not
    communicate wastes the training energy (the 'useless training' arm of
    the wooden-barrel effect)."""
    if not dev.alive:
        return False
    need = e_tra + e_com
    if dev.remaining <= need:
        # device attempts the round and dies mid-way; energy is wasted
        dev.remaining = 0.0
        dev.alive = False
        return False
    dev.remaining -= need
    return True


def total_remaining(devices: Sequence[DeviceState]) -> float:
    return float(sum(d.remaining for d in devices))


def make_fleet(n: int, seed: int = 0,
               tier_probs=(0.4, 0.3, 0.3),
               data_sizes: Optional[List[int]] = None) -> List[DeviceState]:
    """Heterogeneous fleet: 40%% small / 30%% medium / 30%% large by default
    (paper RQ2 uses 20 Nano + 20 Xavier; benchmarks override tier_probs)."""
    rng = np.random.default_rng(seed)
    tiers = rng.choice(list(DEVICE_TIERS), size=n, p=tier_probs)
    fleet = []
    for i, t in enumerate(tiers):
        prof = DeviceProfile.from_tier(str(t), rng)
        ds = int(data_sizes[i]) if data_sizes is not None else int(rng.integers(200, 1200))
        fleet.append(DeviceState(profile=prof, remaining=prof.battery,
                                 data_size=ds))
    return fleet


# The functions above are the SCALAR REFERENCE semantics; the vectorized
# struct-of-arrays engine lives in repro.core.fleet.  Lazy re-export (PEP
# 562) so `from repro.core.energy import FleetState` works without a
# circular import (fleet.py imports this module at its top).
_FLEET_EXPORTS = ("FleetState", "as_fleet_state", "make_fleet_state",
                  "sample_fleet_state", "fleet_round_cost",
                  "fleet_cost_matrix", "fleet_affordability", "fleet_charge",
                  "fleet_topk_mask", "fleet_summary", "summary_width",
                  "fleet_total_remaining", "fleet_connect",
                  "fleet_disconnect", "fleet_idle", "fleet_set_busy",
                  "set_modes")


def __getattr__(name):
    if name in _FLEET_EXPORTS:
        from repro.core import fleet as _fleet
        return getattr(_fleet, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
