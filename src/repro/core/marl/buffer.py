"""Episode replay buffer for QMIX (host-side numpy ring buffer).

Stores whole episodes (one FL run = one episode) so the GRU hidden state can
be unrolled from t=0 during learning.  Episodes are fixed-length ``T`` with
a validity mask (FL runs end early when the fleet dies).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, episode_len: int, n_agents: int,
                 obs_dim: int, state_dim: int, seed: int = 0):
        self.capacity = capacity
        self.T = episode_len
        self.N = n_agents
        self.size = 0
        self.ptr = 0
        self.rng = np.random.default_rng(seed)
        self.obs = np.zeros((capacity, episode_len + 1, n_agents, obs_dim), np.float32)
        self.state = np.zeros((capacity, episode_len + 1, state_dim), np.float32)
        self.actions = np.zeros((capacity, episode_len, n_agents), np.int64)
        self.rewards = np.zeros((capacity, episode_len), np.float32)
        self.mask = np.zeros((capacity, episode_len), np.float32)

    def add_episode(self, obs, state, actions, rewards):
        """obs: [t+1, N, obs_dim]; state: [t+1, state_dim];
        actions: [t, N]; rewards: [t] — t <= T."""
        t = len(rewards)
        i = self.ptr
        self.obs[i, :t + 1] = obs
        self.obs[i, t + 1:] = obs[-1]
        self.state[i, :t + 1] = state
        self.state[i, t + 1:] = state[-1]
        self.actions[i, :t] = actions
        self.actions[i, t:] = 0
        self.rewards[i, :t] = rewards
        self.rewards[i, t:] = 0.0
        self.mask[i, :t] = 1.0
        self.mask[i, t:] = 0.0
        self.ptr = (self.ptr + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def sample(self, batch: int) -> Optional[Dict[str, np.ndarray]]:
        if self.size == 0:
            return None
        idx = self.rng.integers(0, self.size, size=min(batch, self.size))
        return {
            "obs": self.obs[idx],
            "state": self.state[idx],
            "actions": self.actions[idx],
            "rewards": self.rewards[idx],
            "mask": self.mask[idx],
        }

    def __len__(self):
        return self.size
