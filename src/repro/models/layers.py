"""Core neural-net primitives (pure JAX, no flax).

Parameters are plain nested dicts of ``jnp.ndarray``.  Stacked ("scanned")
layer parameters carry a leading ``[L, ...]`` axis produced by ``vmap`` over
per-layer PRNG keys — see :mod:`repro.models.transformer`.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

Params = dict

# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return {"w": _normal(key, (d_in, d_out), scale, dtype)}


def dense_bias_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    p = dense_init(key, d_in, d_out, dtype, scale)
    p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embed_init(key, vocab: int, d: int, dtype):
    return {"emb": _normal(key, (vocab, d), 1.0, dtype)}


# ---------------------------------------------------------------------------
# normalisation
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def _make_dtype_barrier():
    # Older jax releases ship optimization_barrier without a differentiation
    # rule; wrap it in a custom_vjp (the barrier is semantically identity)
    # so the FL/train grads still work there.
    barrier = getattr(jax.lax, "optimization_barrier", None)
    if barrier is None:
        return lambda x: x
    try:
        jax.grad(lambda x: barrier(x * 1.0))(jnp.float32(1))
        return barrier
    except Exception:
        @jax.custom_vjp
        def _wrapped(x):
            return barrier(x)

        _wrapped.defvjp(lambda x: (barrier(x), None), lambda _, g: (g,))
        return _wrapped


_dtype_barrier_impl = None


def _dtype_barrier(x):
    # Probe lazily on first use (not at import) so importing the model
    # package stays free of jax tracing / backend-init side effects.
    global _dtype_barrier_impl
    if _dtype_barrier_impl is None:
        _dtype_barrier_impl = _make_dtype_barrier()
    return _dtype_barrier_impl(x)


def rmsnorm_apply(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    out = (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    # dtype barrier: without it XLA hoists the f32 internals above the SPMD
    # partitioner's resharding point and the residual-stream all-gathers /
    # all-reduces move FULL-PRECISION tensors (measured 2.8 TB f32/step on
    # yi-34b train_4k; bf16 halves it).  See EXPERIMENTS.md §Perf.
    return _dtype_barrier(out)


def layernorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: broadcastable to [..., S] (int)."""
    freqs = rope_freqs(x.shape[-1], theta)                     # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]                        # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, causal / sliding-window / cross, cached decode)
# ---------------------------------------------------------------------------


def attention_init(key, cfg, dtype, *, cross: bool = False):
    d, nh, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 6)
    mk = dense_bias_init if cfg.attn_bias else dense_init
    p = {
        "wq": mk(ks[0], d, nh * hd, dtype),
        "wk": mk(ks[1], d, nkv * hd, dtype),
        "wv": mk(ks[2], d, nkv * hd, dtype),
        "wo": mk(ks[3], nh * hd, d, dtype, scale=1.0 / math.sqrt(nh * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def gqa_attend(
    q: jnp.ndarray,            # [B, Sq, Hq, D]
    k: jnp.ndarray,            # [B, Sk, Hkv, D]
    v: jnp.ndarray,            # [B, Sk, Hkv, D]
    *,
    causal: bool,
    window: int = 0,
    q_offset=0,                # scalar or [B]; absolute position of q[0]
    kv_len=None,               # scalar/[B]: #valid cache entries (decode)
) -> jnp.ndarray:
    """Grouped-query attention.

    Default: grouped einsum (no repeated KV in HBM).  Under the
    ``repeat_kv`` sharding policy the KV heads ARE materialised to Hq so the
    score einsum contracts only the head_dim — on TP meshes where Hkv does
    not divide the model axis, the grouped form makes GSPMD partially
    contract the KV-head axis and ALL-REDUCE full [Sq,Sk] score tensors
    (measured 2.7 TB/step on yi-34b train_4k; see EXPERIMENTS.md §Perf)."""
    from repro.sharding.rules import get_sharding_policy
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)

    q_pos = jnp.asarray(q_offset)[..., None] + jnp.arange(Sq)     # [..., Sq]
    k_pos = jnp.arange(Sk)                                        # [Sk]
    mask = jnp.ones((Sq, Sk), bool) if q_pos.ndim == 1 else None
    qp = q_pos[..., :, None]                                      # [(B,)Sq,1]
    kp = k_pos[None, :]
    valid = jnp.ones_like(qp * 0 + kp, dtype=bool) if mask is None else mask
    if causal:
        valid = valid & (kp <= qp)
    if window:
        valid = valid & (kp > qp - window)
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        kl = kl[..., None, None] if kl.ndim == 1 else kl
        valid = valid & (kp < kl)
    while valid.ndim < 3:
        valid = valid[None]
    # valid: [B or 1, Sq, Sk]

    if get_sharding_policy().get("repeat_kv") and G > 1:
        # materialise repeated KV heads: the score einsum then has the
        # (padded, shardable) Hq axis as a pure batch dim
        from repro.sharding.rules import attn_head_shard
        kr = jnp.repeat(k, G, axis=2)
        vr = jnp.repeat(v, G, axis=2)
        q, kr, vr = attn_head_shard(q, kr, vr)
        # bf16 operands, fp32 MXU accumulation: collectives/reshards of
        # q/k/v stay half-width (the fp32 upcast used to happen BEFORE the
        # KV all-gather — measured 258 GB/step of f32 gathers on yi-34b)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[:, None, :, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), vr,
                       preferred_element_type=jnp.float32)
        return o.astype(q.dtype)

    qg = q.reshape(B, Sq, Hkv, G, D)
    # scores: [B, Hkv, G, Sq, Sk]; bf16 operands, fp32 accumulation
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


def gqa_attend_chunked(
    q: jnp.ndarray,            # [B, Sq, Hq, D]
    k: jnp.ndarray,            # [B, Sk, Hkv, D]
    v: jnp.ndarray,
    *,
    causal: bool,
    window: int = 0,
    chunk: int = 512,
) -> jnp.ndarray:
    """Online-softmax attention scanning over KV blocks — an XLA-level
    flash attention.  Never materialises the [Sq, Sk] score matrix: peak
    per-step memory is [B, H, Sq, chunk].  Numerically equivalent to
    :func:`gqa_attend` (same fp32 accumulation)."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    if Sk % chunk or Sk <= chunk:
        return gqa_attend(q, k, v, causal=causal, window=window)
    G = Hq // Hkv
    nblk = Sk // chunk
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, Hkv, G, D)
    kb = jnp.moveaxis(k.reshape(B, nblk, chunk, Hkv, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nblk, chunk, Hkv, D), 1, 0)
    q_pos = jnp.arange(Sq)[:, None]

    def body(carry, xs):
        acc, m, l = carry
        kj, vj, j = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kj,
                       preferred_element_type=jnp.float32) * scale
        k_pos = j * chunk + jnp.arange(chunk)[None, :]
        valid = jnp.ones((Sq, chunk), bool)
        if causal:
            valid &= k_pos <= q_pos
        if window:
            valid &= k_pos > q_pos - window
        s = jnp.where(valid[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vj.dtype),
                                       vj, preferred_element_type=jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (kb, vb, jnp.arange(nblk)))
    o = acc / jnp.maximum(l, 1e-30)
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, Hq, D).astype(q.dtype)


def attention_apply(
    p: Params,
    cfg,
    x: jnp.ndarray,                 # [B, S, d]
    positions: jnp.ndarray,         # [B, S] or [S]
    *,
    causal: bool = True,
    window: int = 0,
    cache: Optional[Params] = None,  # decode: {'k','v','pos'}
    kv_src: Optional[jnp.ndarray] = None,  # cross-attn source states
    use_pallas: bool = False,
    attn_chunk: int = 0,
    norm_eps: float = 1e-5,
):
    """Returns (out, new_cache)."""
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = _split_heads(dense_apply(p["wq"], x), nh, hd)
    src = x if kv_src is None else kv_src
    k = _split_heads(dense_apply(p["wk"], src), nkv, hd)
    v = _split_heads(dense_apply(p["wv"], src), nkv, hd)
    if "q_norm" in p:
        q = rmsnorm_apply(p["q_norm"], q, norm_eps)
        k = rmsnorm_apply(p["k_norm"], k, norm_eps)
    if kv_src is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cache is None:
        from repro.sharding.rules import attn_seq_shard
        q, k, v = attn_seq_shard(q, k, v)

    new_cache = None
    if cache is not None and kv_src is None:
        # single-token decode append; ring buffer when the cache is
        # window-sized (slot order is irrelevant post-RoPE: keys carry their
        # absolute positions, softmax is permutation-invariant).
        pos = cache["pos"]
        clen = cache["k"].shape[1]
        widx = jax.lax.rem(pos, clen)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), widx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), widx, axis=1)
        new_cache = {"k": ck, "v": cv, "pos": pos + x.shape[1]}
        o = gqa_attend(q, ck, cv, causal=False, window=0,
                       q_offset=pos, kv_len=jnp.minimum(pos + x.shape[1], clen))
    elif cache is not None:  # cross-attention with precomputed static cache
        o = gqa_attend(q, cache["k"], cache["v"], causal=False)
        new_cache = cache
    else:
        if use_pallas and kv_src is None and causal:
            from repro.kernels.flash_attention import ops as fa_ops
            o = fa_ops.flash_attention(q, k, v, causal=True, window=window)
        elif attn_chunk and kv_src is None:
            o = gqa_attend_chunked(q, k, v, causal=causal, window=window,
                                   chunk=attn_chunk)
        else:
            o = gqa_attend(q, k, v, causal=causal and kv_src is None, window=window)
    out = dense_apply(p["wo"], o.reshape(x.shape[:-1] + (nh * hd,)))
    return out, new_cache


def make_kv_cache(cfg, batch: int, length: int, dtype) -> Params:
    return {
        "k": jnp.zeros((batch, length, cfg.num_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, length, cfg.num_kv_heads, cfg.hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d: int, f: int, dtype, bias: bool = False):
    ks = jax.random.split(key, 3)
    mk = dense_bias_init if bias else dense_init
    return {
        "w_gate": mk(ks[0], d, f, dtype),
        "w_up": mk(ks[1], d, f, dtype),
        "w_down": mk(ks[2], f, d, dtype, scale=1.0 / math.sqrt(f)),
    }


def swiglu_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return dense_apply(p["w_down"], jax.nn.silu(dense_apply(p["w_gate"], x)) * dense_apply(p["w_up"], x))


def gelu_mlp_init(key, d: int, f: int, dtype, bias: bool = True):
    ks = jax.random.split(key, 2)
    mk = dense_bias_init if bias else dense_init
    return {"w_in": mk(ks[0], d, f, dtype), "w_out": mk(ks[1], f, d, dtype, scale=1.0 / math.sqrt(f))}


def gelu_mlp_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return dense_apply(p["w_out"], jax.nn.gelu(dense_apply(p["w_in"], x)))


def mlp_init(key, net_dims, dtype=jnp.float32):
    """Generic MLP used by the MARL nets: net_dims = [in, h1, ..., out]."""
    ks = jax.random.split(key, len(net_dims) - 1)
    return {f"l{i}": dense_bias_init(ks[i], net_dims[i], net_dims[i + 1], dtype)
            for i in range(len(net_dims) - 1)}


def mlp_apply(p: Params, x: jnp.ndarray, act=jax.nn.relu) -> jnp.ndarray:
    n = len(p)
    for i in range(n):
        x = dense_apply(p[f"l{i}"], x)
        if i < n - 1:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# GRU cell (MARL agents, paper Fig. 3)
# ---------------------------------------------------------------------------


def gru_init(key, d_in: int, d_h: int, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    return {
        "wx": dense_bias_init(ks[0], d_in, 3 * d_h, dtype),
        "wh": dense_init(ks[1], d_h, 3 * d_h, dtype),
    }


def gru_apply(p: Params, h: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    gx = dense_apply(p["wx"], x)
    gh = dense_apply(p["wh"], h)
    xr, xz, xn = jnp.split(gx, 3, axis=-1)
    hr, hz, hn = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(xr + hr)
    z = jax.nn.sigmoid(xz + hz)
    n = jnp.tanh(xn + r * hn)
    return (1.0 - z) * n + z * h
