"""Bucketed-vmap client-update executor: one jit dispatch per submodel bucket.

The per-client path (repro.fl.client) costs one jit dispatch per participant
per mini-batch — `participants x epochs x steps` program launches per round,
which dominates wall time at the 256-4096 fleet sizes of the Fig. 6
scalability study.  This module amortizes that to AT MOST ONE program
execution per populated submodel bucket (<= 4 per round, one per model
index):

1. bucket the cohort by submodel index ``m`` (shapes are static per index);
2. precompute a fixed-shape padded batch schedule per bucket on the host —
   per-client epoch permutations from the same ``client_update_seed`` RNG
   the per-client path uses, laid out as global-dataset gather indices
   ``[P, T, B]`` plus a ``[P, T]`` step-validity mask (pad steps re-run
   batch 0 of the schedule but are masked out of both the SGD update and
   the loss, so padding changes nothing);
3. run the bucket as ONE jit program: ``jax.vmap`` over participants of a
   ``jax.lax.scan`` over the T-step schedule, gathering mini-batches
   device-side from the resident training set (no per-batch host->device
   copies) and accumulating losses on device (one host sync per bucket).

The executor returns STACKED deltas ``[P, ...]`` per bucket in the
submodel's own tree structure — exactly what the stacked layer-aligned
aggregation path (repro.fl.server.aggregate_drfl_stacked -> Pallas
``layer_agg``) consumes without unstacking.  Baseline methods (HeteroFL /
ScaleFL) unstack to per-client trees for their scatter aggregation.

Shape discipline: P is padded to the next power of two and T to the next
power of two of the bucket's longest schedule, so recurring rounds reuse
the same compiled programs; ``COUNTERS`` tracks logical compilations (new
shape signatures) and program executions for the dispatch-count regression
guard in ``tests/test_batch.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.family import resolve_family

# dispatch accounting: "compiles" counts NEW (method, model, shape) program
# signatures, "executions" counts bucket program launches.  The regression
# guard asserts <= n_buckets executions per sync round and a bounded
# compile count across a run.
COUNTERS = {"compiles": 0, "executions": 0}
_SEEN_SIGNATURES: set = set()


def reset_counters() -> None:
    COUNTERS["compiles"] = 0
    COUNTERS["executions"] = 0
    _SEEN_SIGNATURES.clear()


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def submodel_params(method: str, global_params, model_idx: int,
                    family=None):
    """The initial tree every client in bucket ``model_idx`` trains."""
    return resolve_family(family).submodel_params(method, global_params,
                                                  model_idx)


# ---------------------------------------------------------------------------
# host-side schedule construction (RNG parity with data.loader.epoch_batches)
# ---------------------------------------------------------------------------


def client_schedule(part: np.ndarray, seed: int, epochs: int,
                    batch: int) -> np.ndarray:
    """Global-dataset gather indices ``[T_i, B]`` for one client's local run.

    Replicates :func:`repro.data.loader.epoch_batches` exactly — shuffled
    epochs, full batches only, one wrap-around padded batch for clients with
    fewer than ``batch`` samples — so a bucketed client consumes the same
    sample sequence as the per-client reference under the same seed."""
    rng = np.random.default_rng(seed)
    part = np.asarray(part)
    n = len(part)
    steps = []
    for _ in range(epochs):
        idx = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            steps.append(part[idx[i:i + batch]])
        if n < batch:
            steps.append(part[np.resize(idx, batch)])
    return np.asarray(steps, np.int32).reshape(len(steps), batch)


@dataclasses.dataclass
class Bucket:
    """One submodel bucket's padded schedule (host arrays)."""
    model_idx: int
    participants: List[int]          # device ids, cohort order
    weights: List[float]             # data sizes, aligned with participants
    gather: np.ndarray               # [P_pad, T_pad, B] int32
    valid: np.ndarray                # [P_pad, T_pad] float32

    @property
    def n_real(self) -> int:
        return len(self.participants)


def bucket_cohort(participants: Sequence[int], model_idxs: Sequence[int],
                  parts: Sequence[np.ndarray], seeds: Sequence[int],
                  weights: Sequence[float], *, epochs: int,
                  batch: int) -> List[Bucket]:
    """Group a cohort by submodel index and build padded schedules.

    Zero-data participants must be filtered by the caller (they have no
    schedule; the engine already skips them)."""
    by_m: Dict[int, List[int]] = {}
    for j, m in enumerate(model_idxs):
        by_m.setdefault(int(m), []).append(j)
    buckets = []
    for m in sorted(by_m):
        js = by_m[m]
        scheds = [client_schedule(parts[j], seeds[j], epochs, batch)
                  for j in js]
        t_pad = _next_pow2(max(len(s) for s in scheds))
        p_pad = _next_pow2(len(js))
        gather = np.zeros((p_pad, t_pad, batch), np.int32)
        valid = np.zeros((p_pad, t_pad), np.float32)
        for r, s in enumerate(scheds):
            gather[r, :len(s)] = s
            # pad steps replay the client's first batch (real rows, so the
            # compute stays finite) but are masked out of update + loss
            gather[r, len(s):] = s[0]
            valid[r, :len(s)] = 1.0
        gather[len(js):] = gather[0]     # pad clients replay client 0, masked
        buckets.append(Bucket(model_idx=m,
                              participants=[int(participants[j]) for j in js],
                              weights=[float(weights[j]) for j in js],
                              gather=gather, valid=valid))
    return buckets


# ---------------------------------------------------------------------------
# the bucket program: vmap over participants of a scan over the schedule
# ---------------------------------------------------------------------------


def _scan_unroll() -> bool | int:
    # XLA CPU executes conv bodies inside while-loops (what lax.scan lowers
    # to) ~6-8x slower than the same ops at top level — the in-loop thunks
    # miss the fused/multithreaded Eigen paths.  Fully unrolling restores
    # full speed at the price of compile time linear in T (bounded by the
    # pow2 T padding).  TPU/GPU keep the rolled scan: it compiles in O(1)
    # and runs at full speed there.
    return True if jax.default_backend() == "cpu" else 1


@functools.partial(jax.jit, static_argnames=("method", "lr", "family"))
def _bucket_program(sub_params, x_all, y_all, gather, valid, *, method: str,
                    lr: float, family):
    """ONE program for a whole bucket.

    sub_params: the bucket's submodel tree (shared initial point)
    gather:     [P, T, B] int32 rows into x_all/y_all
    valid:      [P, T] float32 step mask (0 = padding, no-op step)

    Returns (stacked delta pytree [P, ...], mean losses [P]).
    """
    loss_fn = family.loss_fn(method)

    def one_client(g_i, v_i):
        def body(carry, inp):
            params, loss_sum, n_valid = carry
            idx, v = inp
            xb = jnp.take(x_all, idx, axis=0)
            yb = jnp.take(y_all, idx, axis=0)
            loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
            # v==1.0 multiplies are exact, so real steps match the
            # per-client `p - lr*g`; v==0.0 makes the step an identity
            params = jax.tree.map(lambda p, g: p - lr * (g * v),
                                  params, grads)
            return (params, loss_sum + loss * v, n_valid + v), None

        (params, loss_sum, n_valid), _ = jax.lax.scan(
            body, (sub_params, jnp.float32(0.0), jnp.float32(0.0)),
            (g_i, v_i), unroll=_scan_unroll())
        delta = jax.tree.map(lambda a, b: a - b, params, sub_params)
        return delta, loss_sum / jnp.maximum(n_valid, 1.0)

    # families may swap in a vmap-friendly forward for the batched trace
    # (the CNN's patches+einsum convs on CPU, where vmapped per-client
    # conv kernels lower to a pathological grouped-conv path)
    with family.bucket_trace_context():
        return jax.vmap(one_client)(gather, valid)


def _signature(family, method: str, model_idx: int, sub_params,
               gather_shape, data_shape, lr: float):
    shapes = tuple((tuple(l.shape), str(l.dtype))
                   for l in jax.tree.leaves(sub_params))
    return (family.name, method, int(model_idx), tuple(gather_shape),
            tuple(data_shape), float(lr), shapes)


@dataclasses.dataclass
class BucketResult:
    """Stacked outcome of one bucket execution.

    ``stacked_delta`` keeps the executor's pow2 participant padding (pad
    rows carry garbage deltas and weight 0.0, so downstream weighted
    aggregation ignores them exactly) — stable shapes mean the stacked
    aggregation program compiles once per bucket signature.  Real rows are
    the first ``len(participants)``."""
    model_idx: int
    participants: List[int]
    weights: List[float]             # [P_pad], 0.0 beyond the real rows
    stacked_delta: object            # submodel pytree, leaves [P_pad, ...]
    losses: np.ndarray               # [P_real] float


def run_bucket(method: str, global_params, x_all, y_all, bucket: Bucket, *,
               lr: float, family=None) -> BucketResult:
    """Execute one bucket as a single jit program."""
    fam = resolve_family(family)
    sub = fam.submodel_params(method, global_params, bucket.model_idx)
    sig = _signature(fam, method, bucket.model_idx, sub,
                     bucket.gather.shape, x_all.shape, lr)
    if sig not in _SEEN_SIGNATURES:
        _SEEN_SIGNATURES.add(sig)
        COUNTERS["compiles"] += 1
    COUNTERS["executions"] += 1
    stacked, losses = _bucket_program(
        sub, x_all, y_all, jnp.asarray(bucket.gather),
        jnp.asarray(bucket.valid), method=method, lr=float(lr), family=fam)
    p = bucket.n_real
    p_pad = bucket.gather.shape[0]
    return BucketResult(model_idx=bucket.model_idx,
                        participants=list(bucket.participants),
                        weights=(list(bucket.weights)
                                 + [0.0] * (p_pad - p)),
                        stacked_delta=stacked,
                        # jaxlint: allow(host-sync-in-hot-path) -- one losses pull per bucket program; deltas stay on device
                        losses=np.asarray(losses[:p]))


# ---------------------------------------------------------------------------
# cohort-level API used by the round engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CohortResult:
    buckets: List[BucketResult]

    def unstacked(self):
        """Per-participant (device_id, model_idx, delta, weight, loss),
        in bucket order — for the list-based aggregation paths."""
        out = []
        for b in self.buckets:
            for r, i in enumerate(b.participants):
                delta = jax.tree.map(lambda a, r=r: a[r], b.stacked_delta)
                # jaxlint: allow(host-sync-in-hot-path) -- BucketResult.losses is already host numpy (pulled once per bucket)
                loss = float(b.losses[r])
                out.append((i, b.model_idx, delta, b.weights[r], loss))
        return out


def run_cohort(method: str, global_params, x_all, y_all,
               parts: Sequence[np.ndarray], participants: Sequence[int],
               model_idxs: Sequence[int], seeds: Sequence[int],
               weights: Optional[Sequence[float]] = None, *, epochs: int,
               batch: int, lr: float, family=None) -> CohortResult:
    """Run a whole cohort's local training in <= n_buckets jit dispatches.

    ``parts`` is aligned with ``participants`` (one index array each);
    zero-data participants must already be filtered out."""
    if weights is None:
        weights = [float(len(p)) for p in parts]
    buckets = bucket_cohort(participants, model_idxs, parts, seeds, weights,
                            epochs=epochs, batch=batch)
    x_all = jnp.asarray(x_all)
    y_all = jnp.asarray(y_all)
    fam = resolve_family(family)
    return CohortResult(buckets=[
        run_bucket(method, global_params, x_all, y_all, b, lr=lr,
                   family=fam)
        for b in buckets])
