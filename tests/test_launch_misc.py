"""Launch-layer odds and ends: shape adaptation, serve builders, slot server."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, TrainConfig, get_config, get_smoke_config
from repro.launch.steps import (adapt_for_shape, build_prefill_step,
                                build_serve_step, chunked_cross_entropy)


def test_adapt_for_shape_swa_policy():
    yi = get_config("yi-34b")
    assert adapt_for_shape(yi, INPUT_SHAPES["long_500k"]).window == 8192
    assert adapt_for_shape(yi, INPUT_SHAPES["train_4k"]).window == 0
    mix = get_config("mixtral-8x22b")   # native SWA kept
    assert adapt_for_shape(mix, INPUT_SHAPES["long_500k"]).window == 4096
    xl = get_config("xlstm-1.3b")       # recurrent: untouched
    assert adapt_for_shape(xl, INPUT_SHAPES["long_500k"]).window == 0


def test_prefill_step_last_logits():
    cfg = get_smoke_config("minitron-8b")
    model, step = build_prefill_step(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    out = jax.jit(step)(params, {"tokens": toks})
    assert out.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(out).all())


def test_serve_step_greedy_token():
    cfg = get_smoke_config("phi3-mini-3.8b")
    model, step = build_serve_step(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.decode_init(params, 2, 8)
    tok = jnp.zeros((2, 1), jnp.int32)
    nxt, cache = jax.jit(step, donate_argnums=(1,))(params, cache, tok,
                                                    jnp.int32(0))
    assert nxt.shape == (2, 1) and nxt.dtype == jnp.int32
    assert int(cache["pos"][0]) == 1


def test_chunked_ce_matches_dense():
    key = jax.random.PRNGKey(0)
    B, S, d, V = 2, 16, 8, 32
    h = jax.random.normal(key, (B, S, d))
    W = jax.random.normal(jax.random.fold_in(key, 1), (d, V))
    lab = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)
    dense = (jax.nn.logsumexp(h @ W, -1)
             - jnp.take_along_axis(h @ W, lab[..., None], -1)[..., 0]).mean()
    for chunk in (4, 8, 16):
        out = chunked_cross_entropy(h, W, lab, chunk)
        np.testing.assert_allclose(float(out), float(dense), rtol=1e-5)
    # masked labels excluded
    lab2 = lab.at[:, :8].set(-1)
    out = chunked_cross_entropy(h, W, lab2, 8)
    dense2 = (jax.nn.logsumexp(h @ W, -1)
              - jnp.take_along_axis(h @ W, jnp.maximum(lab2, 0)[..., None],
                                    -1)[..., 0])[:, 8:].mean()
    np.testing.assert_allclose(float(out), float(dense2), rtol=1e-5)


def test_slot_server_serves_requests():
    from repro.launch.serve import SlotServer
    cfg = get_smoke_config("minitron-8b")
    srv = SlotServer(cfg, slots=2, max_len=48)
    rng = np.random.default_rng(0)
    pending = [rng.integers(0, cfg.vocab_size, size=6) for _ in range(3)]
    done = 0
    while done < 3 and srv.pos < srv.max_len - 1:
        while pending and srv.submit(pending[0], 3) is not None:
            pending.pop(0)
        done += len(srv.step())
    assert done == 3
