"""Pure-JAX optimizers (pytree-level AdamW and SGD+momentum).

Conventions:
* params may be bf16 (full-scale runs) or fp32 (smoke tests); AdamW moments
  are kept fp32 and the update math happens in fp32 regardless.
* ``update`` takes the already-scaled learning rate (schedules are applied by
  the caller via :func:`repro.optim.make_schedule`).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

Pytree = Any


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Pytree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params: Pytree, moment_dtype=jnp.float32) -> Pytree:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }


def adamw_update(grads: Pytree, state: Pytree, params: Pytree, *,
                 lr, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.0,
                 grad_clip: float = 0.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if grad_clip:
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
    else:
        scale = jnp.ones((), jnp.float32)
    step = state["step"] + 1
    b1c = 1.0 - beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - beta2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = beta1 * m + (1.0 - beta1) * g
        v = beta2 * v + (1.0 - beta2) * g * g
        mh = m / b1c
        vh = v / b2c
        pf = p.astype(jnp.float32)
        step_ = mh / (jnp.sqrt(vh) + eps)
        if weight_decay and p.ndim >= 2:   # decoupled decay, matrices only
            step_ = step_ + weight_decay * pf
        return (pf - lr * step_).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "mu": new_m, "nu": new_v}, {"grad_norm": gnorm}


# ---------------------------------------------------------------------------
# SGD (+momentum) — used by FL clients (paper: lr 0.05 SGD)
# ---------------------------------------------------------------------------


def sgd_init(params: Pytree, momentum: float = 0.0) -> Pytree:
    if momentum == 0.0:
        return {"step": jnp.zeros((), jnp.int32)}
    return {"step": jnp.zeros((), jnp.int32),
            "vel": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}


def sgd_update(grads: Pytree, state: Pytree, params: Pytree, *,
               lr, momentum: float = 0.0, grad_clip: float = 0.0):
    gnorm = global_norm(grads)
    scale = (jnp.minimum(1.0, grad_clip / (gnorm + 1e-9)) if grad_clip
             else jnp.ones((), jnp.float32))
    step = state["step"] + 1
    if momentum == 0.0:
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32) * scale).astype(p.dtype),
            params, grads)
        return new_p, {"step": step}, {"grad_norm": gnorm}
    new_v = jax.tree.map(
        lambda v, g: momentum * v + g.astype(jnp.float32) * scale,
        state["vel"], grads)
    new_p = jax.tree.map(
        lambda p, v: (p.astype(jnp.float32) - lr * v).astype(p.dtype),
        params, new_v)
    return new_p, {"step": step, "vel": new_v}, {"grad_norm": gnorm}
