"""FL server aggregation paths: width-sliced scatter, depth-truncated
structure tolerance, DR-FL masks, evaluation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import (WIDTH_LEVELS, scalefl_submodel,
                                  width_slice_cnn)
from repro.fl import server as fl_server
from repro.models import cnn


def _params():
    return cnn.init(jax.random.PRNGKey(0), num_classes=10, width_mult=0.25)


def test_width_slice_shapes_shrink():
    p = _params()
    half = width_slice_cnn(p, 0.5)
    assert half["stem"]["conv"].shape[3] == p["stem"]["conv"].shape[3] // 2
    assert half["stem"]["conv"].shape[2] == 3          # input channels kept
    full = width_slice_cnn(p, 1.0)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(p)):
        assert a.shape == b.shape


def test_scalefl_submodel_truncates_depth_and_width():
    p = _params()
    sub = scalefl_submodel(p, 1)          # depth 2 stages, width 0.5
    assert len(sub["stages"]) == 2 and len(sub["exits"]) == 2
    assert sub["stages"][0][0]["conv1"].shape[3] \
        == p["stages"][0][0]["conv1"].shape[3] // 2


def test_aggregate_sliced_identity_on_full_slices():
    """A single full-width zero delta leaves the global model unchanged."""
    p = _params()
    zero = jax.tree.map(jnp.zeros_like, width_slice_cnn(p, 1.0))
    out = fl_server.aggregate_sliced(p, [zero], [1.0])
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_aggregate_sliced_partial_coverage():
    """A quarter-width delta of ones bumps exactly its covered entries."""
    p = _params()
    delta = jax.tree.map(jnp.ones_like, width_slice_cnn(p, 0.25))
    out = fl_server.aggregate_sliced(p, [delta], [2.0])
    w_new = np.asarray(out["stem"]["conv"])
    w_old = np.asarray(p["stem"]["conv"])
    cov = delta["stem"]["conv"].shape[3]
    np.testing.assert_allclose(w_new[..., :cov], w_old[..., :cov] + 1.0,
                               rtol=1e-6)
    np.testing.assert_allclose(w_new[..., cov:], w_old[..., cov:])


def test_aggregate_sliced_aliased_leaves_stay_independent():
    """Regression (ISSUE 4): the contribution table used to be keyed by
    ``id(leaf)``, so two tree positions sharing one array object collided —
    contributions to one path leaked into the other and merged.  Path-keyed
    collection must keep aliased leaves independent."""
    shared = jnp.zeros((4,))                      # ONE object, TWO paths
    gp = {"a": shared, "b": shared, "c": jnp.zeros((4,))}
    # client 1 trains only "a"; client 2 trains "a" and "b" differently
    d1 = {"a": jnp.ones((4,))}
    d2 = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 5.0}
    out = fl_server.aggregate_sliced(gp, [d1, d2], [1.0, 1.0])
    # "a" = mean(1, 3) = 2; "b" covered only by client 2 -> 5; "c" untouched
    np.testing.assert_allclose(np.asarray(out["a"]), 2.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"]), 5.0, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(out["c"]), 0.0)
    # a path no client covered keeps the ORIGINAL leaf even when aliased
    out2 = fl_server.aggregate_sliced(gp, [d1], [2.0])
    np.testing.assert_allclose(np.asarray(out2["a"]), 1.0, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(out2["b"]), 0.0)


def test_aggregate_drfl_untrained_exits_unchanged():
    p = _params()
    delta = jax.tree.map(jnp.ones_like, p)
    out = fl_server.aggregate_drfl(p, [delta], [0], [1.0])   # Model_1 client
    # exit 3 untouched
    np.testing.assert_allclose(np.asarray(out["exits"][3]["w"]),
                               np.asarray(p["exits"][3]["w"]))
    # stem moved
    assert not np.allclose(np.asarray(out["stem"]["conv"]),
                           np.asarray(p["stem"]["conv"]))


def test_evaluate_returns_per_exit_accuracy():
    p = _params()
    x = np.random.default_rng(0).normal(size=(32, 16, 16, 3)).astype(np.float32)
    y = np.random.default_rng(1).integers(0, 10, 32)
    accs = fl_server.evaluate(p, x, y)
    assert accs.shape == (4,)
    assert np.all((accs >= 0) & (accs <= 1))
