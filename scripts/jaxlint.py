#!/usr/bin/env python
"""jaxlint CLI wrapper — equivalent to ``python -m repro.analysis``.

Usable without installing the package or setting PYTHONPATH: it adds the
repo's ``src/`` to ``sys.path`` itself and defaults ``--root`` to the
repo this script lives in.
"""
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a == "--root" or a.startswith("--root=") for a in argv):
        argv = ["--root", _REPO] + argv
    sys.exit(main(argv))
