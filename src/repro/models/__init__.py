from repro.models.api import Model, build, extra_inputs  # noqa: F401
