"""Name-based sharding rules with divisibility fallback.

Logical axes are inferred from parameter *path suffixes* (the same names the
model modules use); each logical axis maps to a mesh axis through
:data:`LOGICAL_TO_MESH`.  Rules silently fall back to replication when a
dimension is not divisible by the mesh-axis size — this is what lets one rule
table cover all ten assigned architectures (e.g. mixtral's 8 experts cannot
shard over a 16-way model axis, so its experts replicate and the expert FFN
width shards instead).

The batch ("data-parallel") axes are ``("pod", "data")`` on the multi-pod
mesh and ``("data",)`` on the single-pod mesh; weights are FSDP-sharded over
``data`` only (each pod holds the full FSDP shard group — this is the FL
mapping: pods are DR-FL clients and exchange weights by layer-aligned
aggregation over the ``pod`` axis).
"""
from __future__ import annotations

import re
import threading
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# --- logical-axis rule table -------------------------------------------------
# suffix regex -> logical axes of the *base* (unstacked) param shape,
# rightmost dims.  Leading stacked layer/group dims are padded with None.
RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    (r"embed/emb$",                    ("vocab", "embed")),
    (r"unembed/w$",                    ("embed", "vocab")),
    (r"attn/w[qkv]/w$",                ("embed", "heads")),
    (r"cross/w[qkv]/w$",               ("embed", "heads")),
    (r"attn/wo/w$",                    ("heads", "embed")),
    (r"cross/wo/w$",                   ("heads", "embed")),
    (r"moe/router$",                   ("embed", None)),
    (r"moe/w_gate$",                   ("expert", "embed", "mlp")),
    (r"moe/w_up$",                     ("expert", "embed", "mlp")),
    (r"moe/w_down$",                   ("expert", "mlp", "embed")),
    (r"(mlp|ffn)/w_gate/w$",           ("embed", "mlp")),
    (r"(mlp|ffn)/w_up/w$",             ("embed", "mlp")),
    (r"(mlp|ffn)/w_down/w$",           ("mlp", "embed")),
    (r"(mlp|ffn)/w_in/w$",             ("embed", "mlp")),
    (r"(mlp|ffn)/w_out/w$",            ("mlp", "embed")),
    (r"w_up$",                         ("embed", "mlp")),      # xlstm mLSTM up
    (r"w_down$",                       ("mlp", "embed")),
    (r"w_in$",                         ("embed", "mlp")),      # mamba / slstm in
    (r"w_out$",                        ("mlp", "embed")),
    (r"wq$",                           ("mlp", "heads")),      # xlstm q/k/v (inner,inner)
    (r"wk$",                           ("mlp", "heads")),
    (r"wv$",                           ("mlp", "heads")),
    # sLSTM recurrent weights: REPLICATED.  They are small (4M params x L/2)
    # but live inside the 4096-step time scan — sharding them made GSPMD
    # all-reduce their gradient every step of the backward scan (206 GB/step
    # measured on xlstm train_4k; §Perf X6).
    (r"/r$",                           (None, None, None)),
)

LOGICAL_TO_MESH = {
    "vocab": ("model",),
    "heads": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "embed": ("data",),     # ZeRO/FSDP axis
}

# --- sharding policy (perf-iteration knobs; see EXPERIMENTS.md §Perf) --------
# fsdp=False        -> weights replicated over 'data' (pure TP+DP): removes
#                      the per-layer weight all-gathers inside the scan at the
#                      cost of per-device weight memory.
# act_model=False   -> residual stream replicated over 'model' (no
#                      sequence-parallel style activation all-gathers; GSPMD
#                      chooses where to partition attention/MLP internals).
_POLICY = {"fsdp": True, "act_model": True, "repeat_kv": False,
           "zero1": False, "attn_seq": False, "attn_heads": False, "act_seq": False, "block_gather": False,
           "dp2d": False}


def set_sharding_policy(*, fsdp: Optional[bool] = None,
                        act_model: Optional[bool] = None,
                        repeat_kv: Optional[bool] = None,
                        zero1: Optional[bool] = None,
                        attn_seq: Optional[bool] = None,
                        attn_heads: Optional[bool] = None,
                        act_seq: Optional[bool] = None,
                        block_gather: Optional[bool] = None,
                        dp2d: Optional[bool] = None):
    """repeat_kv: materialise repeated KV heads inside attention so GSPMD
    shards the (padded) Q-head axis instead of partially contracting the
    indivisible KV-head axis (which all-reduces full score tensors).
    zero1: with fsdp=False, keep optimizer moments sharded over 'data'
    (ZeRO-1) — replicated weights, sharded optimizer state."""
    if fsdp is not None:
        _POLICY["fsdp"] = fsdp
    if act_model is not None:
        _POLICY["act_model"] = act_model
    if repeat_kv is not None:
        _POLICY["repeat_kv"] = repeat_kv
    if zero1 is not None:
        _POLICY["zero1"] = zero1
    if attn_seq is not None:
        _POLICY["attn_seq"] = attn_seq
    if attn_heads is not None:
        _POLICY["attn_heads"] = attn_heads
    if act_seq is not None:
        _POLICY["act_seq"] = act_seq
    if block_gather is not None:
        _POLICY["block_gather"] = block_gather
    if dp2d is not None:
        _POLICY["dp2d"] = dp2d


def get_sharding_policy():
    return dict(_POLICY)


def batch_axes(mesh: Mesh):
    """Mesh axes carrying the global batch.

    Under the ``dp2d`` policy the model axis joins the batch axes: with
    global_batch >= #devices every device holds whole sequences, attention
    and MLP matmuls are fully local, and the only collectives left are the
    per-layer weight/output gathers + gradient reduce-scatters (ZeRO-3-like
    streaming over the model axis).  See EXPERIMENTS.md §Perf (yi-34b)."""
    if _POLICY.get("dp2d"):
        # batch covers (data x model); the pod axis stays a pure replication
        # /aggregation axis (in the FL mapping each pod-client sees its own
        # global batch and aggregates over 'pod')
        return tuple(a for a in ("data", "model") if a in mesh.axis_names)
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _mesh_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec_for(path: str, shape, mesh: Mesh, force_fsdp: bool = False) -> P:
    """PartitionSpec for one param leaf. 1-D/0-D params replicate."""
    if len(shape) <= 1:
        return P()
    for pat, logical in RULES:
        if re.search(pat, path):
            base = list(logical)
            ndim = len(shape)
            pad = ndim - len(base)
            if pad < 0:           # shape smaller than rule (shouldn't happen)
                return P()
            axes = [None] * pad + base
            out, used = [], set()
            for dim, name in zip(shape, axes):
                if name is None:
                    out.append(None)
                    continue
                if name == "embed" and not (_POLICY["fsdp"] or force_fsdp):
                    out.append(None)
                    continue
                mesh_axes = LOGICAL_TO_MESH.get(name, ())
                if (mesh_axes and not (set(mesh_axes) & used)
                        and dim % _mesh_size(mesh, mesh_axes) == 0):
                    used.update(mesh_axes)
                    out.append(mesh_axes[0] if len(mesh_axes) == 1 else tuple(mesh_axes))
                else:
                    out.append(None)
            return P(*out)
    return P()


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params_shape, mesh: Mesh, force_fsdp: bool = False):
    """pytree of PartitionSpec matching a params (shape-)pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: spec_for(_path_str(kp), leaf.shape, mesh, force_fsdp),
        params_shape)


def cache_specs(cache_shape, mesh: Mesh):
    """Decode-cache shardings.

    KV caches are [..., batch, seq, kv_heads, head_dim]; recurrent states are
    [..., batch, heads, ...].  Strategy: shard batch over the data axes when
    divisible; then kv_heads over 'model' when divisible, else the seq dim.
    """
    b_axes = batch_axes(mesh)
    b_size = _mesh_size(mesh, b_axes)
    m_size = mesh.shape["model"]

    def leaf_spec(kp, leaf):
        path = _path_str(kp)
        shape = leaf.shape
        if leaf.ndim <= 1 or path.endswith("pos"):
            return P()
        # locate the batch dim: first dim (after stacked prefixes) whose size
        # matches heuristics is fragile — instead use known layouts:
        # kv caches: (..., B, S, H, D); ssm/conv states: (L?, B, ...)
        out = [None] * leaf.ndim
        if path == "k" or path == "v" or path.endswith("/k") or path.endswith("/v"):
            bdim, sdim, hdim = leaf.ndim - 4, leaf.ndim - 3, leaf.ndim - 2
            ddim = leaf.ndim - 1
            if shape[bdim] % b_size == 0 and shape[bdim] >= b_size:
                out[bdim] = b_axes if len(b_axes) > 1 else b_axes[0]
            if shape[hdim] % m_size == 0:
                out[hdim] = "model"
            elif shape[sdim] % m_size == 0:
                # seq-dim sharding: GSPMD select-rewrites the local cache
                # shard on every dynamic write (~612 GB/step measured on
                # qwen3 decode_32k) but still beats head_dim sharding, whose
                # per-layer f32 score all-reduces cost more (1.2s vs 0.79s —
                # §Perf iteration B2, refuted hypothesis kept for the record)
                out[sdim] = "model"
            elif shape[ddim] % m_size == 0:
                out[ddim] = "model"
        else:
            # recurrent / conv states: (stack?, B, H or C, ...)
            bdim = 1 if leaf.ndim >= 3 else 0
            if shape[bdim] % b_size == 0 and shape[bdim] >= b_size:
                out[bdim] = b_axes if len(b_axes) > 1 else b_axes[0]
            for d in range(bdim + 1, leaf.ndim):
                if shape[d] % m_size == 0:
                    out[d] = "model"
                    break
        return P(*out)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)


# --- activation sharding constraint (set by the step builder) ----------------

_ctx = threading.local()


def set_activation_mesh(mesh: Optional[Mesh], model_axis_ok: bool = True):
    """Install the mesh used by :func:`constrain` inside model code.

    ``model_axis_ok=False`` disables sharding the feature dim (e.g. decode
    steps where the residual stream is tiny)."""
    _ctx.mesh = mesh
    _ctx.model_ok = model_axis_ok


def activation_spec(mesh: Mesh, ndim: int, model_ok: bool = True) -> P:
    b = batch_axes(mesh)
    spec = [None] * ndim
    spec[0] = b if len(b) > 1 else b[0]
    if model_ok and ndim >= 3:
        if _POLICY.get("dp2d"):
            pass                   # model axis already consumed by the batch
        elif _POLICY.get("act_seq"):
            spec[1] = "model"      # Megatron-style sequence parallelism
        else:
            spec[-1] = "model"
    return P(*spec)


def constrain_spec(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """Apply an explicit PartitionSpec constraint if a mesh is installed."""
    mesh = getattr(_ctx, "mesh", None)
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def gather_block_input(x):
    """Manual sequence-parallel boundary: all-gather the bf16 residual to
    full feature width ONCE at block entry.  Without this, the SPMD
    partitioner gathers the norm's f32 UPCAST (2x the bytes) — and does it
    separately for the attention and MLP branches."""
    mesh = getattr(_ctx, "mesh", None)
    if mesh is None or not _POLICY.get("block_gather") or x.ndim != 3:
        return x
    b = batch_axes(mesh)
    return constrain_spec(x, P(b if len(b) > 1 else b[0], None, None))


def attn_head_shard(q, k, v):
    """Head-axis attention sharding with GSPMD padding: constrain Q and the
    (repeated) KV to P(batch, None, 'model', None) on the head axis.  For
    head counts that do not divide the model axis (yi-34b: 56 on 16) GSPMD
    pads rather than falling back to the partial-contraction layout that
    all-reduces full score tensors.  Use together with repeat_kv."""
    mesh = getattr(_ctx, "mesh", None)
    if mesh is None or not _POLICY.get("attn_heads"):
        return q, k, v
    if q.shape[1] <= 1:
        return q, k, v
    b = batch_axes(mesh)
    bspec = b if len(b) > 1 else b[0]
    q = constrain_spec(q, P(bspec, None, "model", None))
    if _POLICY.get("repeat_kv") and q.shape[2] != k.shape[2]:
        return q, k, v   # repeat happens inside gqa_attend; constrain there
    k = constrain_spec(k, P(bspec, None, "model", None))
    v = constrain_spec(v, P(bspec, None, "model", None))
    return q, k, v


def attn_seq_shard(q, k, v):
    """Context-parallel attention sharding: Q over ('model', sequence), KV
    replicated on the model axis.  Rationale (yi-34b: 56 heads on a 16-way
    model axis): GSPMD cannot shard an indivisible head axis, falls back to
    2-D (head x head_dim) sharding, and partially contracts head_dim —
    ALL-REDUCING full [Sq,Sk] f32 score tensors.  Sequence-sharding the
    queries makes every score/output tensor cleanly partitioned; the price
    is one KV all-gather per layer (Hkv * hd * S bytes — 3 orders of
    magnitude smaller).  Applied only when the policy flag is on and shapes
    divide."""
    mesh = getattr(_ctx, "mesh", None)
    if mesh is None or not _POLICY.get("attn_seq"):
        return q, k, v
    m = mesh.shape["model"]
    if q.shape[1] % m or q.shape[1] < m:
        return q, k, v
    b = batch_axes(mesh)
    bspec = b if len(b) > 1 else b[0]
    q = constrain_spec(q, P(bspec, "model", None, None))
    k = constrain_spec(k, P(bspec, None, None, None))
    v = constrain_spec(v, P(bspec, None, None, None))
    return q, k, v


def constrain(x: jnp.ndarray) -> jnp.ndarray:
    """Residual-stream sharding constraint: [B, S, d] -> (batch, None, model).

    No-op unless a mesh was installed via :func:`set_activation_mesh` —
    models call this unconditionally; single-device tests pay nothing.
    """
    mesh = getattr(_ctx, "mesh", None)
    if mesh is None or x.ndim < 2:
        return x
    model_ok = getattr(_ctx, "model_ok", True) and _POLICY["act_model"]
    spec = activation_spec(mesh, x.ndim, model_ok)
    # divisibility guard on the sharded dim
    dim = 1 if _POLICY.get("act_seq") else -1
    if model_ok and x.ndim >= 3 and x.shape[dim] % mesh.shape["model"] != 0:
        spec = activation_spec(mesh, x.ndim, False)
    if x.shape[0] % _mesh_size(mesh, batch_axes(mesh)) != 0:
        lst = list(spec)
        lst[0] = None
        spec = P(*lst)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
