"""End-to-end behaviour tests for the DR-FL system (paper workflow §4.2)."""
import numpy as np
import pytest

from repro.fl import FLConfig, run_simulation


@pytest.fixture(scope="module")
def drfl_history():
    # 10 rounds: enough for the best exit to clear 0.3 under the
    # collision-free client seeds (ISSUE 2) at this tiny budget
    cfg = FLConfig(n_devices=8, n_rounds=10, participation=0.4, n_train=900,
                   local_epochs=2, method="drfl", selector="greedy", seed=3,
                   noise=0.8)
    return run_simulation(cfg)


def test_drfl_learns_above_chance(drfl_history):
    h = drfl_history
    assert max(h["acc_mean"]) > 0.13          # > chance (0.1) on mean of exits
    assert float(np.max(h["best_acc"])) > 0.3  # best exit learns clearly


def test_energy_ledger_monotone_and_consistent(drfl_history):
    e = drfl_history["energy"]
    assert all(e[i + 1] <= e[i] + 1e-6 for i in range(len(e) - 1))
    assert e[-1] >= 0.0


def test_round_time_is_max_over_participants(drfl_history):
    assert all(t >= 0 for t in drfl_history["round_time"])
    assert len(drfl_history["participants"]) == len(drfl_history["acc_mean"])


def test_participation_cap(drfl_history):
    k = max(1, int(round(0.4 * 8)))
    assert all(len(p) <= k for p in drfl_history["participants"])


def test_model_choices_valid(drfl_history):
    for choices in drfl_history["model_choices"]:
        assert all(0 <= m < 4 for m in choices)


def test_marl_arm_runs_and_records_rewards():
    cfg = FLConfig(n_devices=6, n_rounds=4, participation=0.5, n_train=600,
                   local_epochs=1, method="drfl", selector="marl", seed=0)
    h = run_simulation(cfg)
    assert len(h["reward"]) == 4
    assert np.isfinite(h["reward"]).all()


def test_baseline_arms_run():
    for method in ("heterofl", "scalefl"):
        cfg = FLConfig(n_devices=6, n_rounds=2, participation=0.5, n_train=500,
                       local_epochs=1, method=method, seed=1)
        h = run_simulation(cfg)
        assert len(h["acc_mean"]) == 2
        assert np.isfinite(h["acc_mean"]).all()


def test_energy_constraint_kills_devices():
    """With a tiny battery the fleet dies and the run ends early — the
    paper's RQ2 failure mode."""
    cfg = FLConfig(n_devices=6, n_rounds=12, participation=0.6, n_train=500,
                   local_epochs=2, method="drfl", selector="random",
                   energy_scale=0.002, seed=2)
    h = run_simulation(cfg)
    assert h["alive"][-1] < 6
    assert h["dropouts"] >= 0


def test_hotplug_devices_join_mid_run():
    """Paper §4.2: hot-plug devices connect mid-run, receive the global
    model, and participate from their join round with fresh batteries."""
    cfg = FLConfig(n_devices=5, n_rounds=6, participation=0.6, n_train=500,
                   local_epochs=1, method="drfl", selector="greedy", seed=4,
                   hotplug_round=3, hotplug_n=3)
    h = run_simulation(cfg)
    # before the join round, at most 5 devices exist/participate
    assert all(i < 5 for p in h["participants"][:3] for i in p)
    assert h["alive"][0] == 5
    assert h["alive"][3] == 8
    # a hot-plugged device (index >= 5) participates after joining
    late = {i for p in h["participants"][3:] for i in p}
    assert any(i >= 5 for i in late)


def test_fl_env_gym_interface():
    from repro.fl.environment import FLEnv, FLEnvConfig
    import numpy as np
    env = FLEnv(FLEnvConfig(n_devices=6, n_rounds=5, seed=0))
    obs = env.reset()
    assert obs.shape == (6, env.obs_dim)
    total_r = 0.0
    for t in range(5):
        acts = np.full(6, 0)        # everyone trains the smallest model
        obs, r, done, info = env.step(acts)
        total_r += r
        assert np.isfinite(r)
    assert done
    assert info["acc"] > 0.1        # proxy accuracy improved
    # abstention spends no energy
    env2 = FLEnv(FLEnvConfig(n_devices=6, n_rounds=5, seed=0))
    env2.reset()
    _, _, _, info2 = env2.step(np.full(6, 4))
    assert info2["energy"] >= info["energy"]
