"""Paper Fig. 6: learning curves / final accuracy for different fleet sizes
(RQ3 scalability).  Directional claim: DR-FL's advantage does not degrade —
and typically grows — with more heterogeneous devices."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FAST, bench_params, emit
from repro.fl import FLConfig, run_simulation

SIZES = (8, 14) if FAST else (10, 20, 40)


def main(seed=0, verbose=False):
    p = bench_params()
    results = {}
    for n in SIZES:
        for method, sel in (("drfl", "marl"), ("heterofl", "greedy")):
            t0 = time.time()
            cfg = FLConfig(**{**p, "n_devices": n}, method=method,
                           selector=sel, seed=seed, marl_episodes=3)
            h = run_simulation(cfg, verbose=verbose)
            acc = float(np.mean(h["best_acc"]))
            results[(n, method)] = acc
            emit(f"fig6/{method}/n{n}", (time.time() - t0) * 1e6,
                 f"best_acc_mean={acc:.3f}")
    for n in SIZES:
        emit(f"fig6/gap/n{n}", 0.0,
             f"drfl_minus_heterofl={results[(n, 'drfl')] - results[(n, 'heterofl')]:.3f}")
    return results


if __name__ == "__main__":
    main(verbose=True)
