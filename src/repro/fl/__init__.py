from repro.fl.simulation import FLConfig, run_simulation  # noqa: F401
from repro.fl.engine import (RoundEngine, build_world,  # noqa: F401
                             resolve_client_executor, sync_task_budget)
from repro.fl.environment import FLEnv, FLEnvConfig  # noqa: F401
from repro.core.fleet import FleetState, make_fleet_state  # noqa: F401
