"""FL client-side local training (paper Step 5).

Clients train with SGD + cross-entropy on their local shard.  The actual
per-method training programs live on the :class:`repro.models.family.
ModelFamily` singletons (``family.client_update(kind, ...)`` /
``family.loss_fn(kind)``) so the FL layer is model-agnostic; this module
keeps the stable flat API over the DEFAULT family plus the shared
per-(round, device) seed derivation.

Three client kinds mirror the three methods under comparison:

* ``drfl_client_update``    — depth-prefix submodel (loss at every held
  exit; grads are exactly zero outside the submodel, so the returned
  full-structure delta is already "zero-filled" for layer-aligned
  aggregation).
* ``heterofl_client_update`` — width-sliced submodel (HeteroFL).
* ``scalefl_client_update``  — depth+width submodel with self-distillation.

Each kind jits one program per submodel index per family — shapes are
static per index, so ``num_submodels`` programs cover a whole fleet.

This is the PER-CLIENT path (one dispatch per mini-batch): small fleets use
it directly, and it is the parity reference for the bucketed-vmap executor
(:mod:`repro.fl.batch`) that large fleets run — both train the same
per-method family losses.  Per-step losses accumulate on device and sync to
the host ONCE per client (``family._mean_loss``).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.models.family import resolve_family


def client_update_seed(base_seed: int, round_idx: int, device_idx: int) -> int:
    """Collision-free per-(round, device) seed for local training.

    The old ``base*1000 + t*100 + i`` mix collided across rounds for any
    ``i >= 100`` (every 100+ device fleet), silently correlating client
    batch orders.  ``SeedSequence`` hashes the entropy tuple, so distinct
    (base, round, device) triples map to distinct, well-mixed streams."""
    return int(np.random.SeedSequence(
        entropy=(int(base_seed), int(round_idx), int(device_idx))
    ).generate_state(1)[0])


# ---------------------------------------------------------------------------
# per-method local losses over the DEFAULT family — the bucketed executor
# and custom harnesses should prefer ``family.loss_fn(kind)`` directly
# ---------------------------------------------------------------------------


def drfl_submodel_loss(sub, x, y):
    return resolve_family().loss_fn("drfl")(sub, x, y)


def slice_submodel_loss(sub, x, y):
    return resolve_family().loss_fn("heterofl")(sub, x, y)


def scalefl_submodel_loss(sub, x, y):
    return resolve_family().loss_fn("scalefl")(sub, x, y)


# ---------------------------------------------------------------------------
# flat client-update API (defaults to the registered default family)
# ---------------------------------------------------------------------------


def client_update(method: str, global_params, model_idx: int, x, y, *,
                  epochs=5, batch=32, lr=0.05, seed=0, family=None
                  ) -> Tuple[Dict, float]:
    """Family-routed local training: ``(delta pytree, mean local loss)``."""
    return resolve_family(family).client_update(
        method, global_params, model_idx, x, y, epochs=epochs, batch=batch,
        lr=lr, seed=seed)


def drfl_client_update(global_params, model_idx: int, x, y, *, epochs=5,
                       batch=32, lr=0.05, seed=0, family=None
                       ) -> Tuple[Dict, float]:
    """Returns (delta pytree full structure, mean local loss)."""
    return client_update("drfl", global_params, model_idx, x, y,
                         epochs=epochs, batch=batch, lr=lr, seed=seed,
                         family=family)


def heterofl_client_update(global_params, model_idx: int, x, y, *, epochs=5,
                           batch=32, lr=0.05, seed=0, family=None):
    """Returns (sliced delta, mean loss); slice width = WIDTH_LEVELS[idx]."""
    return client_update("heterofl", global_params, model_idx, x, y,
                         epochs=epochs, batch=batch, lr=lr, seed=seed,
                         family=family)


def scalefl_client_update(global_params, model_idx: int, x, y, *, epochs=5,
                          batch=32, lr=0.05, seed=0, family=None):
    return client_update("scalefl", global_params, model_idx, x, y,
                         epochs=epochs, batch=batch, lr=lr, seed=seed,
                         family=family)
