"""Rule ``kernel-parity-contract``.

Every Pallas kernel package under ``src/repro/kernels/`` ships two
implementations of the same math: ``ops.py`` (the accelerated entry
point, with tuning knobs like block sizes and ``interpret``) and
``ref.py`` (the pure-jnp reference the parity tests compare against).
This rule enforces the contract structurally:

* both files exist per kernel package;
* public functions pair up by base name (``rmsnorm_ref`` ↔
  ``rmsnorm_op``, ``attention_ref`` ↔ ``flash_attention``) with the same
  number of required positional parameters, and every optional/kw-only
  parameter of the *ref* also accepted by the *op* (the op may add
  tuning-only knobs; the ref may not have semantics the op lacks);
* ``tests/test_kernels.py`` references at least one public name from
  each side, so the parity test actually exercises both paths.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Tuple

from ..core import Finding, RepoIndex

RULE = "kernel-parity-contract"


def _public_functions(path: str) -> Optional[Dict[str, ast.FunctionDef]]:
    try:
        with open(path, encoding="utf-8") as fh:
            tree = ast.parse(fh.read(), filename=path)
    except (OSError, SyntaxError):
        return None
    return {n.name: n for n in tree.body
            if isinstance(n, ast.FunctionDef) and not n.name.startswith("_")}


def _sig(fn: ast.FunctionDef) -> Tuple[int, List[str]]:
    """(required positional count, optional/kw-only parameter names)."""
    a = fn.args
    pos = a.posonlyargs + a.args
    n_required = len(pos) - len(a.defaults)
    optional = [p.arg for p in pos[n_required:]] + [p.arg for p in
                                                    a.kwonlyargs]
    return n_required, optional


def _base(name: str) -> str:
    return re.sub(r"(_ref|_op)$", "", name)


def _pair(ref_name: str, op_names) -> Optional[str]:
    """Match a ref function to its op by base-name containment."""
    rb = _base(ref_name)
    for op in op_names:
        ob = _base(op)
        if rb == ob or rb in ob or ob in rb:
            return op
    return None


def check(index: RepoIndex, config) -> List[Finding]:
    findings: List[Finding] = []
    kdir = os.path.join(config.repo_root, config.kernels_rel)
    if not os.path.isdir(kdir):
        return findings
    test_path = os.path.join(config.repo_root, config.kernels_test_rel)
    test_words = set()
    if os.path.isfile(test_path):
        with open(test_path, encoding="utf-8") as fh:
            test_words = set(re.findall(r"\w+", fh.read()))
    packages = sorted(
        d for d in os.listdir(kdir)
        if os.path.isdir(os.path.join(kdir, d)) and not d.startswith("_"))
    for pkg in packages:
        rel = f"{config.kernels_rel}/{pkg}"
        ops_path = os.path.join(kdir, pkg, "ops.py")
        ref_path = os.path.join(kdir, pkg, "ref.py")
        missing = [n for n, p in (("ops.py", ops_path), ("ref.py", ref_path))
                   if not os.path.isfile(p)]
        if missing:
            findings.append(Finding(
                rule=RULE, file=rel, line=1,
                message=f"kernel package '{pkg}' missing "
                        f"{' and '.join(missing)}"))
            continue
        ops = _public_functions(ops_path)
        refs = _public_functions(ref_path)
        if ops is None or refs is None:
            findings.append(Finding(
                rule=RULE, file=rel, line=1,
                message=f"kernel package '{pkg}' ops/ref not parseable"))
            continue
        if not refs:
            findings.append(Finding(
                rule=RULE, file=f"{rel}/ref.py", line=1,
                message=f"'{pkg}' ref.py exports no public functions"))
        for ref_name, ref_fn in sorted(refs.items()):
            op_name = _pair(ref_name, ops)
            if op_name is None:
                findings.append(Finding(
                    rule=RULE, file=f"{rel}/ref.py", line=ref_fn.lineno,
                    message=f"{ref_name} has no matching public function "
                            "in ops.py"))
                continue
            ref_req, ref_opt = _sig(ref_fn)
            op_req, op_opt = _sig(ops[op_name])
            if ref_req != op_req:
                findings.append(Finding(
                    rule=RULE, file=f"{rel}/ops.py",
                    line=ops[op_name].lineno,
                    message=f"{op_name} takes {op_req} required args but "
                            f"{ref_name} takes {ref_req} — signatures "
                            "drifted"))
            lost = sorted(set(ref_opt) - set(op_opt))
            if lost:
                findings.append(Finding(
                    rule=RULE, file=f"{rel}/ops.py",
                    line=ops[op_name].lineno,
                    message=f"{op_name} missing optional params {lost} "
                            f"that {ref_name} accepts"))
        # the parity test must reference both sides of each package
        if test_words:
            if not any(n in test_words for n in ops):
                findings.append(Finding(
                    rule=RULE, file=config.kernels_test_rel, line=1,
                    message=f"no ops.py function of '{pkg}' referenced in "
                            f"{os.path.basename(test_path)}"))
            if not any(n in test_words for n in refs):
                findings.append(Finding(
                    rule=RULE, file=config.kernels_test_rel, line=1,
                    message=f"no ref.py function of '{pkg}' referenced in "
                            f"{os.path.basename(test_path)}"))
        elif not os.path.isfile(test_path):
            findings.append(Finding(
                rule=RULE, file=config.kernels_test_rel, line=1,
                message=f"kernel parity test file "
                        f"{config.kernels_test_rel} missing"))
    return findings
