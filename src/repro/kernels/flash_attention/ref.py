"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: [BH, Sq, D]; k/v: [BHkv, Sk, D]; returns [BH, Sq, D]."""
    BH, Sq, D = q.shape
    BHkv, Sk, _ = k.shape
    group = BH // BHkv
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
