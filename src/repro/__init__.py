"""repro: production-grade JAX reproduction of DR-FL (energy-aware FL via
MARL dual-selection) plus a multi-arch, multi-pod distributed runtime."""

__version__ = "0.1.0"
