"""Dual-selection strategies (paper §4.3): choose, per round, (a) which
layer-wise model each device trains and (b) which devices participate.

``MarlSelector`` is the paper's method: per-agent argmax-Q picks the model
action (action M = do not participate), then Top-K over the chosen Q values
picks the participants.  Baseline selectors implement the comparison arms
used in §5 (greedy energy-aware, random, static-by-tier).

All selectors run on the vectorized :class:`repro.core.fleet.FleetState`
engine (affordability masks and cost matrices are single batched kernel
evaluations, not per-device Python loops).  They still accept a plain
``Sequence[DeviceState]`` — :func:`as_fleet_state` converts through the
numpy float64 backend, which matches the scalar reference semantics
bit-for-bit, so legacy callers see identical decisions.

``local_epochs``/``batch_size`` are threaded through ``select`` so the
affordability mask prices exactly the round the simulation will charge
(defaults match the paper's §5 values).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import DeviceState
from repro.core.fleet import (FleetState, as_fleet_state, fleet_affordability,
                              fleet_affordability_jit, fleet_cost_matrix,
                              fleet_cost_matrix_jit, fleet_is_jax)
from repro.core.marl.qmix import QmixConfig, QmixLearner, epsilon


@dataclasses.dataclass
class Selection:
    participants: List[int]          # device indices
    model_choice: List[int]          # per-device submodel index (-1 = none)
    q_values: Optional[np.ndarray] = None

    def __post_init__(self):
        # ``model_choice`` must cover the whole fleet: the engine indexes
        # it by raw device id, so a short list silently mis-indexes (or
        # IndexErrors rounds later).  Participants out of its range are a
        # selector bug — fail at construction, where the stack still
        # points at the offender.
        n = len(self.model_choice)
        bad = [int(i) for i in self.participants
               if not 0 <= int(i) < n]
        if bad:
            raise ValueError(
                f"Selection.participants {bad} out of range for "
                f"model_choice of length {n} (model_choice must have one "
                f"entry per fleet device)")


class SelectorBase:
    name = "base"

    def select(self, devices, round_idx: int, k: int,
               model_sizes: Sequence[float],
               model_fractions: Sequence[float],
               local_epochs: int = 5, batch_size: int = 32) -> Selection:
        raise NotImplementedError

    def observe_reward(self, reward: float,
                       sim_time: Optional[float] = None):
        """Credit the reward for the most recent ``select``.

        Under the event-driven engine this fires at EVENT time — when the
        dispatch's cohort of updates has arrived and been aggregated — with
        ``sim_time`` the fleet's virtual clock at that moment, rather than
        at a synchronous round barrier."""
        pass


def obs_vector(dev: DeviceState, round_idx: int, n_rounds: int) -> np.ndarray:
    """Paper Eq. 9: s_t^n = [L_n, C_n, E_n, t] (+ last-round latencies,
    §4.3.2), normalised to O(1) ranges.  Scalar reference for
    :func:`fleet_obs`."""
    return np.array([
        dev.data_size / 1000.0,
        dev.effective_compute(1.0) / 500.0,
        dev.remaining / dev.profile.battery,
        round_idx / max(n_rounds, 1),
        1.0 if dev.alive else 0.0,
    ], np.float32)


OBS_DIM = 5


def fleet_obs(fleet: FleetState, round_idx: int, n_rounds: int) -> np.ndarray:
    """[n, OBS_DIM] float32 — vectorized :func:`obs_vector` over the fleet."""
    t = round_idx / max(n_rounds, 1)
    cols = np.stack([
        np.asarray(fleet.data_size, np.float64) / 1000.0,
        np.asarray(fleet.compute * fleet.mode_compute) / 500.0,
        np.asarray(fleet.remaining / fleet.battery),
        np.full(len(fleet), t),
        np.asarray(fleet.alive, np.float64),
    ], axis=1)
    return cols.astype(np.float32)


class MarlSelector(SelectorBase):
    """The paper's MARL-based dual-selection (QMIX, Fig. 3)."""

    name = "marl"

    def __init__(self, n_devices: int, n_models: int, n_rounds: int,
                 seed: int = 0):
        self.n_models = n_models
        self.n_rounds = n_rounds
        cfg = QmixConfig(
            n_agents=n_devices, obs_dim=OBS_DIM, num_actions=n_models + 1,
            state_dim=n_devices * OBS_DIM,
            eps_decay_rounds=max(10, n_rounds // 2))
        self.learner = QmixLearner(cfg, jax.random.PRNGKey(seed))
        self.key = jax.random.PRNGKey(seed + 1)
        self.hidden = self.learner.init_hidden()
        self.total_rounds = 0   # epsilon decays on TOTAL experience (across
                                # pre-training episodes), not per-episode
        # episode trace for the replay buffer
        self.ep_obs: List[np.ndarray] = []
        self.ep_state: List[np.ndarray] = []
        self.ep_actions: List[np.ndarray] = []
        self.ep_rewards: List[float] = []

    def reset_episode(self):
        self.hidden = self.learner.init_hidden()
        self.ep_obs, self.ep_state = [], []
        self.ep_actions, self.ep_rewards = [], []

    def select(self, devices, round_idx, k, model_sizes, model_fractions,
               local_epochs=5, batch_size=32):
        fleet = as_fleet_state(devices)
        obs = fleet_obs(fleet, round_idx, self.n_rounds)
        state = obs.reshape(-1)
        self.key, sub = jax.random.split(self.key)
        eps = epsilon(self.learner.cfg, self.total_rounds)
        self.total_rounds += 1
        # affordability action mask ("prevent selected devices from dropping
        # out of the FL process due to energy limitations", paper §4.2 Step
        # 3), priced at the round the simulation will actually charge
        aff = (fleet_affordability_jit if fleet_is_jax(fleet)
               else fleet_affordability)
        avail = np.asarray(aff(
            fleet, model_sizes, model_fractions, local_epochs, batch_size))
        actions, qv, self.hidden = self.learner.act(
            jnp.asarray(obs), self.hidden, sub, eps, jnp.asarray(avail))
        qv = np.array(qv)
        alive = np.asarray(fleet.alive)
        # dead devices never participate
        actions = np.where(alive, np.array(actions), self.n_models)
        willing = np.flatnonzero(actions < self.n_models)
        # Top-K over Q values among willing agents (paper §4.3.3)
        order = willing[np.argsort(-qv[willing], kind="stable")]
        chosen = [int(i) for i in order[:k]]
        model_choice = [-1] * len(fleet)
        for i in chosen:
            model_choice[i] = int(actions[i])
        self.ep_obs.append(obs)
        self.ep_state.append(state)
        self.ep_actions.append(actions.copy())
        return Selection(participants=chosen, model_choice=model_choice,
                         q_values=qv)

    def observe_reward(self, reward: float,
                       sim_time: Optional[float] = None):
        # QMIX is time-index-agnostic: only the reward ORDER (aligned with
        # select calls by the engine's in-dispatch-order commits) matters
        self.ep_rewards.append(float(reward))

    def episode_arrays(self, final_devices, round_idx):
        obs = np.stack(self.ep_obs + [fleet_obs(
            as_fleet_state(final_devices), round_idx, self.n_rounds)])
        state = obs.reshape(obs.shape[0], -1)
        return (obs, state, np.stack(self.ep_actions),
                np.asarray(self.ep_rewards, np.float32))


class GreedySelector(SelectorBase):
    """Energy-aware greedy (the paper's baseline adaptation): each device
    picks the LARGEST submodel it can afford this round; Top-K by remaining
    energy."""

    name = "greedy"

    def select(self, devices, round_idx, k, model_sizes, model_fractions,
               local_epochs=5, batch_size=32):
        fleet = as_fleet_state(devices)
        M = len(model_sizes)
        costs = (fleet_cost_matrix_jit if fleet_is_jax(fleet)
                 else fleet_cost_matrix)
        _, _, e_tra, e_com = costs(
            fleet, model_sizes, model_fractions, local_epochs, batch_size)
        remaining = np.asarray(fleet.remaining)
        afford = (np.asarray(e_tra + e_com) < remaining[:, None]) \
            & np.asarray(fleet.alive)[:, None]          # [n, M]
        # largest affordable submodel per device (-1 if none)
        best = np.where(afford.any(axis=1),
                        M - 1 - np.argmax(afford[:, ::-1], axis=1), -1)
        cand = np.flatnonzero(best >= 0)
        order = cand[np.argsort(-remaining[cand], kind="stable")]
        chosen = [int(i) for i in order[:k]]
        model_choice = [-1] * len(fleet)
        for i in chosen:
            model_choice[i] = int(best[i])
        return Selection(participants=chosen, model_choice=model_choice)


class RandomSelector(SelectorBase):
    """Vanilla-FL-style: uniform random K clients, random affordable model."""

    name = "random"

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def select(self, devices, round_idx, k, model_sizes, model_fractions,
               local_epochs=5, batch_size=32):
        fleet = as_fleet_state(devices)
        alive = [int(i) for i in np.flatnonzero(np.asarray(fleet.alive))]
        self.rng.shuffle(alive)
        chosen = alive[:k]
        model_choice = [-1] * len(fleet)
        for i in chosen:
            model_choice[i] = int(self.rng.integers(0, len(model_sizes)))
        return Selection(participants=chosen, model_choice=model_choice)


class StaticTierSelector(SelectorBase):
    """HeteroFL-style static assignment: submodel fixed by device tier."""

    name = "static"
    TIER_MODEL = {"small": 0, "medium": 1, "large": 3}

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def select(self, devices, round_idx, k, model_sizes, model_fractions,
               local_epochs=5, batch_size=32):
        fleet = as_fleet_state(devices)
        alive = [int(i) for i in np.flatnonzero(np.asarray(fleet.alive))]
        self.rng.shuffle(alive)
        chosen = alive[:k]
        model_choice = [-1] * len(fleet)
        for i in chosen:
            m = self.TIER_MODEL[fleet.tiers[i]]
            model_choice[i] = min(m, len(model_sizes) - 1)
        return Selection(participants=chosen, model_choice=model_choice)
