"""The paper's own experimental backbone: ResNet-18 with 4 early exits
(Models 1-4), DR-FL section 5.1.1.  Not a transformer config — the CNN is
defined in repro.models.cnn; this entry records the FL experiment defaults."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="drfl-resnet18", family="cnn",
    num_layers=4,          # 4 stages == 4 layer-wise models
    d_model=512, num_heads=1, num_kv_heads=1, d_ff=0,
    vocab_size=10,         # num classes (CIFAR10 default)
    exit_points=(1, 2, 3, 4),
    source="DR-FL paper §5.1.1 (He et al. 2015 backbone)",
)

# Paper experimental defaults (§5)
BATCH_SIZE = 32
LOCAL_EPOCHS = 5
LEARNING_RATE = 0.05
PARTICIPATION_FRACTION = 0.10
BATTERY_JOULES = 7560.0         # 1500 mAh @ 5.04 V
VALIDATION_FRACTION = 0.04      # Table 2 optimum
REWARD_WEIGHTS = (1000.0, 0.01, 1.0)   # w1, w2, w3 (footnote 1)
