"""Jit'd wrapper + pytree adapter for the layer-agg kernel.

``aggregate_stacked_pytree`` flattens every stacked ``[L, ...]`` leaf of N
client update pytrees into one ``[N, L, D]`` call (padding D to the block
multiple), then scatters results back — so the whole of DR-FL Step 2 for a
scanned transformer is a handful of fused kernel launches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.layer_agg.layer_agg import layer_agg


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def layer_agg_op(updates, masks, weights, *, block_d=2048, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    N, L, D = updates.shape
    pad = (-D) % min(block_d, max(D, 1))
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, 0), (0, pad)))
    out = layer_agg(updates, masks, weights,
                    block_d=min(block_d, D + pad), interpret=interpret)
    return out[:, :D]


def aggregate_stacked_leaf(global_leaf, client_leaves, client_masks, weights,
                           interpret=None):
    """global_leaf: [L, ...]; client_leaves: list of [L, ...];
    client_masks: list of [L] (or broadcastable).  Returns updated leaf."""
    L = global_leaf.shape[0]
    D = int(global_leaf.size // L)
    U = jnp.stack([c.reshape(L, D) for c in client_leaves])      # [N,L,D]
    M = jnp.stack([jnp.broadcast_to(m.reshape(m.shape[0], -1)[:, 0], (L,))
                   for m in client_masks])                        # [N,L]
    w = jnp.asarray(weights, jnp.float32)
    avg = layer_agg_op(U, M, w, interpret=interpret)              # [L,D]
    return (global_leaf.astype(jnp.float32)
            + avg.reshape(global_leaf.shape)).astype(global_leaf.dtype)
