"""Pallas kernel for DR-FL layer-aligned aggregation (paper Step 2).

This is the server-side hot spot when many clients upload large layer-wise
updates: for stacked updates ``U [N, L, D]`` (N clients, L layers, D
flattened per-layer params), masks ``M [N, L]`` and data-size weights
``w [N]``:

    out[l, d] = sum_n w_n * M[n,l] * U[n,l,d] / max(sum_n w_n * M[n,l], eps)

One fused pass: the unfused XLA version materialises the ``[N, L, D]``
weighted product and a broadcasted denominator; here each grid step reduces
a ``[N, block_d]`` VMEM tile straight into the output — HBM traffic drops
from ~3·N·L·D to ~N·L·D reads + L·D writes.

Grid: (L, D // block_d); block over clients is unnecessary (N <= ~64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(u_ref, m_ref, w_ref, o_ref):
    u = u_ref[:, 0, :].astype(jnp.float32)          # [N, bd]
    m = m_ref[:, 0].astype(jnp.float32)             # [N]
    w = w_ref[...].astype(jnp.float32)              # [N]
    wm = w * m                                      # [N]
    num = wm @ u                                    # [bd]  (MXU row-vector)
    den = jnp.sum(wm)
    o_ref[0, :] = jnp.where(den > 0, num / jnp.maximum(den, 1e-12),
                            jnp.zeros_like(num)).astype(o_ref.dtype)


def layer_agg(updates, masks, weights, *, block_d=2048, interpret=False):
    """updates: [N, L, D]; masks: [N, L]; weights: [N] -> [L, D] float32."""
    N, L, D = updates.shape
    block_d = min(block_d, D)
    assert D % block_d == 0, f"D={D} % block_d={block_d}"
    grid = (L, D // block_d)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((N, 1, block_d), lambda l, j: (0, l, j)),
            pl.BlockSpec((N, 1), lambda l, j: (0, l)),
            pl.BlockSpec((N,), lambda l, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda l, j: (l, j)),
        out_shape=jax.ShapeDtypeStruct((L, D), jnp.float32),
        interpret=interpret,
    )(updates, masks, weights)
