"""Seeded fault injection for the async engine (churn as a first-class
timeline event, after the intermittent-availability setting of arXiv
2208.04505 and unreliable-participation MARL of arXiv 2201.02932).

A :class:`FaultPlan` is a frozen, seed-deterministic list of
:class:`FaultEvent`\\ s that the async engine pushes onto its event heap
at startup; each pops like any completion/hot-plug event, so a faulted
run is exactly as reproducible (and checkpoint/resumable) as a clean
one.

Event taxonomy (``kind``):

* ``"crash"``       — device dies mid-whatever: battery spent
  (``fleet_kill``), any in-flight task is lost, and its cohort is charged
  a wasted-energy penalty so the MARL selector *learns* flakiness.
* ``"timeout"``     — straggler: the in-flight task never completes; the
  device stays unresponsive (busy) until the task's deadline reaps it.
* ``"disconnect"``  — transient: alive -> False for ``duration`` sim
  seconds (in-flight task lost), then a ``"rejoin"`` event restores the
  device with its battery intact.
* ``"corrupt"``     — the device's next completed delta is replaced by a
  poisoned payload (``nan`` / ``inf`` / ``huge``); aggregation-side
  quarantine must keep it out of the global params.

``"rejoin"`` events are engine-internal (scheduled by a disconnect);
plans never contain them directly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

FAULT_KINDS = ("crash", "timeout", "disconnect", "corrupt")
CORRUPT_PAYLOADS = ("nan", "inf", "huge")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    time: float                  # sim-seconds
    kind: str                    # one of FAULT_KINDS (or "rejoin", internal)
    device: int
    duration: float = 0.0        # disconnect only: seconds until rejoin
    payload: str = ""            # corrupt only: nan | inf | huge

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    events: Tuple[FaultEvent, ...]

    def __post_init__(self):
        for ev in self.events:
            if ev.kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {ev.kind!r} "
                                 f"(expected one of {FAULT_KINDS})")
            if ev.kind == "corrupt" and ev.payload not in CORRUPT_PAYLOADS:
                raise ValueError(f"corrupt payload {ev.payload!r} "
                                 f"(expected one of {CORRUPT_PAYLOADS})")

    def __len__(self):
        return len(self.events)

    @staticmethod
    def sample(n_devices: int, horizon: float, *, crashes: int = 0,
               timeouts: int = 0, disconnects: int = 0, corrupts: int = 0,
               seed: int = 0) -> "FaultPlan":
        """Seed-deterministic plan: event times uniform over the middle
        90% of ``horizon`` sim-seconds, devices uniform over the fleet."""
        if horizon <= 0:
            raise ValueError("FaultPlan.sample needs horizon > 0 "
                             "(sim-seconds over which to spread events)")
        rng = np.random.default_rng((int(seed), 0xFA17))
        events = []
        for kind, count in (("crash", crashes), ("timeout", timeouts),
                            ("disconnect", disconnects),
                            ("corrupt", corrupts)):
            for _ in range(int(count)):
                t = float(rng.uniform(0.05, 0.95) * horizon)
                dev = int(rng.integers(0, n_devices))
                dur = float(rng.uniform(0.05, 0.25) * horizon)
                payload = str(rng.choice(CORRUPT_PAYLOADS))
                events.append(FaultEvent(
                    time=t, kind=kind, device=dev,
                    duration=dur if kind == "disconnect" else 0.0,
                    payload=payload if kind == "corrupt" else ""))
        events.sort(key=lambda e: (e.time, e.device, e.kind))
        return FaultPlan(events=tuple(events))

    @staticmethod
    def from_config(cfg) -> Optional["FaultPlan"]:
        """Build the plan the flat config describes (None = faults off)."""
        counts = dict(crashes=getattr(cfg, "fault_crashes", 0),
                      timeouts=getattr(cfg, "fault_timeouts", 0),
                      disconnects=getattr(cfg, "fault_disconnects", 0),
                      corrupts=getattr(cfg, "fault_corrupts", 0))
        if not any(counts.values()):
            return None
        horizon = (getattr(cfg, "fault_horizon", 0.0)
                   or getattr(cfg, "async_time_horizon", 0.0))
        if horizon <= 0:
            raise ValueError(
                "fault injection needs a time window: set fault_horizon "
                "(or async_time_horizon) > 0 so events can be scheduled")
        fault_seed = getattr(cfg, "fault_seed", -1)
        seed = fault_seed if fault_seed >= 0 else cfg.seed
        return FaultPlan.sample(cfg.n_devices, float(horizon), seed=seed,
                                **counts)


def poison_payload(payload: str):
    """The value a corrupted delta's leaves are filled with."""
    return {"nan": float("nan"), "inf": float("inf"),
            "huge": 1e30}[payload]
