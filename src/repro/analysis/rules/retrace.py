"""Rule ``retrace-hazard``.

Two ways this repo has historically re-traced per call:

1. ``jax.jit(...)`` (or ``functools.partial(jax.jit, ...)``) invoked
   inside a function body or loop.  Every call builds a fresh jitted
   wrapper with an empty cache, so every call re-traces.  Jit wrappers
   belong at module scope or in an explicit cache (``self._jit_cache``);
   when the in-body jit IS cached, say so with
   ``# jaxlint: allow(retrace-hazard) -- cached in self._jit_cache``.

2. ``static_argnames`` naming a parameter that some call site passes an
   array: each distinct array *value* hashes to a new cache entry, so
   the cache grows without bound and every new value re-traces.  The
   check resolves call sites of the jitted function across the repo and
   flags arguments to static params that are array-valued expressions
   (``jnp.*`` / ``np.*`` calls, or names bound from them).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from ..core import Finding, Module, RepoIndex

RULE = "retrace-hazard"

_ARRAY_MODULES = {"jax", "jax.numpy", "numpy"}


def _is_jit_expr(mod: Module, call: ast.Call) -> bool:
    """True when ``call`` is ``jax.jit(...)`` or
    ``functools.partial(jax.jit, ...)``."""
    func = call.func
    if isinstance(func, ast.Attribute) and func.attr == "jit":
        root = func.value
        if (isinstance(root, ast.Name)
                and mod.module_aliases.get(root.id, root.id) == "jax"):
            return True
    if isinstance(func, ast.Name):
        if mod.from_imports.get(func.id) == ("jax", "jit"):
            return True
    # functools.partial(jax.jit, ...)
    if (isinstance(func, ast.Attribute) and func.attr == "partial"
            and call.args):
        first = call.args[0]
        if (isinstance(first, ast.Attribute) and first.attr == "jit"
                and isinstance(first.value, ast.Name)
                and mod.module_aliases.get(first.value.id,
                                           first.value.id) == "jax"):
            return True
    return False


def _jit_calls_in_function_bodies(index: RepoIndex) -> List[Finding]:
    findings: List[Finding] = []
    for mod in index.modules.values():
        for info in index.functions_in(mod.modname):
            # walk the BODY only: the function's own decorators run at
            # module/class scope, where jax.jit belongs
            for node in (n for stmt in info.node.body
                         for n in ast.walk(stmt)):
                if isinstance(node, ast.Call) and _is_jit_expr(mod, node):
                    where = info.qualname.split(":")[-1]
                    findings.append(Finding(
                        rule=RULE, file=mod.relpath, line=node.lineno,
                        message=f"jax.jit constructed inside {where}() — "
                                "each call re-traces unless the wrapper is "
                                "cached; move it to module scope or an "
                                "explicit cache"))
                # @jax.jit on a def nested inside a function body is the
                # same hazard: the decorator runs on every enclosing call.
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    for deco in node.decorator_list:
                        d = deco.func if isinstance(deco, ast.Call) else deco
                        if (isinstance(d, ast.Attribute) and d.attr == "jit"
                                and isinstance(d.value, ast.Name)
                                and mod.module_aliases.get(
                                    d.value.id, d.value.id) == "jax"):
                            findings.append(Finding(
                                rule=RULE, file=mod.relpath,
                                line=deco.lineno,
                                message=f"@jax.jit on a def nested inside "
                                        f"{info.name}() re-jits per call"))
    # module scope: a jit constructed inside a module-level loop
    for mod in index.modules.values():
        for top in mod.tree.body:
            if isinstance(top, (ast.For, ast.While)):
                for node in ast.walk(top):
                    if isinstance(node, ast.Call) and _is_jit_expr(mod, node):
                        findings.append(Finding(
                            rule=RULE, file=mod.relpath, line=node.lineno,
                            message="jax.jit constructed inside a "
                                    "module-level loop"))
    return findings


# -- static_argnames vs array-valued call sites -----------------------------

def _static_names_of(call: ast.Call) -> Tuple[str, ...]:
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums") \
                and kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                return tuple(e.value for e in v.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, str))
    return ()


def _collect_static_jits(index: RepoIndex) \
        -> Dict[str, Tuple[str, Tuple[str, ...], List[str]]]:
    """callable-name -> (defining module, static names, param order).

    Covers module-level aliases (``f_jit = jax.jit(f, static_argnames=...)``)
    and ``@partial(jax.jit, static_argnames=...)`` decorated defs.
    """
    out: Dict[str, Tuple[str, Tuple[str, ...], List[str]]] = {}

    def params_of(fn_node) -> List[str]:
        a = fn_node.args
        return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]

    for mod in index.modules.values():
        for alias, (target, call) in mod.jit_aliases.items():
            statics = _static_names_of(call)
            if not statics:
                continue
            hit = index.functions.get(f"{mod.modname}:{target}")
            if hit:
                out[alias] = (mod.modname, statics, params_of(hit.node))
        for info in index.functions_in(mod.modname):
            for deco in info.node.decorator_list:
                if isinstance(deco, ast.Call) and _is_jit_expr(mod, deco):
                    statics = _static_names_of(deco)
                    if statics:
                        out[info.name] = (mod.modname, statics,
                                          params_of(info.node))
    return out


def _is_arrayish(mod: Module, node: ast.expr,
                 array_names: Set[str]) -> bool:
    """Heuristic: expression clearly produces an array."""
    if isinstance(node, ast.Name):
        return node.id in array_names
    if isinstance(node, ast.Call):
        func = node.func
        root = func
        while isinstance(root, ast.Attribute):
            root = root.value
        if isinstance(root, ast.Name):
            target = mod.module_aliases.get(root.id, "")
            if target in _ARRAY_MODULES:
                return True
    if isinstance(node, (ast.Subscript, ast.Attribute)):
        return _is_arrayish(mod, node.value, array_names)
    return False


def _array_locals(mod: Module, fn_node) -> Set[str]:
    """Names in ``fn_node`` bound from jnp./np.-rooted expressions."""
    names: Set[str] = set()
    for stmt in ast.walk(fn_node):
        if isinstance(stmt, ast.Assign) and \
                _is_arrayish(mod, stmt.value, names):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name):
                    names.add(tgt.id)
    return names


def _static_argnames_misuse(index: RepoIndex) -> List[Finding]:
    jits = _collect_static_jits(index)
    if not jits:
        return []
    findings: List[Finding] = []
    for mod in index.modules.values():
        for info in index.functions_in(mod.modname):
            arr_names: Optional[Set[str]] = None
            for node in ast.walk(info.node):
                if not isinstance(node, ast.Call):
                    continue
                name = None
                if isinstance(node.func, ast.Name):
                    name = node.func.id
                elif isinstance(node.func, ast.Attribute):
                    name = node.func.attr
                if name not in jits:
                    continue
                def_mod, statics, params = jits[name]
                if arr_names is None:
                    arr_names = _array_locals(mod, info.node)
                # positional args mapped onto the param order
                for i, arg in enumerate(node.args):
                    if i < len(params) and params[i] in statics and \
                            _is_arrayish(mod, arg, arr_names):
                        findings.append(Finding(
                            rule=RULE, file=mod.relpath, line=node.lineno,
                            message=f"array passed positionally to static "
                                    f"param '{params[i]}' of {name} — every "
                                    "distinct value re-traces"))
                for kw in node.keywords:
                    if kw.arg in statics and \
                            _is_arrayish(mod, kw.value, arr_names):
                        findings.append(Finding(
                            rule=RULE, file=mod.relpath, line=node.lineno,
                            message=f"array passed to static param "
                                    f"'{kw.arg}' of {name} — every distinct "
                                    "value re-traces"))
    return findings


def check(index: RepoIndex, config) -> List[Finding]:
    return _jit_calls_in_function_bodies(index) + \
        _static_argnames_misuse(index)
