"""Mixtral-8x22B — 8 experts top-2, sliding-window attention
[arXiv:2401.04088].  d_ff is the per-expert FFN width."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768,
    head_dim=128, rope_theta=1_000_000.0,
    num_experts=8, experts_per_token=2,
    window=4096,
    exit_points=(14, 28, 42, 56),
    source="arXiv:2401.04088",
)
